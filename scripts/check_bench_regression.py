"""Perf-trajectory regression gate: compare fresh BENCH_*.json bench runs
against the committed baselines in ``benchmarks/baselines/``.

The serving benches already assert CORRECTNESS invariants inline (stream
identity, acceptance > 0, int4 KV reduction, ...).  What they could not
catch is a silent trajectory regression — a refactor that doubles
compiled shapes, inflates pad waste, or stops skipping cached prefill
tokens still passes every identity assert.  This gate closes that hole:
every bench row is diffed against its committed baseline value, with a
tolerance policy keyed on the row's UNIT:

* **structural units** (``count``, ``frac``, ``rows``, ``tok``, ``MB``,
  ``B``, ``pages``) are deterministic on CPU CI — compiled-shape counts,
  prefill rows, KV bytes, cache hit fractions.  They gate: relative
  drift beyond ``--default-tolerance`` (or a per-row ``--tolerance
  NAME=FRAC`` override) fails the check, modulo a small absolute
  epsilon so 0-vs-0 and tiny-count jitter never trip it.
* **timing units** (``ms``, ``s``, ``tok/s``, ``x``) are hardware noise
  on shared runners — they are reported (so the artifact preserves the
  trajectory) but NEVER gate.

A row present in the baseline but missing from the candidate FAILS (a
deleted metric is a silent coverage loss — update the baseline
deliberately instead); new candidate rows are reported as informational
(they gate once committed to the baseline).

  python scripts/check_bench_regression.py \\
      --compare benchmarks/baselines/BENCH_serving.json=BENCH_serving.json \\
      [--default-tolerance 0.05] [--tolerance NAME=FRAC ...] \\
      [--abs-epsilon 1e-9] [--warn-only]

``--warn-only`` prints GitHub ``::warning`` annotations for failures and
exits 0 — the introduction mode while baselines stabilise.  Drop the
flag to make drift fail the job.  To accept an intended change, rerun
the bench and copy the fresh JSON over the committed baseline.

Host-only, stdlib-only (the CI step runs it without jax).
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Tuple

# units that gate (deterministic structure) vs report-only (wall time)
STRUCTURAL_UNITS = {"count", "frac", "rows", "tok", "MB", "B", "pages"}
TIMING_UNITS = {"ms", "s", "tok/s", "x"}


def load_rows(path: str) -> Dict[str, Tuple[float, str]]:
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for row in doc["rows"]:
        rows[row["name"]] = (float(row["value"]), str(row["unit"]))
    return rows


def compare(baseline: Dict[str, Tuple[float, str]],
            candidate: Dict[str, Tuple[float, str]],
            *, default_tol: float, abs_eps: float,
            overrides: Dict[str, float],
            label: str) -> Tuple[List[str], List[str]]:
    """(failures, notes) for one baseline=candidate pair."""
    failures: List[str] = []
    notes: List[str] = []
    for name in sorted(baseline):
        base_v, unit = baseline[name]
        if name not in candidate:
            failures.append(f"{label}: row '{name}' missing from candidate "
                            f"(baseline {base_v:.6g} {unit})")
            continue
        cand_v, cand_unit = candidate[name]
        if cand_unit != unit:
            failures.append(f"{label}: row '{name}' changed unit "
                            f"{unit!r} -> {cand_unit!r}")
            continue
        both_nan = math.isnan(base_v) and math.isnan(cand_v)
        if both_nan:
            continue
        nan_flip = math.isnan(base_v) != math.isnan(cand_v)
        diff = abs(cand_v - base_v) if not nan_flip else math.inf
        rel = diff / max(abs(base_v), abs_eps)
        tol = overrides.get(name, default_tol)
        drifted = nan_flip or (diff > abs_eps and rel > tol)
        line = (f"{label}: {name} [{unit}] baseline {base_v:.6g} -> "
                f"candidate {cand_v:.6g} "
                f"({'nan flip' if nan_flip else f'rel {rel:.1%}'}, "
                f"tol {tol:.1%})")
        if unit in STRUCTURAL_UNITS:
            if drifted:
                failures.append(line)
        elif drifted:
            notes.append(f"timing drift (informational) {line}")
    for name in sorted(set(candidate) - set(baseline)):
        v, unit = candidate[name]
        notes.append(f"{label}: new row '{name}' ({v:.6g} {unit}) — "
                     f"not in baseline, gates once committed")
    return failures, notes


def parse_tolerances(specs: List[str]) -> Dict[str, float]:
    out = {}
    for spec in specs:
        name, _, frac = spec.partition("=")
        if not name or not frac:
            raise SystemExit(f"--tolerance expects NAME=FRAC, got {spec!r}")
        out[name] = float(frac)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--compare", action="append", default=[],
                    metavar="BASELINE=CANDIDATE", required=True,
                    help="baseline json = freshly generated json "
                         "(repeatable, one per bench leg)")
    ap.add_argument("--default-tolerance", type=float, default=0.05,
                    help="relative drift allowed on structural rows "
                         "without a per-row override (default 5%%)")
    ap.add_argument("--tolerance", action="append", default=[],
                    metavar="NAME=FRAC",
                    help="per-row relative tolerance override "
                         "(repeatable)")
    ap.add_argument("--abs-epsilon", type=float, default=1e-9,
                    help="absolute slack under which drift never gates "
                         "(protects 0-vs-0 rows)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report failures as GitHub ::warning lines and "
                         "exit 0 (baseline introduction mode)")
    args = ap.parse_args(argv)
    overrides = parse_tolerances(args.tolerance)

    failures: List[str] = []
    notes: List[str] = []
    for pair in args.compare:
        base_path, _, cand_path = pair.partition("=")
        if not base_path or not cand_path:
            raise SystemExit(f"--compare expects BASELINE=CANDIDATE, "
                             f"got {pair!r}")
        label = f"{base_path} vs {cand_path}"
        try:
            baseline = load_rows(base_path)
            candidate = load_rows(cand_path)
        except (OSError, ValueError, KeyError) as e:
            failures.append(f"{label}: unreadable bench json: {e}")
            continue
        f, n = compare(baseline, candidate,
                       default_tol=args.default_tolerance,
                       abs_eps=args.abs_epsilon,
                       overrides=overrides, label=label)
        failures += f
        notes += n
        gated = sum(1 for _, (_, u) in baseline.items()
                    if u in STRUCTURAL_UNITS)
        print(f"check_bench_regression: {label}: {len(baseline)} baseline "
              f"rows ({gated} gated), {len(f)} drift failure(s)")
    for n in notes:
        print(f"note: {n}")
    for f in failures:
        if args.warn_only:
            print(f"::warning title=bench drift::{f}")
        else:
            print(f"FAIL: {f}", file=sys.stderr)
    if failures and args.warn_only:
        print(f"check_bench_regression: {len(failures)} drift(s) "
              f"(warn-only: exit 0)")
        return 0
    if failures:
        return 1
    print("check_bench_regression: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
