"""Dev smoke: every reduced arch runs train fwd + prefill + decode on CPU."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import (
    build_plan,
    decode_step,
    forward_train,
    init_params,
    prefill,
)
from dataclasses import replace

def run(name: str) -> None:
    cfg = get_config(name).reduced()
    cfg = replace(cfg, dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    n_params = sum(np.prod(x.shape) for x in jax.tree.leaves(params))
    B, T = 2, 64
    if cfg.n_codebooks > 1:
        tokens = jax.random.randint(key, (B, cfg.n_codebooks, T), 0, cfg.vocab_size)
    else:
        tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision":
        batch["tokens"] = tokens[:, : T - cfg.n_frontend_tokens]
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    logits, aux = forward_train(params, cfg, batch, remat=False)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{name}: NaN in train logits"
    # prefill + decode
    pl_logits, cache = prefill(params, cfg, batch)
    assert not bool(jnp.any(jnp.isnan(pl_logits))), f"{name}: NaN in prefill"
    if cfg.n_codebooks > 1:
        nt = jnp.argmax(pl_logits[:, -1], axis=-1).reshape(B, cfg.n_codebooks, 1)
    else:
        nt = jnp.argmax(pl_logits[:, -1:], axis=-1).reshape(B, 1)
    dbatch = {"tokens": nt}
    if cfg.frontend == "vision":
        dbatch["vision_embeds"] = batch["vision_embeds"][:, :0]
    dl, cache = decode_step(params, cfg, dbatch, cache, jnp.int32(T))
    assert not bool(jnp.any(jnp.isnan(dl))), f"{name}: NaN in decode"
    print(f"OK {name:20s} params={n_params:>10,} runs={len(build_plan(cfg))} "
          f"logits={tuple(logits.shape)}")

if __name__ == "__main__":
    archs = sys.argv[1:] or list_archs()
    for a in archs:
        run(a)
