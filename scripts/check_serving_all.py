"""Lint check: serving ``__all__`` literals must exactly match the public
surface of their module.

Pure AST — no imports of the package (the CI lint job has no jax).  Two
module shapes are checked:

  * ``src/repro/serving/__init__.py`` — the package facade: public names
    bound by top-level imports and assignments must match ``__all__``.
  * ``src/repro/serving/types.py`` — the host-only dataclass module split
    out of engine.py: public names DEFINED here (classes, functions,
    assignments — imports are implementation detail, not surface) must
    match ``__all__``.
  * ``src/repro/serving/metrics.py`` / ``tracing.py`` — the observability
    layer (PR 9), same definition-surface rule as types.py.
  * ``src/repro/serving/frontend.py`` / ``traffic.py`` — the async
    front-end and traffic harness (PR 10), same rule.

A name bound but not listed, or listed but never bound, fails the job;
so does an unsorted or duplicated ``__all__``.

  python scripts/check_serving_all.py
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path

SERVING = Path(__file__).resolve().parent.parent / "src/repro/serving"
# path -> do imports count as public surface (True only for the facade)
TARGETS = [(SERVING / "__init__.py", True), (SERVING / "types.py", False),
           (SERVING / "metrics.py", False), (SERVING / "tracing.py", False),
           (SERVING / "frontend.py", False), (SERVING / "traffic.py", False)]


def check(path: Path, imports_are_surface: bool) -> list[str]:
    tree = ast.parse(path.read_text())
    declared: list[str] = []
    bound: set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            if not imports_are_surface:
                continue
            for alias in node.names:
                name = alias.asname or alias.name.split(".")[0]
                if not name.startswith("_"):
                    bound.add(name)
        elif isinstance(node, (ast.ClassDef, ast.FunctionDef,
                               ast.AsyncFunctionDef)):
            if not node.name.startswith("_"):
                bound.add(node.name)
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    if tgt.id == "__all__":
                        declared = [ast.literal_eval(e)
                                    for e in node.value.elts]
                    elif not tgt.id.startswith("_"):
                        bound.add(tgt.id)
    errors = []
    if not declared:
        errors.append("no __all__ literal found")
    missing = bound - set(declared)
    if missing:
        errors.append(f"bound but not in __all__: {sorted(missing)}")
    phantom = set(declared) - bound
    if phantom:
        errors.append(f"in __all__ but never bound: {sorted(phantom)}")
    if len(declared) != len(set(declared)):
        errors.append("__all__ has duplicates")
    if declared != sorted(declared):
        errors.append("__all__ is not sorted")
    if not errors:
        print(f"check_serving_all: {path.name} OK "
              f"({len(declared)} exported names)")
    return [f"check_serving_all: {path}: {e}" for e in errors]


def main() -> int:
    errors: list[str] = []
    for path, imports_are_surface in TARGETS:
        errors += check(path, imports_are_surface)
    for e in errors:
        print(e, file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
