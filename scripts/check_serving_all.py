"""Lint check: ``repro.serving.__all__`` must exactly match the names the
package publicly re-exports.

Pure AST — no imports of the package (the CI lint job has no jax), so it
parses ``src/repro/serving/__init__.py`` and compares the ``__all__``
literal against every public name bound at module top level (imports and
assignments).  A name imported but not listed, or listed but never
bound, fails the job; so does an unsorted or duplicated ``__all__``.

  python scripts/check_serving_all.py
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path

INIT = Path(__file__).resolve().parent.parent / "src/repro/serving/__init__.py"


def main() -> int:
    tree = ast.parse(INIT.read_text())
    declared: list[str] = []
    bound: set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                name = alias.asname or alias.name.split(".")[0]
                if not name.startswith("_"):
                    bound.add(name)
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    if tgt.id == "__all__":
                        declared = [ast.literal_eval(e)
                                    for e in node.value.elts]
                    elif not tgt.id.startswith("_"):
                        bound.add(tgt.id)
    errors = []
    if not declared:
        errors.append("no __all__ literal found")
    missing = bound - set(declared)
    if missing:
        errors.append(f"bound but not in __all__: {sorted(missing)}")
    phantom = set(declared) - bound
    if phantom:
        errors.append(f"in __all__ but never bound: {sorted(phantom)}")
    if len(declared) != len(set(declared)):
        errors.append("__all__ has duplicates")
    if declared != sorted(declared):
        errors.append("__all__ is not sorted")
    if errors:
        for e in errors:
            print(f"check_serving_all: {INIT}: {e}", file=sys.stderr)
        return 1
    print(f"check_serving_all: OK ({len(declared)} exported names)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
