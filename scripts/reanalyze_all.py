"""Regenerate the roofline table from every saved dry-run HLO with the
CURRENT analyzer (launch/hlo_analysis.py) — no recompilation.

  PYTHONPATH=src python scripts/reanalyze_all.py results/hlo results/dryrun_v2.jsonl
  PYTHONPATH=src python scripts/reanalyze_all.py results/hlo_perf results/perf_v2.jsonl --flash
"""

import json
import os
import sys

from repro.configs.base import SHAPES, get_config
from repro.launch.reanalyze import FLASH_REGIONS, analyze_file
from repro.launch.roofline import model_flops_for


def cell_from_filename(name: str):
    # <arch>_<shape>_<pod>[ _variant].hlo ; shape names contain '_'
    base = name[:-4]
    for shape in SHAPES:
        tag = f"_{shape}_"
        if tag in base:
            arch, rest = base.split(tag)
            parts = rest.split("_")
            pod = parts[0]
            variant = "_".join(parts[1:]) if len(parts) > 1 else ""
            return arch, shape, pod, variant
    return None


def main():
    hlo_dir = sys.argv[1]
    out_path = sys.argv[2]
    flash = "--flash" in sys.argv
    regions = FLASH_REGIONS if flash else ()
    rows = []
    for name in sorted(os.listdir(hlo_dir)):
        if not name.endswith(".hlo"):
            continue
        parsed = cell_from_filename(name)
        if not parsed:
            print(f"skip {name}", file=sys.stderr)
            continue
        arch, shape, pod, variant = parsed
        cfg = get_config(arch)
        sh = SHAPES[shape]
        n_chips = 512 if pod == "pod2" else 256
        mf = model_flops_for(cfg, sh.kind, sh.seq_len, sh.global_batch)
        row = analyze_file(os.path.join(hlo_dir, name), regions,
                           n_chips=n_chips, model_flops=mf)
        cid = f"{arch}|{shape}|{pod}"
        if variant:
            cid += f"|{variant}"
        if flash:
            cid += "|flashkrn"
        row["cell"] = cid
        row["arch"], row["shape"], row["n_chips"] = arch, shape, n_chips
        row.pop("top_shapes", None)
        rows.append(row)
        print(f"{cid:50s} t_c {row['t_compute_s']:8.3f} "
              f"t_m {row['t_memory_s']:8.3f} t_l {row['t_collective_s']:8.3f} "
              f"{row['bottleneck'][:4]} roof {row.get('roofline_frac', 0):.4f}",
              flush=True)
    with open(out_path, "w") as f:
        for r in rows:
            f.write(json.dumps(r, default=str) + "\n")
    print(f"wrote {len(rows)} rows to {out_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
