"""Validate the analytical model against every paper claim."""
from repro.configs import get_config
from repro.core import evaluate, gmean_speedup
from repro.core.scheduler import PREFILL_LENGTHS, DECODE_GRID, geomean

llama = get_config("llama2-7b")
qwen = get_config("qwen3-8b")

claims = []
# Fig 5: fully-CiM prefill 6x faster TTFT than fully-CiD
r = geomean([evaluate(llama, "full_cid", L, 1).ttft / evaluate(llama, "full_cim", L, 1).ttft
             for L in PREFILL_LENGTHS])
claims.append(("Fig5a TTFT  full_cid/full_cim", r, 6.0))
r = geomean([evaluate(llama, "full_cid", L, 1).prefill_energy /
             evaluate(llama, "full_cim", L, 1).prefill_energy for L in PREFILL_LENGTHS])
claims.append(("Fig5b E_pre full_cid/full_cim", r, 2.6))
# Fig 6: fully-CiD decode 39x faster TPOT than fully-CiM
r = geomean([evaluate(llama, "full_cim", li, lo).tpot / evaluate(llama, "full_cid", li, lo).tpot
             for li, lo in DECODE_GRID])
claims.append(("Fig6a TPOT  full_cim/full_cid", r, 39.0))
r = geomean([(evaluate(llama, "full_cim", li, lo).decode_energy /
              evaluate(llama, "full_cid", li, lo).decode_energy) for li, lo in DECODE_GRID])
claims.append(("Fig6b E_dec full_cim/full_cid", r, 3.9))
# Fig 7: HALO1 prefill vs CENT 6.54x
r = gmean_speedup(llama, "cent", "halo1", metric="ttft")
claims.append(("Fig7 TTFT   cent/halo1", r, 6.54))
# decode vs attacc1: 34x
r = gmean_speedup(llama, "attacc1", "halo1", metric="tpot")
claims.append(("Fig7 TPOT   attacc1/halo1", r, 34.0))
# e2e: 18x vs attacc1, 2.4x vs cent (gmean across models)
for m, name in [(llama, "llama2"), (qwen, "qwen3")]:
    claims.append((f"Fig7 e2e    attacc1/halo1 {name}", gmean_speedup(m, "attacc1", "halo1"), 18.0))
    claims.append((f"Fig7 e2e    cent/halo1    {name}", gmean_speedup(m, "cent", "halo1"), 2.4))
# HALO2 vs HALO1 e2e: 10% slowdown
claims.append(("Fig7 e2e    halo2/halo1", gmean_speedup(llama, "halo2", "halo1"), 1.10))
# Fig 8 energy: 2x vs attacc1, 1.8x vs cent
claims.append(("Fig8 E e2e  attacc1/halo1", gmean_speedup(llama, "attacc1", "halo1", metric="energy"), 2.0))
claims.append(("Fig8 E e2e  cent/halo1", gmean_speedup(llama, "cent", "halo1", metric="energy"), 1.8))
# Fig 10: HALO-CiM1 1.3x over HALO-SA
claims.append(("Fig10 e2e   halo_sa/halo1", gmean_speedup(llama, "halo_sa", "halo1"), 1.3))

print(f"{'claim':<38} {'model':>8} {'paper':>7} {'ratio':>6}")
for name, got, want in claims:
    print(f"{name:<38} {got:>8.2f} {want:>7.2f} {got/want:>6.2f}")
