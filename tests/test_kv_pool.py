"""Paged KV arena: pool invariants (hypothesis), paged-vs-dense token
identity, token-level admission, preemption, pool-bounded capacity, and the
int8 page format."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving.engine import RequestState, ServeConfig, ServingEngine
from repro.serving.kv_pool import KVPool, PagePool
from repro.serving.scheduler import PhaseAwareConfig, PhaseScheduler


def tiny_cfg(name="qwen3-1.7b"):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


_PARAMS = {}


def cached_params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def make_engine(cfg, max_batch=3, max_len=64, *, paged=False, page_size=8,
                n_pages=24, kv_dtype="f32", prefill_chunk=2048,
                max_prefill_tokens=8192):
    params = cached_params(cfg)
    sc = ServeConfig(max_batch=max_batch, max_len=max_len,
                     phase=PhaseAwareConfig(max_decode_batch=max_batch,
                                            prefill_chunk=prefill_chunk,
                                            max_prefill_tokens=max_prefill_tokens),
                     paged=paged, page_size=page_size, n_pages=n_pages,
                     kv_dtype=kv_dtype)
    return ServingEngine(cfg, params, sc)


def prompts(cfg, n, L, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (L,), dtype=np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# PagePool invariants (pure host logic)
# ---------------------------------------------------------------------------


def test_page_pool_basic_alloc_free():
    p = PagePool(n_pages=8, page_size=4, n_slots=2, capacity=32)
    assert p.width == 8 and p.free_pages() == 8
    assert p.grow(0, 10)                 # 3 pages
    assert p.used_pages() == 3 and int(p.lens[0]) == 10
    assert p.grow(0, 12)                 # still 3 pages (page tail)
    assert p.used_pages() == 3
    assert p.grow(1, 17)                 # 5 pages -> pool exactly full
    assert p.free_pages() == 0
    assert not p.grow(0, 13)             # needs a 4th page: refused
    assert int(p.lens[0]) == 12          # refusal left state untouched
    p.release(1)
    assert p.free_pages() == 5
    assert p.grow(0, 13)
    p.check_invariants()


def test_page_pool_ring_capacity_clamps():
    """A sliding-window pool never needs more than ceil(R / P) pages."""
    p = PagePool(n_pages=8, page_size=4, n_slots=1, capacity=10)  # ring R=10
    assert p.width == 3
    assert p.grow(0, 500)                # any length: ring reuses its pages
    assert p.used_pages() == 3
    p.check_invariants()


def test_kv_pool_grow_is_all_or_nothing():
    """A partial per-run success must roll back (no leaked pages)."""
    cfg = tiny_cfg("gemma3-1b")          # mixed window/full runs
    pool = KVPool(cfg, n_slots=2, n_pages=4, page_size=4)
    # capacity 16; ring runs clamp at min(window=16, 16)
    assert pool.grow(0, 12)
    free_before = [p.free_pages() for p in pool.pools]
    assert not pool.grow(1, 16)          # full runs out of pages
    assert [p.free_pages() for p in pool.pools] == free_before
    for p in pool.pools:
        p.check_invariants()


def test_kv_pool_accounting():
    cfg = tiny_cfg()
    pool = KVPool(cfg, n_slots=2, n_pages=8, page_size=4)
    assert pool.resident_bytes() == 0
    assert pool.grow(0, 9)
    r1 = pool.resident_bytes()
    assert r1 == 3 * pool.page_bytes(0)
    assert 0 < r1 < pool.total_bytes()
    assert pool.utilization() == pytest.approx(3 / 8)
    pool.release(0)
    assert pool.resident_bytes() == 0 and pool.free_pages() == 8


try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(
        n_pages=st.integers(2, 16),
        page_size=st.integers(1, 8),
        ops=st.lists(
            st.tuples(st.integers(0, 2),       # 0 grow, 1 release, 2 shrink
                      st.integers(0, 3),       # slot
                      st.integers(0, 40)),     # length delta / target
            max_size=60),
    )
    def test_page_pool_interleavings_conserve_pages(n_pages, page_size, ops):
        """ANY interleaving of grow/release/shrink (alloc, retire, preempt)
        never double-assigns a page and conserves n_pages."""
        pool = PagePool(n_pages, page_size, n_slots=4,
                        capacity=n_pages * page_size)
        for kind, slot, arg in ops:
            if kind == 0:
                pool.grow(slot, int(pool.lens[slot]) + arg)
            elif kind == 1:
                pool.release(slot)
            else:
                pool.shrink(slot, min(int(pool.lens[slot]), arg))
            pool.check_invariants()
        assert (sum(p2.used_pages() for p2 in [pool])
                + pool.free_pages()) == n_pages


# ---------------------------------------------------------------------------
# scheduler: token-level (page-aware) admission
# ---------------------------------------------------------------------------


def test_scheduler_admits_only_what_free_pages_cover():
    s = PhaseScheduler(PhaseAwareConfig(
        "halo", max_decode_batch=4, max_prefill_tokens=1000,
        prefill_chunk=600))
    # 2 free pages of 8 tokens; request 10 already holds 4 tokens of a page
    plan = s.plan_tick(waiting=[(10, 600, True, 4), (11, 600, True, 0)],
                       decoding=[], free_pages=2, page_size=8)
    # req 10: page tail (4) + 2 fresh pages = 20 coverable tokens
    assert plan.prefill_chunks == [(10, 20)]
    # no pages at all: nothing admitted even though the token budget is open
    plan = s.plan_tick(waiting=[(11, 600, True, 0)], decoding=[],
                       free_pages=0, page_size=8)
    assert plan.prefill_chunks == []
    # page-tail tokens are admitted without consuming a page
    plan = s.plan_tick(waiting=[(12, 3, True, 5)], decoding=[],
                       free_pages=0, page_size=8)
    assert plan.prefill_chunks == [(12, 3)]


def test_scheduler_page_accounting_across_requests():
    """Pages consumed by an earlier chunk shrink what later ones may take
    (two fresh requests cannot share one free page)."""
    s = PhaseScheduler(PhaseAwareConfig(
        "halo", max_decode_batch=4, max_prefill_tokens=1000,
        prefill_chunk=600))
    plan = s.plan_tick(waiting=[(1, 5, True, 0), (2, 5, True, 0)],
                       decoding=[], free_pages=1, page_size=8)
    assert plan.prefill_chunks == [(1, 5)]   # req 2 has no page left


# ---------------------------------------------------------------------------
# paged-vs-dense engine identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b",       # GQA
                                  "gemma3-1b",        # sliding-window ring
                                  "deepseek-v2-236b"  # MLA latent pages
                                  ])
def test_paged_engine_token_identical_to_dense(arch):
    """Paged and dense engines produce identical greedy token streams —
    the pool + block tables + paged kernel are a pure relayout."""
    cfg = tiny_cfg(arch)
    ps = prompts(cfg, 4, 14, seed=2)
    dense = make_engine(cfg)
    rd = [dense.submit(p.copy(), max_new_tokens=5) for p in ps]
    dense.run_until_drained()
    paged = make_engine(cfg, paged=True, page_size=8, n_pages=24)
    rp = [paged.submit(p.copy(), max_new_tokens=5) for p in ps]
    paged.run_until_drained()
    assert [r.generated for r in rd] == [r.generated for r in rp]
    assert paged.preemptions == 0        # pool was big enough
    # paged residency stayed below the pool reservation
    kv = paged.kv_bytes()
    assert 0 < kv["peak_resident"] <= kv["reserved"]


def test_paged_engine_chunked_prefill_identical():
    """Chunked prefill through the block tables == one-shot prefill."""
    cfg = tiny_cfg()
    p = prompts(cfg, 1, 40, seed=5)[0]
    outs = []
    for chunk in (64, 7):
        eng = make_engine(cfg, max_batch=2, paged=True, page_size=8,
                          n_pages=24, prefill_chunk=chunk,
                          max_prefill_tokens=chunk)
        r = eng.submit(p.copy(), max_new_tokens=6)
        eng.run_until_drained()
        outs.append(r.generated)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# capacity beyond max_len & preemption
# ---------------------------------------------------------------------------


def test_paged_capacity_is_pool_not_max_len():
    """A request with prompt_len + max_new_tokens > max_len completes under
    the paged engine when the pool covers it."""
    cfg = tiny_cfg()
    eng = make_engine(cfg, max_batch=2, max_len=48, paged=True,
                      page_size=8, n_pages=12)     # 96-token pool
    long_req = eng.submit(prompts(cfg, 1, 40, seed=5)[0], max_new_tokens=30)
    assert 40 + 30 > 48                  # would not even submit densely
    eng.run_until_drained()
    assert long_req.state == RequestState.DONE
    assert len(long_req.generated) == 30


def test_dense_engine_rejects_what_paged_accepts():
    cfg = tiny_cfg()
    dense = make_engine(cfg, max_len=32)
    with pytest.raises(ValueError):
        dense.submit(prompts(cfg, 1, 40, seed=5)[0])
    paged = make_engine(cfg, max_len=32, paged=True, page_size=8, n_pages=12)
    paged.submit(prompts(cfg, 1, 40, seed=5)[0])   # fits the 96-token pool
    with pytest.raises(ValueError):
        paged.submit(prompts(cfg, 1, 96, seed=5)[0])   # pool-bounded still


def test_pool_exhaustion_preempts_and_preempted_request_finishes():
    """Forced exhaustion: 3 requests of 26 total tokens vs a 48-token pool.
    The youngest is evicted mid-decode (pages released, WAITING), resumes
    by recompute, and every request finishes with the tokens it would have
    produced running alone (greedy recompute identity)."""
    cfg = tiny_cfg()
    solo = []
    for p in prompts(cfg, 3, 14, seed=7):
        eng = make_engine(cfg, max_batch=1, paged=True, page_size=8,
                          n_pages=6)
        r = eng.submit(p.copy(), max_new_tokens=12)
        eng.run_until_drained()
        solo.append(r.generated)
    eng = make_engine(cfg, max_batch=3, paged=True, page_size=8, n_pages=6)
    rs = [eng.submit(p.copy(), max_new_tokens=12)
          for p in prompts(cfg, 3, 14, seed=7)]
    done = eng.run_until_drained()
    assert len(done) == 3
    assert eng.preemptions > 0
    assert max(r.n_preempted for r in rs) > 0
    assert all(r.state == RequestState.DONE for r in rs)
    assert [r.generated for r in rs] == solo
    # preempted pages really went back: pool fully free at drain
    assert eng.pool.free_pages() == 6
    assert sum(t.preemptions for t in eng.tick_log) == eng.preemptions


def test_prefill_stall_breaks_via_preemption():
    """Regression: two mid-prefill requests holding every page between
    them (no decoder running) used to spin forever — the stall breaker
    must evict the youngest holder and still drain everything with
    dense-identical tokens."""
    cfg = tiny_cfg()
    ps = prompts(cfg, 6, 48, seed=7)
    dense = make_engine(cfg, max_batch=4, max_len=64)
    rd = [dense.submit(p.copy(), max_new_tokens=8) for p in ps]
    dense.run_until_drained()
    # 10 pages x 8 = 80 tokens for 6 x 56-token requests: heavy contention
    paged = make_engine(cfg, max_batch=4, max_len=64, paged=True,
                        page_size=8, n_pages=10)
    rp = [paged.submit(p.copy(), max_new_tokens=8) for p in ps]
    done = paged.run_until_drained(max_ticks=500)
    assert len(done) == 6                # no deadlock
    assert paged.preemptions > 0
    assert [r.generated for r in rd] == [r.generated for r in rp]


def test_preemption_never_evicts_the_oldest():
    """The oldest admitted request must always run to completion (progress
    guarantee: no preemption livelock)."""
    cfg = tiny_cfg()
    eng = make_engine(cfg, max_batch=3, paged=True, page_size=8, n_pages=6)
    rs = [eng.submit(p, max_new_tokens=12)
          for p in prompts(cfg, 3, 14, seed=7)]
    eng.run_until_drained()
    assert rs[0].n_preempted == 0


# ---------------------------------------------------------------------------
# int8 paged pool (HALO's CiD memory format on pages)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-1b"])
def test_int8_paged_greedy_token_identity(arch):
    """GQA int8 pages (scales in a parallel page array): greedy tokens
    match the f32 pool within tolerance — int8 KV rounding may flip a
    near-tie, so we require >= 90% agreement and identical first tokens."""
    cfg = tiny_cfg(arch)
    ps = prompts(cfg, 3, 12, seed=11)
    outs = {}
    for dt in ("f32", "int8"):
        eng = make_engine(cfg, paged=True, page_size=8, n_pages=24,
                          kv_dtype=dt)
        rs = [eng.submit(p.copy(), max_new_tokens=6) for p in ps]
        eng.run_until_drained()
        outs[dt] = [r.generated for r in rs]
    total = sum(len(g) for g in outs["f32"])
    agree = sum(a == b for ga, gb in zip(outs["f32"], outs["int8"])
                for a, b in zip(ga, gb))
    assert agree / total >= 0.9
    # the first generated token comes straight off the f32 prefill logits:
    # it must match exactly
    assert [g[0] for g in outs["f32"]] == [g[0] for g in outs["int8"]]


def test_int8_requires_paged():
    cfg = tiny_cfg()
    with pytest.raises(ValueError):
        make_engine(cfg, kv_dtype="int8")


def test_paged_rejects_recurrent_plans():
    cfg = tiny_cfg("mamba2-2.7b")
    with pytest.raises(ValueError):
        make_engine(cfg, paged=True)
