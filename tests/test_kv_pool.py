"""Paged KV arena: pool invariants (hypothesis), paged-vs-dense token
identity, token-level admission, preemption, pool-bounded capacity, and the
int8 page format."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving.engine import RequestState, ServeConfig, ServingEngine
from repro.serving.kv_pool import KVPool, PagePool
from repro.serving.scheduler import PhaseAwareConfig, PhaseScheduler


def tiny_cfg(name="qwen3-1.7b"):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


_PARAMS = {}


def cached_params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def make_engine(cfg, max_batch=3, max_len=64, *, paged=False, page_size=8,
                n_pages=24, kv_dtype="f32", prefill_chunk=2048,
                max_prefill_tokens=8192):
    params = cached_params(cfg)
    sc = ServeConfig(max_batch=max_batch, max_len=max_len,
                     phase=PhaseAwareConfig(max_decode_batch=max_batch,
                                            prefill_chunk=prefill_chunk,
                                            max_prefill_tokens=max_prefill_tokens),
                     paged=paged, page_size=page_size, n_pages=n_pages,
                     kv_dtype=kv_dtype)
    return ServingEngine(cfg, params, sc)


def prompts(cfg, n, L, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (L,), dtype=np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# PagePool invariants (pure host logic)
# ---------------------------------------------------------------------------


def test_page_pool_basic_alloc_free():
    p = PagePool(n_pages=8, page_size=4, n_slots=2, capacity=32)
    assert p.width == 8 and p.free_pages() == 8
    assert p.grow(0, 10)                 # 3 pages
    assert p.used_pages() == 3 and int(p.lens[0]) == 10
    assert p.grow(0, 12)                 # still 3 pages (page tail)
    assert p.used_pages() == 3
    assert p.grow(1, 17)                 # 5 pages -> pool exactly full
    assert p.free_pages() == 0
    assert not p.grow(0, 13)             # needs a 4th page: refused
    assert int(p.lens[0]) == 12          # refusal left state untouched
    p.release(1)
    assert p.free_pages() == 5
    assert p.grow(0, 13)
    p.check_invariants()


def test_page_pool_ring_capacity_clamps():
    """A sliding-window pool never needs more than ceil(R / P) pages."""
    p = PagePool(n_pages=8, page_size=4, n_slots=1, capacity=10)  # ring R=10
    assert p.width == 3
    assert p.grow(0, 500)                # any length: ring reuses its pages
    assert p.used_pages() == 3
    p.check_invariants()


def test_kv_pool_grow_is_all_or_nothing():
    """A partial per-run success must roll back (no leaked pages)."""
    cfg = tiny_cfg("gemma3-1b")          # mixed window/full runs
    pool = KVPool(cfg, n_slots=2, n_pages=4, page_size=4)
    # capacity 16; ring runs clamp at min(window=16, 16)
    assert pool.grow(0, 12)
    free_before = [p.free_pages() for p in pool.pools]
    assert not pool.grow(1, 16)          # full runs out of pages
    assert [p.free_pages() for p in pool.pools] == free_before
    for p in pool.pools:
        p.check_invariants()


def test_kv_pool_accounting():
    cfg = tiny_cfg()
    pool = KVPool(cfg, n_slots=2, n_pages=8, page_size=4)
    assert pool.resident_bytes() == 0
    assert pool.grow(0, 9)
    r1 = pool.resident_bytes()
    assert r1 == 3 * pool.page_bytes(0)
    assert 0 < r1 < pool.total_bytes()
    assert pool.utilization() == pytest.approx(3 / 8)
    pool.release(0)
    assert pool.resident_bytes() == 0 and pool.free_pages() == 8


# ---------------------------------------------------------------------------
# refcounts, sharing, copy-on-write
# ---------------------------------------------------------------------------


def test_attach_shares_pages_and_refcounts():
    p = PagePool(n_pages=8, page_size=4, n_slots=3, capacity=32)
    assert p.grow(0, 8)                  # 2 pages
    pages = [int(x) for x in p.table[0, :2]]
    p.attach(1, pages, 8)                # no allocation, refcount++
    assert p.used_pages() == 2           # still 2 distinct pages
    assert all(p.is_shared(q) for q in pages)
    p.check_invariants()
    # releasing one sharer keeps the pages live for the other
    p.release(0)
    assert p.used_pages() == 2 and not any(p.is_shared(q) for q in pages)
    p.release(1)
    assert p.free_pages() == 8
    p.check_invariants()


def test_attach_validates():
    p = PagePool(n_pages=8, page_size=4, n_slots=2, capacity=32)
    assert p.grow(0, 8)
    pages = [int(x) for x in p.table[0, :2]]
    with pytest.raises(ValueError):      # wrong page count for the length
        p.attach(1, pages[:1], 8)
    with pytest.raises(ValueError):      # dead page
        p.attach(1, [7, 7], 8)
    assert p.grow(1, 2)
    with pytest.raises(ValueError):      # slot not empty
        p.attach(1, pages, 8)


def test_cow_moves_writer_never_frees_shared():
    p = PagePool(n_pages=8, page_size=4, n_slots=2, capacity=32)
    assert p.grow(0, 6)
    pages = [int(x) for x in p.table[0, :2]]
    p.attach(1, pages, 6)                # tail page shared mid-fill
    moved = p.cow(1, 1)                  # writer 1 extends the tail
    assert moved is not None
    old, new = moved
    assert old == pages[1] and new != old
    assert int(p.table[1, 1]) == new and int(p.table[0, 1]) == old
    assert not p.is_shared(old) and not p.is_shared(new)   # aliasing gone
    assert p.cow(1, 1) is None           # second write: already exclusive
    p.check_invariants()


def test_cow_requires_free_page():
    p = PagePool(n_pages=2, page_size=4, n_slots=2, capacity=8)
    assert p.grow(0, 8)                  # pool full
    pages = [int(x) for x in p.table[0, :2]]
    p.attach(1, pages, 8)                # shared, and no free copy target
    with pytest.raises(IndexError):
        p.cow(1, 0)
    p.check_invariants()


def test_external_refs_keep_pages_past_release():
    """A cache-retained page survives its publisher's release and frees
    only when the external ref drops too (no free-while-referenced)."""
    p = PagePool(n_pages=4, page_size=4, n_slots=1, capacity=16)
    assert p.grow(0, 8)
    pages = [int(x) for x in p.table[0, :2]]
    for q in pages:
        p.retain(q)
    p.release(0)
    assert p.free_pages() == 2           # retained pages did NOT free
    p.check_invariants()
    for q in pages:
        p.release_ref(q)
    assert p.free_pages() == 4
    p.check_invariants()
    with pytest.raises(ValueError):
        p.release_ref(pages[0])          # no external ref left


def test_kv_pool_ensure_writable_is_atomic():
    """If any run lacks COW copy targets, ensure_writable mutates NOTHING
    (the caller retries after evicting)."""
    cfg = tiny_cfg()
    pool = KVPool(cfg, n_slots=2, n_pages=4, page_size=4)
    assert pool.grow(0, 16)              # pool full
    pool.attach(1, pool.prefix_pages(0, 16), 16)   # all shared, none free
    before = [p.table.copy() for p in pool.pools]
    assert pool.ensure_writable(1, 0, 4) is None
    for p, t in zip(pool.pools, before):
        assert (p.table == t).all()
        p.check_invariants()


try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(
        n_pages=st.integers(2, 16),
        page_size=st.integers(1, 8),
        ops=st.lists(
            st.tuples(st.integers(0, 5),       # 0 grow, 1 release, 2 shrink,
                                               # 3 attach, 4 retain+release_ref,
                                               # 5 cow
                      st.integers(0, 3),       # slot
                      st.integers(0, 40)),     # length delta / target / row
            max_size=60),
    )
    def test_page_pool_interleavings_conserve_pages(n_pages, page_size, ops):
        """ANY interleaving of grow/release/shrink/attach/retain/cow
        (alloc, retire, preempt, prefix share, cache pin, write fault)
        conserves refcounts, never frees a referenced page, and never
        leaves a COW'd writer aliasing a shared page."""
        pool = PagePool(n_pages, page_size, n_slots=4,
                        capacity=n_pages * page_size)
        external: list = []              # pages we hold cache refs on
        for kind, slot, arg in ops:
            if kind == 0:
                pool.grow(slot, int(pool.lens[slot]) + arg)
            elif kind == 1:
                pool.release(slot)
            elif kind == 2:
                pool.shrink(slot, min(int(pool.lens[slot]), arg))
            elif kind == 3:
                src = arg % pool.n_slots
                n = int((pool.table[src] < pool.n_pages).sum())
                if src != slot and int(pool.lens[slot]) == 0 and n:
                    take_tok = min(int(pool.lens[src]), n * page_size)
                    take = pool.pages_of(take_tok)
                    pool.attach(slot, [int(q) for q in pool.table[src, :take]],
                                take_tok)
            elif kind == 4:
                if external and arg % 2:
                    pool.release_ref(external.pop())
                else:
                    live = np.nonzero(pool.ref > 0)[0]
                    if len(live):
                        q = int(live[arg % len(live)])
                        pool.retain(q)
                        external.append(q)
            else:
                rows = np.nonzero(pool.table[slot] < pool.n_pages)[0]
                if len(rows):
                    row = int(rows[arg % len(rows)])
                    try:
                        moved = pool.cow(slot, row)
                    except IndexError:   # no copy target: state unchanged
                        moved = None
                    if moved is not None:
                        old, new = moved
                        # the writer aliases nobody and owns the new page
                        assert int(pool.table[slot, row]) == new
                        assert pool.ref[new] == 1
            pool.check_invariants()
        assert pool.used_pages() + pool.free_pages() == n_pages

    @settings(max_examples=80, deadline=None)
    @given(
        n_pages=st.integers(1, 24),
        page_size=st.integers(1, 8),
        window=st.integers(0, 64),       # 0: full attention
        budget=st.integers(1, 256),
        chunk=st.integers(1, 128),
        waiting=st.lists(st.tuples(st.integers(0, 200),   # remaining
                                   st.integers(0, 200)),  # cur_len
                         min_size=1, max_size=4),
        decoding=st.integers(0, 3),
    )
    def test_plan_tick_never_exceeds_budget_or_pool(n_pages, page_size,
                                                    window, budget, chunk,
                                                    waiting, decoding):
        """Under ARBITRARY (waiting, decoding, pool, window) the planned
        chunks respect the token budget AND every chunk's pages can
        actually be granted by a real PagePool seeded with the same
        state — the scheduler's ring-clamped charge (pages_for) and the
        pool's are the same rule."""
        from repro.serving.scheduler import pages_for
        capacity = min(window, n_pages * page_size) if window > 0 \
            else n_pages * page_size
        pool = PagePool(n_pages, page_size, n_slots=len(waiting),
                        capacity=capacity)
        entries = []
        for i, (remaining, cur_len) in enumerate(waiting):
            if not pool.grow(i, cur_len):
                return                   # seed state not realizable
            entries.append((i, remaining, True, cur_len))
        s = PhaseScheduler(PhaseAwareConfig(
            "halo", max_decode_batch=4, max_prefill_tokens=budget,
            prefill_chunk=chunk))
        plan = s.plan_tick(entries, list(range(decoding)),
                           free_pages=pool.free_pages(),
                           page_size=page_size, capacity=capacity)
        assert plan.prefill_tokens <= budget
        by_id = {e[0]: e for e in entries}
        for rid, take in plan.prefill_chunks:
            _, remaining, _, cur_len = by_id[rid]
            assert 0 < take <= remaining
            assert take <= chunk
            # the real pool grants EVERY planned chunk, in plan order
            assert pool.grow(rid, cur_len + take), (
                f"planned chunk ({rid}, {take}) exceeds what the pool "
                f"can grant (cur_len={cur_len}, "
                f"free={pool.free_pages()})")
            pool.check_invariants()
        # cross-check the scheduler's page arithmetic directly
        for rid, take in plan.prefill_chunks:
            cur = by_id[rid][3]
            assert (pages_for(cur + take, page_size, capacity)
                    - pages_for(cur, page_size, capacity)) >= 0


# ---------------------------------------------------------------------------
# scheduler: token-level (page-aware) admission
# ---------------------------------------------------------------------------


def test_scheduler_admits_only_what_free_pages_cover():
    s = PhaseScheduler(PhaseAwareConfig(
        "halo", max_decode_batch=4, max_prefill_tokens=1000,
        prefill_chunk=600))
    # 2 free pages of 8 tokens; request 10 already holds 4 tokens of a page
    plan = s.plan_tick(waiting=[(10, 600, True, 4), (11, 600, True, 0)],
                       decoding=[], free_pages=2, page_size=8)
    # req 10: page tail (4) + 2 fresh pages = 20 coverable tokens
    assert plan.prefill_chunks == [(10, 20)]
    # no pages at all: nothing admitted even though the token budget is open
    plan = s.plan_tick(waiting=[(11, 600, True, 0)], decoding=[],
                       free_pages=0, page_size=8)
    assert plan.prefill_chunks == []
    # page-tail tokens are admitted without consuming a page
    plan = s.plan_tick(waiting=[(12, 3, True, 5)], decoding=[],
                       free_pages=0, page_size=8)
    assert plan.prefill_chunks == [(12, 3)]


def test_scheduler_page_accounting_across_requests():
    """Pages consumed by an earlier chunk shrink what later ones may take
    (two fresh requests cannot share one free page)."""
    s = PhaseScheduler(PhaseAwareConfig(
        "halo", max_decode_batch=4, max_prefill_tokens=1000,
        prefill_chunk=600))
    plan = s.plan_tick(waiting=[(1, 5, True, 0), (2, 5, True, 0)],
                       decoding=[], free_pages=1, page_size=8)
    assert plan.prefill_chunks == [(1, 5)]   # req 2 has no page left


def test_scheduler_ring_clamp_matches_pool():
    """Regression (sliding-window admission): a request whose arena length
    exceeds the ring span holds ceil(R / P) pages FOREVER — growth costs
    zero fresh pages.  The unclamped ``ceil(cur_len / page_size)`` charge
    used to diverge from ``PagePool.pages_of``'s ring clamp and refuse
    (or page-charge) work the pool grants for free."""
    s = PhaseScheduler(PhaseAwareConfig(
        "halo", max_decode_batch=4, max_prefill_tokens=1000,
        prefill_chunk=600))
    # ring R = 16, P = 8: a slot at cur_len 40 has long since wrapped
    plan = s.plan_tick(waiting=[(1, 100, True, 40)], decoding=[],
                       free_pages=0, page_size=8, capacity=16)
    assert plan.prefill_chunks == [(1, 100)]   # ring reuse: zero pages
    # the real pool agrees: grow costs nothing once wrapped
    pool = PagePool(n_pages=2, page_size=8, n_slots=1, capacity=16)
    assert pool.grow(0, 40) and pool.free_pages() == 0
    assert pool.grow(0, 140)
    pool.check_invariants()
    # unclamped (capacity omitted = legacy behavior): mis-charges 5 pages
    # and admits nothing — exactly the bug the clamp fixes
    legacy = s.plan_tick(waiting=[(1, 100, True, 40)], decoding=[],
                         free_pages=0, page_size=8)
    assert legacy.prefill_chunks == []


def test_scheduler_ring_clamp_engine_end_to_end():
    """A sliding-window config whose prompt exceeds the window serves
    through an exactly-ring-sized pool: without the clamp the planner
    starves (it charges pages the ring never needs)."""
    cfg = tiny_cfg("gemma3-1b")          # window 16
    window = cfg.attn.sliding_window
    # force an ALL-sliding-window plan so the ring is the binding run
    # (local_global_ratio=0 + sliding_window>0 -> every layer local);
    # rename: cached_params keys on cfg.name
    cfg = dataclasses.replace(
        cfg, name="gemma3-1b-all-local",
        attn=dataclasses.replace(cfg.attn, local_global_ratio=0))
    from repro.models.transformer import build_plan
    assert all(r.window > 0 for r in build_plan(cfg))
    eng = make_engine(cfg, max_batch=1, paged=True, page_size=8,
                      n_pages=window // 8, prefill_chunk=8,
                      max_prefill_tokens=8)
    r = eng.submit(prompts(cfg, 1, 3 * window, seed=3)[0],
                   max_new_tokens=4)     # prompt far beyond the ring
    eng.run_until_drained(max_ticks=200)
    assert r.state == RequestState.DONE
    assert len(r.generated) == 4


# ---------------------------------------------------------------------------
# paged-vs-dense engine identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b",       # GQA
                                  "gemma3-1b",        # sliding-window ring
                                  "deepseek-v2-236b"  # MLA latent pages
                                  ])
def test_paged_engine_token_identical_to_dense(arch):
    """Paged and dense engines produce identical greedy token streams —
    the pool + block tables + paged kernel are a pure relayout."""
    cfg = tiny_cfg(arch)
    ps = prompts(cfg, 4, 14, seed=2)
    dense = make_engine(cfg)
    rd = [dense.submit(p.copy(), max_new_tokens=5) for p in ps]
    dense.run_until_drained()
    paged = make_engine(cfg, paged=True, page_size=8, n_pages=24)
    rp = [paged.submit(p.copy(), max_new_tokens=5) for p in ps]
    paged.run_until_drained()
    assert [r.generated for r in rd] == [r.generated for r in rp]
    assert paged.preemptions == 0        # pool was big enough
    # paged residency stayed below the pool reservation
    kv = paged.kv_bytes()
    assert 0 < kv["peak_resident"] <= kv["reserved"]


def test_paged_engine_chunked_prefill_identical():
    """Chunked prefill through the block tables == one-shot prefill."""
    cfg = tiny_cfg()
    p = prompts(cfg, 1, 40, seed=5)[0]
    outs = []
    for chunk in (64, 7):
        eng = make_engine(cfg, max_batch=2, paged=True, page_size=8,
                          n_pages=24, prefill_chunk=chunk,
                          max_prefill_tokens=chunk)
        r = eng.submit(p.copy(), max_new_tokens=6)
        eng.run_until_drained()
        outs.append(r.generated)
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# capacity beyond max_len & preemption
# ---------------------------------------------------------------------------


def test_paged_capacity_is_pool_not_max_len():
    """A request with prompt_len + max_new_tokens > max_len completes under
    the paged engine when the pool covers it."""
    cfg = tiny_cfg()
    eng = make_engine(cfg, max_batch=2, max_len=48, paged=True,
                      page_size=8, n_pages=12)     # 96-token pool
    long_req = eng.submit(prompts(cfg, 1, 40, seed=5)[0], max_new_tokens=30)
    assert 40 + 30 > 48                  # would not even submit densely
    eng.run_until_drained()
    assert long_req.state == RequestState.DONE
    assert len(long_req.generated) == 30


def test_dense_engine_rejects_what_paged_accepts():
    cfg = tiny_cfg()
    dense = make_engine(cfg, max_len=32)
    with pytest.raises(ValueError):
        dense.submit(prompts(cfg, 1, 40, seed=5)[0])
    paged = make_engine(cfg, max_len=32, paged=True, page_size=8, n_pages=12)
    paged.submit(prompts(cfg, 1, 40, seed=5)[0])   # fits the 96-token pool
    with pytest.raises(ValueError):
        paged.submit(prompts(cfg, 1, 96, seed=5)[0])   # pool-bounded still


def test_pool_exhaustion_preempts_and_preempted_request_finishes():
    """Forced exhaustion: 3 requests of 26 total tokens vs a 48-token pool.
    The youngest is evicted mid-decode (pages released, WAITING), resumes
    by recompute, and every request finishes with the tokens it would have
    produced running alone (greedy recompute identity)."""
    cfg = tiny_cfg()
    solo = []
    for p in prompts(cfg, 3, 14, seed=7):
        eng = make_engine(cfg, max_batch=1, paged=True, page_size=8,
                          n_pages=6)
        r = eng.submit(p.copy(), max_new_tokens=12)
        eng.run_until_drained()
        solo.append(r.generated)
    eng = make_engine(cfg, max_batch=3, paged=True, page_size=8, n_pages=6)
    rs = [eng.submit(p.copy(), max_new_tokens=12)
          for p in prompts(cfg, 3, 14, seed=7)]
    done = eng.run_until_drained()
    assert len(done) == 3
    assert eng.preemptions > 0
    assert max(r.n_preempted for r in rs) > 0
    assert all(r.state == RequestState.DONE for r in rs)
    assert [r.generated for r in rs] == solo
    # preempted pages really went back: pool fully free at drain
    assert eng.pool.free_pages() == 6
    assert sum(t.preemptions for t in eng.tick_log) == eng.preemptions


def test_prefill_stall_breaks_via_preemption():
    """Regression: two mid-prefill requests holding every page between
    them (no decoder running) used to spin forever — the stall breaker
    must evict the youngest holder and still drain everything with
    dense-identical tokens."""
    cfg = tiny_cfg()
    ps = prompts(cfg, 6, 48, seed=7)
    dense = make_engine(cfg, max_batch=4, max_len=64)
    rd = [dense.submit(p.copy(), max_new_tokens=8) for p in ps]
    dense.run_until_drained()
    # 10 pages x 8 = 80 tokens for 6 x 56-token requests: heavy contention
    paged = make_engine(cfg, max_batch=4, max_len=64, paged=True,
                        page_size=8, n_pages=10)
    rp = [paged.submit(p.copy(), max_new_tokens=8) for p in ps]
    done = paged.run_until_drained(max_ticks=500)
    assert len(done) == 6                # no deadlock
    assert paged.preemptions > 0
    assert [r.generated for r in rd] == [r.generated for r in rp]


def test_preemption_never_evicts_the_oldest():
    """The oldest admitted request must always run to completion (progress
    guarantee: no preemption livelock)."""
    cfg = tiny_cfg()
    eng = make_engine(cfg, max_batch=3, paged=True, page_size=8, n_pages=6)
    rs = [eng.submit(p, max_new_tokens=12)
          for p in prompts(cfg, 3, 14, seed=7)]
    eng.run_until_drained()
    assert rs[0].n_preempted == 0


# ---------------------------------------------------------------------------
# int8 paged pool (HALO's CiD memory format on pages)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-1b"])
def test_int8_paged_greedy_token_identity(arch):
    """GQA int8 pages (scales in a parallel page array): greedy tokens
    match the f32 pool within tolerance — int8 KV rounding may flip a
    near-tie, so we require >= 90% agreement and identical first tokens."""
    cfg = tiny_cfg(arch)
    ps = prompts(cfg, 3, 12, seed=11)
    outs = {}
    for dt in ("f32", "int8"):
        eng = make_engine(cfg, paged=True, page_size=8, n_pages=24,
                          kv_dtype=dt)
        rs = [eng.submit(p.copy(), max_new_tokens=6) for p in ps]
        eng.run_until_drained()
        outs[dt] = [r.generated for r in rs]
    total = sum(len(g) for g in outs["f32"])
    agree = sum(a == b for ga, gb in zip(outs["f32"], outs["int8"])
                for a, b in zip(ga, gb))
    assert agree / total >= 0.9
    # the first generated token comes straight off the f32 prefill logits:
    # it must match exactly
    assert [g[0] for g in outs["f32"]] == [g[0] for g in outs["int8"]]


def test_int8_requires_paged():
    cfg = tiny_cfg()
    with pytest.raises(ValueError):
        make_engine(cfg, kv_dtype="int8")


def test_paged_rejects_recurrent_plans():
    cfg = tiny_cfg("mamba2-2.7b")
    with pytest.raises(ValueError):
        make_engine(cfg, paged=True)
