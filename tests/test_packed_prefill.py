"""Packed multi-request prefill: the flat-stream chunk path vs the padded
[N, C] batch — greedy identity across architectures and engine modes,
prefix-skip on partial chunks, pack-plan token conservation, the Pallas
kernel vs its pure-JAX reference, and the bucket ladder's zero-recompile
guarantee on repeated traffic."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import (
    forward_chunk,
    forward_chunk_packed,
    init_cache,
    init_params,
)
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.kv_pool import KVPool
from repro.serving.scheduler import (
    PackedPrefill,
    PhaseAwareConfig,
    align_up,
    bucket_pow2,
    bucket_tokens,
    pack_chunks,
)


def tiny_cfg(name="qwen3-1.7b"):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


# -- pack plans -------------------------------------------------------------------

def test_pack_chunks_layout():
    pk = pack_chunks([(3, 5), (7, 8), (9, 2)], align=4)
    assert isinstance(pk, PackedPrefill)
    assert pk.req_ids == (3, 7, 9)
    assert pk.takes == (5, 8, 2)
    assert pk.starts == (0, 8, 16)           # 5 -> 8, 8 -> 16 (aligned)
    assert pk.total_tokens == 15
    # packed end 18 aligns to 20, then rounds up the half-octave ladder
    # (..., 16, 24, 32, ...) rather than all the way to the next pow2
    assert pk.length == 24
    assert pk.padded_tokens == 24 - 15
    assert bucket_pow2(20) == 32 and bucket_tokens(20, 4) == 24


def test_pack_chunks_conserves_tokens():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(
        takes=st.lists(st.integers(min_value=0, max_value=700),
                       min_size=0, max_size=12),
        align=st.sampled_from([1, 2, 4, 8, 16, 128]))
    @hyp.settings(deadline=None, max_examples=200)
    def check(takes, align):
        pk = pack_chunks(list(enumerate(takes)), align=align)
        live = [(i, t) for i, t in enumerate(takes) if t > 0]
        # every planned token survives packing, none are invented
        assert pk.total_tokens == sum(t for _, t in live)
        assert pk.takes == tuple(t for _, t in live)
        assert pk.req_ids == tuple(i for i, _ in live)
        # segments are disjoint, ordered, and tile-aligned
        for j, (s, t) in enumerate(zip(pk.starts, pk.takes)):
            assert s % align == 0
            if j + 1 < len(pk.starts):
                assert s + t <= pk.starts[j + 1]
        # the stream bounds every segment and buckets to the pow2 ladder
        if pk.takes:
            assert pk.starts[-1] + pk.takes[-1] <= pk.length
            end = align_up(pk.starts[-1] + pk.takes[-1], align)
            assert pk.length == max(bucket_tokens(end, align), align)
        assert pk.padded_tokens == pk.length - pk.total_tokens

    check()


# -- model-level identity ---------------------------------------------------------

def _run_ticks_padded(cfg, params, prompts, slots, ticks, cache, pool):
    outs = {}
    for tick in ticks:
        C = max(t for _, _, t in tick)
        N = len(tick)
        toks = np.zeros((N, C), np.int32)
        offs = np.zeros((N,), np.int32)
        lens = np.zeros((N,), np.int32)
        slts = np.full((N,), 4, np.int32)
        for i, (ri, off, take) in enumerate(tick):
            toks[i, :take] = prompts[ri][off:off + take]
            offs[i], lens[i], slts[i] = off, take, slots[ri]
        kw = {"block_tables": pool.block_tables()} if pool else {}
        lg, cache = forward_chunk(params, cfg, toks, offs, lens, slts,
                                  cache, **kw)
        if pool:
            pool.caches = cache
        for i, (ri, off, take) in enumerate(tick):
            outs[(ri, off)] = np.asarray(lg[i, 0])
    return outs, cache


def _run_ticks_packed(cfg, params, prompts, slots, ticks, cache, pool,
                      align):
    outs = {}
    for tick in ticks:
        pk = pack_chunks([(ri, take) for ri, _, take in tick], align=align)
        T, N = pk.length, len(tick)
        toks = np.zeros((T,), np.int32)
        starts = np.full((N,), T, np.int32)
        offs = np.zeros((N,), np.int32)
        lens = np.zeros((N,), np.int32)
        slts = np.full((N,), 4, np.int32)
        for i, (ri, off, take) in enumerate(tick):
            s = pk.starts[i]
            toks[s:s + take] = prompts[ri][off:off + take]
            starts[i], offs[i], lens[i], slts[i] = s, off, take, slots[ri]
        kw = {"block_tables": pool.block_tables()} if pool else {}
        lg, cache = forward_chunk_packed(params, cfg, toks, starts, offs,
                                         lens, slts, cache,
                                         pack_align=align, **kw)
        if pool:
            pool.caches = cache
        for i, (ri, off, take) in enumerate(tick):
            outs[(ri, off)] = np.asarray(lg[i, 0])
    return outs, cache


# llama2-7b / qwen3-8b are the paper's two models (MHA / GQA); gemma3-1b
# adds the sliding-window ring, deepseek-v2-236b the MLA latent cache
@pytest.mark.parametrize("name", ["llama2-7b", "qwen3-8b", "gemma3-1b",
                                  "deepseek-v2-236b"])
@pytest.mark.parametrize("paged", [False, True])
def test_packed_matches_padded_chunks(name, paged):
    """Two mixed-length chunk ticks: packed logits pick the same greedy
    token as the padded batch for every chunk, and the KV written to the
    arena matches."""
    cfg = tiny_cfg(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (13, 7)]
    slots = [0, 2]
    ticks = [[(0, 0, 8), (1, 0, 7)], [(0, 8, 5)]]

    def fresh():
        if paged:
            pool = KVPool(cfg, n_slots=4, page_size=8, n_pages=32)
            for i, p in enumerate(prompts):
                assert pool.grow(slots[i], len(p))
            return pool.caches, pool
        return init_cache(cfg, 4, 48), None

    cache, pool = fresh()
    ref, ref_cache = _run_ticks_padded(cfg, params, prompts, slots, ticks,
                                       cache, pool)
    cache, pool = fresh()
    got, got_cache = _run_ticks_packed(cfg, params, prompts, slots, ticks,
                                       cache, pool, align=8)
    for k in ref:
        assert np.argmax(ref[k]) == np.argmax(got[k]), k
        np.testing.assert_allclose(got[k], ref[k], atol=1e-5, rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(ref_cache),
                    jax.tree_util.tree_leaves(got_cache)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=1e-5, rtol=1e-5)


# -- engine-level identity --------------------------------------------------------

def _engine(cfg, params, packed, **kw):
    sc = ServeConfig(max_batch=4, max_len=128,
                     phase=PhaseAwareConfig(prefill_chunk=8, pack_align=8),
                     page_size=8, n_pages=96, packed_prefill=packed, **kw)
    return ServingEngine(cfg, params, sc)


@pytest.mark.parametrize("mode", ["dense", "paged", "prefix", "spec"])
def test_engine_packed_identity(mode):
    """Greedy token streams are identical with packed prefill on or off,
    in every engine mode the padded path serves."""
    from repro.serving.speculative import SpecConfig

    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    kw = {"dense": {},
          "paged": {"paged": True},
          "prefix": {"paged": True, "prefix_cache": True},
          "spec": {"paged": True, "speculative": SpecConfig(k=3)}}[mode]
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (13, 29, 7, 22)]
    streams = {}
    for packed in (False, True):
        eng = _engine(cfg, params, packed, **kw)
        # guard the gate itself: with packed_prefill=True on a chunkable
        # single-codebook model the flat-stream path MUST engage — a
        # silently-off gate would make this test vacuously green
        assert eng._packed is packed
        for p in prompts:
            eng.submit(p, max_new_tokens=5)
        done = eng.run_until_drained(max_ticks=400)
        assert len(done) == len(prompts)
        streams[packed] = {r.req_id: list(r.generated) for r in done}
        assert eng.prefill_launches > 0
    assert streams[False] == streams[True]


def test_partial_chunk_prefix_skip():
    """A cached prefix ending mid-chunk: the resumed request's packed
    stream starts exactly at the first uncached token — the skipped
    tokens never enter the stream (prefill_tokens_executed counts only
    the remainder) and the continuation is greedy-identical."""
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    head = rng.integers(0, cfg.vocab_size, size=20).astype(np.int32)
    tail = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    long = np.concatenate([head, tail])

    # reference stream for the long prompt, no cache
    ref = _engine(cfg, params, True, paged=True)
    r0 = ref.submit(long, max_new_tokens=4)
    ref.run_until_drained(max_ticks=200)

    eng = _engine(cfg, params, True, paged=True, prefix_cache=True)
    eng.submit(head, max_new_tokens=2)
    eng.run_until_drained(max_ticks=200)
    before = eng.prefill_tokens_executed
    r1 = eng.submit(long, max_new_tokens=4)
    eng.run_until_drained(max_ticks=200)
    executed = eng.prefill_tokens_executed - before
    # the 20-token head published 2 FULL pages (page_size 8, 16 tokens);
    # the resume enters the packed stream at token 16 and prefills only
    # the 9 uncached tokens — one full chunk plus a 1-token partial
    assert executed == len(long) - 16
    assert r1.generated == r0.generated


def test_compile_counter_stability():
    """Second pass of the same mixed-length traffic compiles nothing new:
    the pow2 ladder over pack lengths and decode batches closes the
    compiled-shape set after one wave (tick_log carries the per-tick
    delta)."""
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = _engine(cfg, params, True, paged=True)
    assert eng._packed
    rng = np.random.default_rng(3)
    lens = (13, 29, 7, 22, 40, 3)
    for wave in range(2):
        for n in lens:
            eng.submit(rng.integers(0, cfg.vocab_size, size=n)
                       .astype(np.int32), max_new_tokens=4)
        eng.run_until_drained(max_ticks=400)
        if wave == 0:
            first = eng.compile_count
            assert first > 0
    assert eng.compile_count == first, "second wave recompiled"
    assert sum(t.new_compiles for t in eng.tick_log) == first


# -- kernel vs reference ----------------------------------------------------------

def test_packed_kernel_matches_reference():
    """The Pallas packed-prefill kernel (interpret mode) reproduces the
    pure-JAX packed reference on a multi-segment stream with arena
    history, a wrapped SWA ring, and an all-sentinel pad segment."""
    from repro.kernels.flash_attention import packed_prefill_attention
    from repro.models.attention import _packed_attention_jax, \
        make_packed_segs

    rng = np.random.default_rng(1)
    Hkv, G, D, P, W, n_pages = 2, 2, 16, 8, 4, 16
    ring, window, bq = 16, 16, 8
    H = Hkv * G
    segs = [(6, 21, [2, 3, 4, 5]), (11, 18, [7, 8, 9, 10]), (0, 0, [])]
    takes = [t for t, _, _ in segs]
    starts, cur = [], 0
    for t in takes:
        starts.append(cur)
        cur = align_up(cur + t, bq)
    T = max(cur, bq)
    offs = np.array([o for _, o, _ in segs], np.int32)
    lens = np.array(takes, np.int32)
    starts = np.array(starts, np.int32)
    starts[-1] = T                              # pad segment: empty tail
    bt = np.full((len(segs), W), n_pages, np.int32)
    for i, (_, _, pgs) in enumerate(segs):
        bt[i, :len(pgs)] = pgs
    q = rng.standard_normal((T, H, D)).astype(np.float32)
    kn = rng.standard_normal((T, Hkv, D)).astype(np.float32)
    vn = rng.standard_normal((T, Hkv, D)).astype(np.float32)
    kp = rng.standard_normal((n_pages, P, Hkv, D)).astype(np.float32)
    vp = rng.standard_normal((n_pages, P, Hkv, D)).astype(np.float32)

    out = packed_prefill_attention(
        jnp.asarray(q), jnp.asarray(kn), jnp.asarray(vn), jnp.asarray(kp),
        jnp.asarray(vp), jnp.asarray(bt), jnp.asarray(starts),
        jnp.asarray(offs), jnp.asarray(lens), ring=ring, window=window,
        bq=bq, interpret=True)

    seg = make_packed_segs(starts, offs, lens,
                           np.arange(len(segs), dtype=np.int32), T)
    S = W * P
    pages = np.clip(bt, 0, n_pages - 1)
    prev_k = jnp.asarray(kp)[pages].reshape(len(segs), S, Hkv, D)
    prev_v = jnp.asarray(vp)[pages].reshape(len(segs), S, Hkv, D)
    s_idx = np.arange(S, dtype=np.int32)
    prev_pos = offs[:, None] - 1 - ((offs[:, None] - 1 - s_idx) % ring)
    prev_pos = np.where(s_idx[None, :] < ring, prev_pos, -1)
    prev_pos = np.where(np.repeat(bt >= n_pages, P, axis=1), -1, prev_pos)
    ref = _packed_attention_jax(
        jnp.asarray(q), jnp.asarray(kn), jnp.asarray(vn), prev_k, prev_v,
        jnp.asarray(prev_pos), seg, n_heads=H, n_kv_heads=Hkv, d_head=D,
        window=jnp.int32(window), softcap=0.0).reshape(T, H, D)

    valid = np.asarray(seg.valid)
    np.testing.assert_allclose(np.asarray(out)[valid],
                               np.asarray(ref)[valid], atol=2e-5)
