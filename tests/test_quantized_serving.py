"""Quantized serving (HALO IV-A / V-A: int8 end to end on the decode
datapath): per-channel int8 weights through the fused dequantizing GEMV,
int8 KV / MLA-latent pages, and packed-int4 GQA pages, composed with the
paged arena, prefix-cache COW, speculative rollback, and packed prefill.

Two kinds of contract are asserted here:

  * TOLERANCE vs the f32 reference — quantization changes the math, so
    quantized greedy streams track f32 rather than reproduce it: first
    tokens must match, later positions may flip on random-init near-ties
    (logit margins ~1e-4 against a ~1 spread); agreement is bounded.
  * BIT-IDENTITY within a quantized config — paged / prefix-cache /
    packed-prefill layouts execute the same quantized arithmetic, so
    their greedy streams must be byte-equal.  The speculative verify
    program is chunk-shaped (different fp summation order at ~1e-6),
    which flips random-init near-ties on some seeds even at f32; the
    seeds here are pinned to workloads where identity holds, the same
    discipline the PR 2-6 serving tests use.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.layers import gemv_route_count, reset_gemv_route_count
from repro.models.transformer import init_params
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.kv_pool import KVPool
from repro.serving.quantized_cache import (
    dequantize,
    pack_int4,
    quantize_token_int4,
    unpack_int4,
)
from repro.serving.quantized_weights import quantize_params, quantize_weight
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import PhaseAwareConfig
from repro.serving.speculative import SpecConfig

_PARAMS = {}


def cached(arch):
    if arch not in _PARAMS:
        cfg = dataclasses.replace(get_config(arch).reduced(),
                                  dtype="float32")
        _PARAMS[arch] = (cfg, init_params(jax.random.PRNGKey(0), cfg))
    return _PARAMS[arch]


def run_engine(cfg, params, seed=0, *, max_new=10, lens=(12, 9, 15), **kw):
    sc = ServeConfig(max_batch=3, max_len=64,
                     phase=PhaseAwareConfig(max_decode_batch=3,
                                            prefill_chunk=16,
                                            max_prefill_tokens=256), **kw)
    eng = ServingEngine(cfg, params, sc)
    rng = np.random.default_rng(seed)
    ps = [rng.integers(0, cfg.vocab_size, (L,)).tolist() for L in lens]
    reqs = eng.generate(ps, SamplingParams(max_new_tokens=max_new))
    return eng, [r.generated for r in reqs]


def agreement(a, b):
    hits = sum(x == y for o, p in zip(a, b) for x, y in zip(o, p))
    return hits / max(sum(len(o) for o in b), 1)


# ---------------------------------------------------------------------------
# quantizer units
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scale", [1e-3, 1.0, 1e3])
def test_quantize_weight_roundtrip_bound(scale):
    """Per-output-channel int8: |w - dq(w)| <= scale_n / 2 everywhere.
    (The hypothesis-driven sweep lives in test_quantized.py.)"""
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 33)) * scale
    q = quantize_weight(w)
    assert q["q"].dtype == jnp.int8 and q["scale"].shape == (33,)
    back = np.asarray(q["q"], np.float32) * np.asarray(q["scale"])[None, :]
    err = np.abs(np.asarray(w) - back)
    bound = np.asarray(q["scale"])[None, :] * 0.5 + 1e-9
    assert (err <= bound * 1.01).all()


def test_quantize_params_leaves_and_moe():
    """Only matmul leaves above min_size quantize; MoE expert banks (raw
    einsum consumers) and norms/embeddings stay dense."""
    big = jnp.ones((64, 64), jnp.float32)
    tree = {"layers": {"wq": big, "moe": {"wi_gate": big},
                       "ln": jnp.ones((64,))},
            "embed": big}
    out = quantize_params(tree, min_size=0)
    assert set(out["layers"]["wq"].keys()) == {"q", "scale"}
    assert isinstance(out["layers"]["moe"]["wi_gate"], jnp.ndarray)
    assert isinstance(out["layers"]["ln"], jnp.ndarray)
    assert isinstance(out["embed"], jnp.ndarray)
    # min_size gate: the same leaf stays dense below the floor
    kept = quantize_params(tree, min_size=big.size * 4 + 1)
    assert isinstance(kept["layers"]["wq"], jnp.ndarray)


def test_int4_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    q = rng.integers(-8, 8, (3, 5, 16), dtype=np.int8)
    packed = pack_int4(jnp.asarray(q))
    assert packed.dtype == jnp.uint8 and packed.shape == (3, 5, 8)
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), q)
    with pytest.raises(AssertionError):
        pack_int4(jnp.zeros((2, 7), jnp.int8))


@pytest.mark.parametrize("scale", [1e-3, 1.0, 1e3])
def test_quantize_token_int4_roundtrip_bound(scale):
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 64)) * scale
    q, s = quantize_token_int4(x)
    assert int(jnp.max(jnp.abs(q))) <= 7
    y = dequantize(unpack_int4(pack_int4(q)), s)
    err = np.abs(np.asarray(x) - np.asarray(y))
    bound = np.asarray(s)[..., None] * 0.5 + 1e-9
    assert (err <= bound * 1.01).all()


def test_int4_pool_page_bytes():
    """int8 pages halve and packed int4 pages quarter the f32 KV bytes
    (scale pages included in both)."""
    cfg, _ = cached("llama2-7b")
    sizes = {}
    for kdt in ("f32", "int8", "int4"):
        pool = KVPool(cfg, n_slots=2, n_pages=16, page_size=8,
                      kv_dtype=kdt)
        sizes[kdt] = sum(leaf.nbytes for c in pool.caches
                         for leaf in (c.values() if isinstance(c, dict)
                                      else [c]))
        if kdt == "int4":
            assert any(leaf.dtype == jnp.uint8 for c in pool.caches
                       for leaf in c.values())
    assert sizes["int8"] <= sizes["f32"] / 2
    assert sizes["int4"] <= sizes["f32"] / 4


# ---------------------------------------------------------------------------
# engine: quantized weights / KV vs the f32 reference (tolerance)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama2-7b", "qwen3-8b",
                                  "h2o-danube-1.8b", "deepseek-v2-236b"])
def test_weights_int8_stream_tolerance(arch):
    """int8 weights (GQA both paper models, SWA, MLA): decode must route
    through the fused GEMV, first greedy tokens must match f32, and the
    streams stay in bounded agreement (seeds pinned per the module
    docstring: first tokens are near-tie-dependent on random init)."""
    cfg, params = cached(arch)
    seed = 2 if arch == "h2o-danube-1.8b" else 0
    reset_gemv_route_count()
    _, base = run_engine(cfg, params, seed)
    assert gemv_route_count() == 0, "f32 weights took the GEMV route"
    reset_gemv_route_count()
    _, got = run_engine(cfg, params, seed, weights_dtype="int8")
    assert gemv_route_count() > 0, \
        "int8 decode never hit the fused quantized GEMV"
    assert all(o[0] == p[0] for o, p in zip(got, base)), \
        f"{arch}: first greedy token diverged under int8 weights"
    assert agreement(got, base) >= 0.5, \
        f"{arch}: agreement {agreement(got, base)} < 0.5"


@pytest.mark.parametrize("arch,kdt", [("llama2-7b", "int8"),
                                      ("llama2-7b", "int4"),
                                      ("qwen3-8b", "int4"),
                                      ("deepseek-v2-236b", "int8")])
def test_kv_quantized_stream_tolerance(arch, kdt):
    """Quantized KV pages (int8 GQA + MLA latents, packed int4 GQA) track
    the f32-paged reference within tolerance."""
    cfg, params = cached(arch)
    paged = dict(paged=True, page_size=8, n_pages=48)
    _, base = run_engine(cfg, params, **paged)
    _, got = run_engine(cfg, params, kv_dtype=kdt, **paged)
    assert all(o[0] == p[0] for o, p in zip(got, base)), \
        f"{arch}/{kdt}: first greedy token diverged"
    assert agreement(got, base) >= 0.5


def test_kv_int4_requires_paged_and_mla_stays_int8():
    cfg, params = cached("llama2-7b")
    with pytest.raises(ValueError):
        run_engine(cfg, params, kv_dtype="int4")         # dense arena
    mla_cfg, _ = cached("deepseek-v2-236b")
    pool = KVPool(mla_cfg, n_slots=2, n_pages=16, page_size=8,
                  kv_dtype="int4")
    # MLA latents are already rank-compressed; int4 requests fall back to
    # int8 latent pages rather than packing the latent vector
    assert pool.caches[0]["latent"].dtype == jnp.int8
    assert "latent_scale" in pool.caches[0]


# ---------------------------------------------------------------------------
# engine: bit-identity across layouts WITHIN a quantized config
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama2-7b", "qwen3-8b"])
def test_quantized_cross_mode_identity(arch):
    """weights=int8 + kv=int4: paged / prefix-cache / packed-prefill /
    speculative greedy streams are byte-equal (seed pinned per the module
    docstring — the speculative verify program flips near-ties on other
    seeds, f32 included)."""
    cfg, params = cached(arch)
    seed = {"llama2-7b": 1, "qwen3-8b": 2}[arch]
    q = dict(weights_dtype="int8", kv_dtype="int4",
             paged=True, page_size=8, n_pages=48)
    _, base = run_engine(cfg, params, seed, **q)
    _, pfx = run_engine(cfg, params, seed, prefix_cache=True, **q)
    _, pak = run_engine(cfg, params, seed, packed_prefill=True, **q)
    eng_s, spc = run_engine(cfg, params, seed,
                            speculative=SpecConfig(k=3), **q)
    assert pfx == base, f"{arch}: prefix-cache stream diverged"
    assert pak == base, f"{arch}: packed-prefill stream diverged"
    assert spc == base, f"{arch}: speculative stream diverged"
    ss = eng_s.spec_stats()
    assert ss["windows"] > 0, "speculative path never ran a verify window"


def test_quantized_prefix_cow_divergence():
    """Shared-head prompts under int8 weights + int4 KV: the radix cache
    must COW the PACKED pages and their scale pages when suffixes diverge
    — streams equal to the cache-off run, with real hits and copies."""
    cfg, params = cached("llama2-7b")
    rng = np.random.default_rng(0)
    head = rng.integers(0, cfg.vocab_size, (16,)).tolist()
    ps = [head + rng.integers(0, cfg.vocab_size, (t,)).tolist()
          for t in (6, 9, 12)]
    q = dict(weights_dtype="int8", kv_dtype="int4",
             paged=True, page_size=8, n_pages=48)

    def gen(**kw):
        # max_batch=1 runs the requests back to back, so the first one
        # publishes its prefix pages before the others prefill (a joint
        # batch prefills concurrently and can never hit)
        sc = ServeConfig(max_batch=1, max_len=64,
                         phase=PhaseAwareConfig(max_decode_batch=1,
                                                prefill_chunk=16,
                                                max_prefill_tokens=256),
                         **q, **kw)
        eng = ServingEngine(cfg, params, sc)
        reqs = [eng.submit(list(p), max_new_tokens=10) for p in ps]
        eng.run_until_drained()
        return eng, [r.generated for r in reqs]

    _, base = gen()
    eng, got = gen(prefix_cache=True)
    stats = eng.prefix_stats()
    assert stats["hit_tokens"] > 0, "prefix cache never hit"
    assert got == base, "COW on quantized pages changed greedy streams"


def test_quantized_spec_truncate_on_scale_pages():
    """Speculative rollback truncates packed int4 pages AND their scale
    pages: rejected drafts must leave no stale quantized entries (streams
    equal to the non-speculative twin, with verify windows that actually
    rejected)."""
    cfg, params = cached("llama2-7b")
    seed = 3
    q = dict(kv_dtype="int4", paged=True, page_size=8, n_pages=48)
    _, base = run_engine(cfg, params, seed, max_new=16, **q)
    eng, spc = run_engine(cfg, params, seed, max_new=16,
                          speculative=SpecConfig(k=3), **q)
    ss = eng.spec_stats()
    assert ss["windows"] > 0
    assert ss["acceptance_rate"] < 1.0, (
        "random prompts should reject some drafts (truncate path unused)")
    assert spc == base, "speculative truncate corrupted quantized pages"
