"""Sharded execution tests: run REAL computations on 8 host devices.

XLA locks the device count at first backend init, so these run in
subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count=8.
Each subprocess numerically compares the sharded result against the
unsharded oracle — proving the sharding rules preserve semantics, not just
that they compile.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, timeout=480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


COMMON = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_config
from repro.distributed.policy import ShardingPolicy, sharding_policy
from repro.distributed.sharding import (param_pspecs, shardings_from_pspecs,
                                        train_state_pspecs, cache_pspecs)
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import init_params, forward_train, prefill, decode_step, init_cache

assert len(jax.devices()) == 8, jax.devices()
mesh = make_host_mesh(4, 2)   # data=4, model=2
cfg = dataclasses.replace(get_config("{arch}").reduced(), dtype="float32")
key = jax.random.PRNGKey(0)
params = init_params(key, cfg)
B, T = 4, 32
tokens = jax.random.randint(key, (B, cfg.n_codebooks, T) if cfg.n_codebooks > 1
                            else (B, T), 0, cfg.vocab_size)
batch = {{"tokens": tokens}}
if cfg.frontend == "vision":
    batch["tokens"] = tokens[:, : T - cfg.n_frontend_tokens]
    batch["vision_embeds"] = jax.random.normal(
        key, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)

# unsharded oracle
ref_logits, _ = forward_train(params, cfg, batch, remat=False)

# sharded run
pspec = param_pspecs(cfg, params_tree=params)
shard = shardings_from_pspecs(mesh, pspec)
params_sh = jax.device_put(params, shard)
policy = ShardingPolicy(mesh, batch_axes=("data",))
with mesh, sharding_policy(policy):
    f = jax.jit(lambda p, b: forward_train(p, cfg, b, remat=False)[0])
    got = f(params_sh, batch)
np.testing.assert_allclose(np.asarray(got, np.float32),
                           np.asarray(ref_logits, np.float32),
                           rtol=2e-3, atol=2e-3)
print("SHARDED-OK", "{arch}")
"""


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-2.7b",
                                  "deepseek-v2-236b", "arctic-480b",
                                  "gemma3-1b"])
def test_sharded_forward_matches_unsharded(arch):
    out = run_sub(COMMON.format(arch=arch))
    assert f"SHARDED-OK {arch}" in out


DECODE = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_config
from repro.distributed.policy import ShardingPolicy, sharding_policy
from repro.distributed.sharding import (param_pspecs, shardings_from_pspecs,
                                        cache_pspecs)
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import (init_params, prefill, decode_step,
                                      pad_cache)

mesh = make_host_mesh(4, 2)
cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(), dtype="float32")
key = jax.random.PRNGKey(1)
params = init_params(key, cfg)
B, P_, S = 4, 16, 32
tokens = jax.random.randint(key, (B, P_), 0, cfg.vocab_size)

# oracle: unsharded prefill+decode
logits, cache = prefill(params, cfg, {"tokens": tokens})
cache = pad_cache(cfg, cache, P_, S)
nt = jnp.argmax(logits[:, -1:], -1)
ref_dl, _ = decode_step(params, cfg, {"tokens": nt}, cache, jnp.int32(P_))

# sharded: cache sequence axis over 'model', batch over 'data'
pspec = param_pspecs(cfg, fsdp_axis=None, params_tree=params)
pshard = shardings_from_pspecs(mesh, pspec)
params_sh = jax.device_put(params, pshard)
cspec = cache_pspecs(cfg, mesh, B)
cshard = [jax.tree.map(lambda s: NamedSharding(mesh, s), cs,
                       is_leaf=lambda x: isinstance(x, P)) for cs in cspec]
cache_sh = [jax.device_put(c, s) for c, s in zip(cache, cshard)]
policy = ShardingPolicy(mesh, batch_axes=("data",))
with mesh, sharding_policy(policy):
    f = jax.jit(lambda p, b, c, pos: decode_step(p, cfg, b, c, pos))
    dl_sh, _ = f(params_sh, {"tokens": nt}, cache_sh, jnp.int32(P_))
np.testing.assert_allclose(np.asarray(dl_sh, np.float32),
                           np.asarray(ref_dl, np.float32),
                           rtol=2e-3, atol=2e-3)
print("DECODE-SHARDED-OK")
"""


def test_sharded_decode_with_sequence_sharded_cache():
    out = run_sub(DECODE)
    assert "DECODE-SHARDED-OK" in out


TRAIN = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.distributed.policy import ShardingPolicy, sharding_policy
from repro.distributed.sharding import shardings_from_pspecs, train_state_pspecs
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import init_train_state, make_train_step
from repro.optim.optimizers import adamw

mesh = make_host_mesh(4, 2)
cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(), dtype="float32")
opt = adamw(lambda s: jnp.float32(1e-2))
step_fn = make_train_step(cfg, opt, remat=False)
key = jax.random.PRNGKey(0)
state = init_train_state(key, cfg, opt)
tokens = jax.random.randint(key, (8, 32), 0, cfg.vocab_size)
batch = {"tokens": tokens}

# oracle
ref_state, ref_metrics = jax.jit(step_fn)(
    jax.tree.map(lambda x: x, state), batch)

# sharded
pspecs = train_state_pspecs(cfg, opt_state_tree=state["opt_state"],
                            params_tree=state["params"])
shard = shardings_from_pspecs(mesh, pspecs)
state_sh = {"params": jax.device_put(state["params"], shard["params"]),
            "opt_state": jax.device_put(state["opt_state"], shard["opt_state"]),
            "step": jax.device_put(state["step"], shard["step"])}
policy = ShardingPolicy(mesh, batch_axes=("data",))
with mesh, sharding_policy(policy):
    got_state, got_metrics = jax.jit(step_fn)(state_sh, batch)
np.testing.assert_allclose(float(got_metrics["loss"]),
                           float(ref_metrics["loss"]), rtol=1e-3)
for a, b in zip(jax.tree.leaves(ref_state["params"]),
                jax.tree.leaves(got_state["params"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-3, atol=3e-3)
print("TRAIN-SHARDED-OK")
"""


def test_sharded_train_step_matches_unsharded():
    out = run_sub(TRAIN)
    assert "TRAIN-SHARDED-OK" in out
