"""Property-based tests (hypothesis) on the system's invariants."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.layers import apply_rope, rmsnorm, rmsnorm_init
from repro.models.ssm import _segsum, ssd_chunked
from repro.optim.grad_utils import (
    compress_int8,
    compress_with_feedback,
    decompress_int8,
)
from repro.kernels.gemv_cid import quantize_int8

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# RoPE: relative-position property — scores depend only on distance
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(offset=st.integers(0, 512), d=st.sampled_from([32, 64, 128]))
def test_rope_relative_position(offset, d):
    """<rope(q,p+o), rope(k,p'+o)> == <rope(q,p), rope(k,p')> for all o."""
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    q = jax.random.normal(k1, (1, 4, 1, d))
    k = jax.random.normal(k2, (1, 4, 1, d))
    pos = jnp.array([[3, 7, 11, 20]], jnp.int32)
    q0 = apply_rope(q, pos, 10000.0)
    k0 = apply_rope(k, pos, 10000.0)
    q1 = apply_rope(q, pos + offset, 10000.0)
    k1_ = apply_rope(k, pos + offset, 10000.0)
    s0 = jnp.einsum("bthd,bshd->bhts", q0, k0)
    s1 = jnp.einsum("bthd,bshd->bhts", q1, k1_)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=2e-4, atol=2e-4)


@settings(**SETTINGS)
@given(d=st.sampled_from([16, 64, 256]))
def test_rope_preserves_norm(d):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (2, 8, 2, d))
    pos = jnp.arange(8, dtype=jnp.int32)[None].repeat(2, 0)
    y = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(scale=st.floats(0.1, 100.0), d=st.sampled_from([8, 64, 256]))
def test_rmsnorm_scale_invariance(scale, d):
    """rmsnorm(c*x) == rmsnorm(x) for any positive c."""
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (3, d)) + 0.1
    p = rmsnorm_init(d, jnp.float32)
    a = rmsnorm(p, x, 1e-6)
    b = rmsnorm(p, x * scale, 1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-3)


def test_rmsnorm_unit_rms():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (16, 128)) * 7.0
    p = rmsnorm_init(128, jnp.float32)
    y = np.asarray(rmsnorm(p, x, 1e-6))
    rms = np.sqrt((y ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


# ---------------------------------------------------------------------------
# int8 compression: error bounds + error-feedback telescoping
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(n=st.integers(10, 5000), scale=st.floats(1e-3, 1e3))
def test_compress_roundtrip_error_bound(n, scale):
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (n,)) * scale
    q, s = compress_int8(x)
    y = decompress_int8(q, s, x.shape, jnp.float32)
    # per-block max error <= scale/2 = amax/254
    err = np.abs(np.asarray(x) - np.asarray(y))
    bound = np.asarray(s).max() * 0.5 + 1e-12
    assert err.max() <= bound * 1.001


def test_error_feedback_telescopes():
    """sum of dequantized updates + final error == sum of raw gradients."""
    key = jax.random.PRNGKey(5)
    grads = jax.random.normal(key, (20, 1000))
    err = jnp.zeros((1000,))
    sent = jnp.zeros((1000,))
    for i in range(20):
        q, s, err = compress_with_feedback(grads[i], err)
        sent = sent + decompress_int8(q, s, (1000,), jnp.float32)
    total = np.asarray(grads.sum(0))
    np.testing.assert_allclose(np.asarray(sent + err), total,
                               rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(k=st.integers(1, 64))
def test_weight_quantize_int8_bound(k):
    key = jax.random.PRNGKey(k)
    w = jax.random.normal(key, (64, 32)) * (10.0 ** (k % 5 - 2))
    q, s = quantize_int8(w)
    deq = np.asarray(q, np.float32) * np.asarray(s)[None, :]
    err = np.abs(deq - np.asarray(w))
    assert (err <= np.asarray(s)[None, :] * 0.5 + 1e-9).all()


# ---------------------------------------------------------------------------
# SSD: chunked == sequential recurrence (the state-space duality itself)
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(T=st.sampled_from([32, 64]), chunk=st.sampled_from([8, 16, 32]))
def test_ssd_chunked_equals_recurrence(T, chunk):
    B, H, P, N, G = 1, 2, 8, 4, 1
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (B, T, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H))) * 0.2
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, T, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, T, G, N)) * 0.5
    D = jnp.zeros((H,))

    y_chunk, state_chunk = ssd_chunked(x, dt, A, Bm, Cm, D, chunk)

    # token-by-token recurrence oracle
    state = np.zeros((B, H, P, N), np.float64)
    ys = []
    for t in range(T):
        dA = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])   # [B,H]
        Bt = np.asarray(Bm[:, t, 0])                              # [B,N]
        Ct = np.asarray(Cm[:, t, 0])
        xt = np.asarray(x[:, t])                                  # [B,H,P]
        upd = (np.asarray(dt[:, t])[..., None, None]
               * xt[..., None] * Bt[:, None, None, :])
        state = state * dA[..., None, None] + upd
        ys.append(np.einsum("bhpn,bn->bhp", state, Ct))
    y_seq = np.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_chunk), y_seq,
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(state_chunk), state,
                               rtol=2e-3, atol=2e-3)


def test_segsum_matches_direct():
    dA = jnp.asarray(np.random.default_rng(0).normal(size=(3, 8)))
    out = np.asarray(_segsum(dA))
    for i in range(8):
        for j in range(8):
            if j > i:
                assert out[0, i, j] == -np.inf
            else:
                want = np.asarray(dA[0, j + 1: i + 1]).sum()
                np.testing.assert_allclose(out[0, i, j], want, rtol=1e-5,
                                           atol=1e-5)


# ---------------------------------------------------------------------------
# MoE: sparse dispatch == dense reference (no drops at small S)
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(E=st.sampled_from([4, 8]), K=st.sampled_from([1, 2]),
       S=st.sampled_from([16, 64]))
def test_moe_dispatch_matches_dense(E, K, S):
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_apply, moe_apply_reference, moe_init

    d, ff = 32, 64
    m = MoEConfig(n_experts=E, top_k=K, d_ff_expert=ff)
    params = moe_init(jax.random.PRNGKey(11), d, m, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(12), (1, S, d)) * 0.5
    got, aux1 = moe_apply(params, x, m)
    want, aux2 = moe_apply_reference(params, x, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-4)


# ---------------------------------------------------------------------------
# analytical scheduler: structural properties of the paper model
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(l_in=st.sampled_from([128, 512, 2048]),
       l_out=st.sampled_from([128, 512]))
def test_halo_never_slower_than_parts(l_in, l_out):
    """Phase-aware mapping must be <= each single-engine mapping on its own
    phase (it IS those mappings per phase)."""
    from repro.configs.base import get_config
    from repro.core.scheduler import evaluate

    cfg = get_config("llama2-7b")
    halo = evaluate(cfg, "halo1", l_in, l_out)
    cid = evaluate(cfg, "full_cid", l_in, l_out)
    cim = evaluate(cfg, "full_cim", l_in, l_out)
    assert halo.ttft <= cim.ttft * 1.001
    assert halo.tpot <= cid.tpot * 1.001
    assert halo.e2e <= min(cid.e2e, cim.e2e) * 1.001


@settings(max_examples=10, deadline=None)
@given(l_in=st.sampled_from([128, 512, 2048]))
def test_ttft_monotonic_in_context(l_in):
    from repro.configs.base import get_config
    from repro.core.scheduler import evaluate

    cfg = get_config("llama2-7b")
    a = evaluate(cfg, "halo1", l_in, 64)
    b = evaluate(cfg, "halo1", l_in * 2, 64)
    assert b.ttft > a.ttft
    assert b.tpot >= a.tpot * 0.999    # longer KV cache


def test_decode_trapezoid_matches_explicit_sum():
    """The closed-form trapezoid decode cost equals the explicit per-token
    sum (cost is affine in context length)."""
    from repro.configs.base import get_config
    from repro.core.engines import make_engines
    from repro.core.hardware import DEFAULT_HW
    from repro.core.mapping import get_mapping
    from repro.core.opgraph import decode_ops
    from repro.core.scheduler import _phase_cost, evaluate

    cfg = get_config("llama2-7b")
    l_in, l_out = 256, 32
    r = evaluate(cfg, "halo1", l_in, l_out)
    mapping = get_mapping("halo1")
    hw = DEFAULT_HW.with_wordlines(128)
    engines = make_engines(hw)
    total = sum(
        _phase_cost(decode_ops(cfg, t, 1), mapping, engines, "decode").seconds
        for t in range(l_in, l_in + l_out))
    np.testing.assert_allclose(r.decode_total, total, rtol=1e-6)
