"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (ref.py).

Kernels run in interpret mode on CPU — the kernel body executes as JAX ops,
bit-exact algorithm, no Mosaic — per the task sheet's validation contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gemv_cid import quantize_int8

KEY = jax.random.PRNGKey(42)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# gemm_cim (prefill GEMM)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,K,N", [(256, 512, 256), (512, 1024, 512),
                                   (128, 256, 384), (256, 2048, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes(M, K, N, dtype):
    k1, k2 = jax.random.split(KEY)
    x = rand(k1, (M, K), dtype)
    w = rand(k2, (K, N), dtype)
    got = ops.matmul(x, w, bm=128, bn=128, bk=256)
    want = ref.matmul_ref(x, w)
    # f32: pallas accumulates per K-tile, the oracle in one dot — ordering
    # differences bound the relative error at ~1e-3 for K=2048
    tol = 2e-2 if dtype == jnp.bfloat16 else 5e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_matmul_block_invariance():
    """Result must not depend on the tiling."""
    k1, k2 = jax.random.split(KEY)
    x = rand(k1, (256, 512))
    w = rand(k2, (512, 256))
    a = ops.matmul(x, w, bm=256, bn=256, bk=512)
    b = ops.matmul(x, w, bm=64, bn=64, bk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# gemv_cid (decode GEMV + fused int8 dequant)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,K,N", [(1, 1024, 512), (4, 2048, 1024),
                                   (8, 512, 2048)])
def test_gemv(B, K, N):
    k1, k2 = jax.random.split(KEY)
    x = rand(k1, (B, K))
    w = rand(k2, (K, N))
    got = ops.gemv(x, w, bn=256, bk=512)
    want = ref.gemv_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,K,N", [(1, 1024, 512), (4, 512, 1024)])
def test_gemv_int8_fused_dequant(B, K, N):
    k1, k2 = jax.random.split(KEY)
    x = rand(k1, (B, K))
    w = rand(k2, (K, N))
    q, scale = quantize_int8(w)
    got = ops.gemv(x, q, scale, bn=256, bk=512)
    want = ref.gemv_ref(x, q, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # and the dequantized path approximates the f32 GEMV
    exact = ref.gemv_ref(x, w)
    err = np.abs(np.asarray(got) - np.asarray(exact))
    assert err.max() / (np.abs(np.asarray(exact)).max() + 1e-9) < 0.05


@pytest.mark.parametrize("B,K,N,bn,bk", [
    (1, 1000, 500, 256, 512),     # K and N both ragged vs the tile grid
    (2, 768, 896, 512, 1024),     # K < bk entirely (single masked tile)
    (1, 1536, 300, 256, 512),     # N smaller than two tiles
])
def test_gemv_ragged_tiles(B, K, N, bn, bk):
    """Shapes that don't divide the tile grid: the masked edge tiles must
    not leak padding garbage into the accumulator (serving models' d_model
    / d_ff are not multiples of the default 512x1024 tiling)."""
    k1, k2 = jax.random.split(KEY)
    x = rand(k1, (B, K))
    w = rand(k2, (K, N))
    got = ops.gemv(x, w, bn=bn, bk=bk)
    want = ref.gemv_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    q, scale = quantize_int8(w)
    got_q = ops.gemv(x, q, scale, bn=bn, bk=bk)
    want_q = ref.gemv_ref(x, q, scale)
    np.testing.assert_allclose(np.asarray(got_q), np.asarray(want_q),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention (prefill)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,Hkv,T,D", [
    (1, 4, 4, 512, 64),
    (2, 8, 2, 512, 64),      # GQA
    (1, 4, 1, 1024, 128),    # MQA
])
def test_flash_attention_causal(B, H, Hkv, T, D):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, T, D), scale=0.5)
    k = rand(ks[1], (B, Hkv, T, D), scale=0.5)
    v = rand(ks[2], (B, Hkv, T, D), scale=0.5)
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [128, 256])
def test_flash_attention_sliding_window(window):
    B, H, T, D = 1, 4, 512, 64
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, T, D), scale=0.5)
    k = rand(ks[1], (B, H, T, D), scale=0.5)
    v = rand(ks[2], (B, H, T, D), scale=0.5)
    got = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    B, H, T, D = 1, 4, 512, 64
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, T, D), jnp.bfloat16, 0.5)
    k = rand(ks[1], (B, H, T, D), jnp.bfloat16, 0.5)
    v = rand(ks[2], (B, H, T, D), jnp.bfloat16, 0.5)
    got = ops.flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# decode_attention (flash-decode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,Hkv,S,D", [
    (1, 8, 8, 1024, 64),
    (4, 8, 2, 2048, 64),
    (2, 4, 1, 1024, 128),
])
def test_decode_attention(B, H, Hkv, S, D):
    ks = jax.random.split(KEY, 4)
    q = rand(ks[0], (B, H, D), scale=0.5)
    kc = rand(ks[1], (B, S, Hkv, D), scale=0.5)
    vc = rand(ks[2], (B, S, Hkv, D), scale=0.5)
    lengths = jax.random.randint(ks[3], (B,), S // 4, S + 1)
    got = ops.decode_attention(q, kc, vc, lengths, bs=256)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_decode_attention_ragged_lengths():
    """Masked entries must not influence the output at all."""
    B, H, S, D = 2, 4, 512, 64
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (B, H, D))
    kc = rand(ks[1], (B, S, H, D))
    vc = rand(ks[2], (B, S, H, D))
    lengths = jnp.array([128, 256], jnp.int32)
    base = ops.decode_attention(q, kc, vc, lengths, bs=128)
    # poison everything beyond the lengths
    poison_k = kc.at[0, 128:].set(99.0).at[1, 256:].set(-99.0)
    poison_v = vc.at[0, 128:].set(99.0).at[1, 256:].set(-99.0)
    got = ops.decode_attention(q, poison_k, poison_v, lengths, bs=128)
    np.testing.assert_allclose(np.asarray(base), np.asarray(got),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S,bs", [(1536, 1024), (48, 32), (1000, 256)])
def test_decode_attention_non_divisible_cache_length(S, bs):
    """Regression: S % bs != 0 used to trip a hard assert; the final tile
    is now ragged and masked."""
    B, H, D = 2, 4, 64
    ks = jax.random.split(KEY, 4)
    q = rand(ks[0], (B, H, D), scale=0.5)
    kc = rand(ks[1], (B, S, H, D), scale=0.5)
    vc = rand(ks[2], (B, S, H, D), scale=0.5)
    lengths = jax.random.randint(ks[3], (B,), 1, S + 1)
    got = ops.decode_attention(q, kc, vc, lengths, bs=bs)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# paged_decode_attention (block-pool cache)
# ---------------------------------------------------------------------------


def _paged_setup(key, B, Hkv, D, n_pages, ps, W, lengths):
    """Build a pool + block tables and the equivalent dense gathered cache."""
    ks = jax.random.split(key, 2)
    k_pages = rand(ks[0], (n_pages, ps, Hkv, D), scale=0.5)
    v_pages = rand(ks[1], (n_pages, ps, Hkv, D), scale=0.5)
    rng = np.random.default_rng(0)
    free = list(rng.permutation(n_pages))
    bt = np.full((B, W), n_pages, np.int32)        # sentinel: unallocated
    for b in range(B):
        for i in range(-(-int(lengths[b]) // ps)):
            bt[b, i] = free.pop()
    # dense view: gather each row's pages (sentinel rows stay zero)
    kd = np.zeros((B, W * ps, Hkv, D), np.float32)
    vd = np.zeros((B, W * ps, Hkv, D), np.float32)
    for b in range(B):
        for i in range(W):
            if bt[b, i] < n_pages:
                kd[b, i * ps:(i + 1) * ps] = np.asarray(k_pages[bt[b, i]])
                vd[b, i * ps:(i + 1) * ps] = np.asarray(v_pages[bt[b, i]])
    return k_pages, v_pages, jnp.asarray(bt), jnp.asarray(kd), jnp.asarray(vd)


@pytest.mark.parametrize("B,H,Hkv,D,ps", [
    (2, 8, 8, 64, 16),
    (3, 8, 2, 64, 32),       # GQA
    (2, 4, 1, 128, 8),       # MQA
])
def test_paged_decode_attention_matches_ref(B, H, Hkv, D, ps):
    n_pages, W = 24, 6
    ks = jax.random.split(KEY, 2)
    lengths = jax.random.randint(ks[0], (B,), 1, W * ps + 1)
    q = rand(ks[1], (B, H, D), scale=0.5)
    k_pages, v_pages, bt, kd, vd = _paged_setup(
        jax.random.fold_in(KEY, 7), B, Hkv, D, n_pages, ps, W, lengths)
    got = ops.paged_decode_attention(q, k_pages, v_pages, bt, lengths)
    want = ref.decode_attention_ref(q, kd, vd, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_paged_decode_attention_unallocated_pages_inert():
    """Pool content outside a sequence's block table must not leak in."""
    B, H, D, ps, n_pages, W = 2, 4, 64, 16, 16, 4
    ks = jax.random.split(KEY, 2)
    lengths = jnp.array([20, 64], jnp.int32)
    q = rand(ks[1], (B, H, D))
    k_pages, v_pages, bt, kd, vd = _paged_setup(
        jax.random.fold_in(KEY, 8), B, H, D, n_pages, ps, W, lengths)
    base = ops.paged_decode_attention(q, k_pages, v_pages, bt, lengths)
    used = set(np.asarray(bt).ravel().tolist()) - {n_pages}
    unused = [p for p in range(n_pages) if p not in used]
    poison_k, poison_v = k_pages, v_pages
    for p in unused:
        poison_k = poison_k.at[p].set(99.0)
        poison_v = poison_v.at[p].set(-99.0)
    got = ops.paged_decode_attention(q, poison_k, poison_v, bt, lengths)
    np.testing.assert_allclose(np.asarray(base), np.asarray(got),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,H,Hkv,D,ps", [
    (2, 8, 8, 64, 16),
    (3, 8, 2, 64, 32),       # GQA
    (2, 4, 1, 128, 8),       # MQA
])
def test_paged_decode_attention_q4_matches_dequant_ref(B, H, Hkv, D, ps):
    """Packed-int4 paged decode: the in-register nibble unpack + dequant
    must reproduce attention over the explicitly dequantized dense view."""
    from repro.serving.quantized_cache import (
        dequantize, pack_int4, quantize_token_int4)

    n_pages, W = 24, 6
    ks = jax.random.split(KEY, 2)
    lengths = jax.random.randint(ks[0], (B,), 1, W * ps + 1)
    q = rand(ks[1], (B, H, D), scale=0.5)
    k_pages, v_pages, bt, _, _ = _paged_setup(
        jax.random.fold_in(KEY, 9), B, Hkv, D, n_pages, ps, W, lengths)
    kq, k_sc = quantize_token_int4(k_pages)
    vq, v_sc = quantize_token_int4(v_pages)
    kp, vp = pack_int4(kq), pack_int4(vq)
    got = ops.paged_decode_attention_q4(q, kp, k_sc, vp, v_sc, bt, lengths)
    # dense view of the QUANTIZED pool (so only the kernel arithmetic is
    # under test, not the quantization error)
    kd = np.zeros((B, W * ps, Hkv, D), np.float32)
    vd = np.zeros((B, W * ps, Hkv, D), np.float32)
    kdq = np.asarray(dequantize(kq, k_sc))
    vdq = np.asarray(dequantize(vq, v_sc))
    btn = np.asarray(bt)
    for b in range(B):
        for i in range(W):
            if btn[b, i] < n_pages:
                kd[b, i * ps:(i + 1) * ps] = kdq[btn[b, i]]
                vd[b, i * ps:(i + 1) * ps] = vdq[btn[b, i]]
    want = ref.decode_attention_ref(q, jnp.asarray(kd), jnp.asarray(vd),
                                    lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# ssd_chunk (Mamba-2 intra-chunk)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nc,H,Q,P,N", [(2, 4, 64, 32, 16),
                                        (1, 8, 128, 64, 32)])
def test_ssd_chunk(nc, H, Q, P, N):
    ks = jax.random.split(KEY, 5)
    x = rand(ks[0], (nc, H, Q, P), scale=0.5)
    dt = jax.nn.softplus(rand(ks[1], (nc, H, Q))) * 0.1
    A = -jnp.exp(rand(ks[2], (H,)) * 0.2)
    Bm = rand(ks[3], (nc, Q, N), scale=0.5)
    Cm = rand(ks[4], (nc, Q, N), scale=0.5)
    y, st = ops.ssd_chunk(x, dt, A, Bm, Cm, bh=2)
    for c in range(nc):
        y_ref, st_ref = ref.ssd_chunk_ref(
            x[c].transpose(1, 0, 2), dt[c].T, A, Bm[c], Cm[c])
        np.testing.assert_allclose(np.asarray(y[c]),
                                   np.asarray(y_ref.transpose(1, 0, 2)),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st[c]),
                                   np.asarray(st_ref.transpose(0, 2, 1)),
                                   rtol=2e-3, atol=2e-3)
