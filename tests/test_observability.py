"""Observability layer (PR 9): metrics registry, lifecycle tracing,
SLO/goodput, and the bench regression gate.

The load-bearing claims: the registry IS the engine's counter state
(``counts()``/``spec_stats()`` are views, never copies), the trace
RECONCILES with the registry (summing span args reproduces the lifetime
counters exactly), and tracing is identity-preserving (tracer on vs off
yields bit-identical greedy streams at an equal compile count).
"""

import dataclasses
import importlib.util
import json
import math
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving import (
    SLO,
    MetricsRegistry,
    SamplingParams,
    ServeConfig,
    ServingEngine,
    Tracer,
    quantile,
    slo_attainment,
)
from repro.serving.engine import RequestState
from repro.serving.metrics import counter_attr, gauge_attr
from repro.serving.scheduler import PhaseAwareConfig
from repro.serving.tracing import PID, TICK_TID


def tiny_cfg(name="qwen3-1.7b"):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


_PARAMS = {}


def cached_params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def make_engine(cfg, max_batch=2, *, executor="colocated", paged=True,
                page_size=4, n_pages=32, host_spill_pages=0,
                prefix_cache=False, spec=None, max_len=96,
                prefill_chunk=8, max_prefill_tokens=16, tracer=None):
    sc = ServeConfig(max_batch=max_batch, max_len=max_len,
                     phase=PhaseAwareConfig(
                         max_decode_batch=max_batch,
                         prefill_chunk=prefill_chunk,
                         max_prefill_tokens=max_prefill_tokens),
                     paged=paged, page_size=page_size, n_pages=n_pages,
                     prefix_cache=prefix_cache, speculative=spec,
                     executor=executor, host_spill_pages=host_spill_pages)
    return ServingEngine(cfg, cached_params(cfg), sc, tracer=tracer)


def prompts(cfg, n, L, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (L,), dtype=np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# metrics registry (host-only, no jax)
# ---------------------------------------------------------------------------


def test_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    assert m.counter("nope") == 0 and m.gauge("nope") == 0
    m.inc("c")
    m.inc("c", 4)
    m.set_gauge("g", 7.5)
    m.observe("h", 0.003, buckets=(0.001, 0.01, 0.1))
    m.observe("h", 0.02)    # buckets fixed at first observe
    m.observe("h", 99.0)    # lands in +Inf
    snap = m.snapshot()
    assert snap["counters"] == {"c": 5}
    assert snap["gauges"] == {"g": 7.5}
    h = snap["histograms"]["h"]
    # cumulative le-buckets: nothing <= 1ms, one <= 10ms, two <= 100ms,
    # all three <= +Inf
    assert h["buckets"] == [[0.001, 0], [0.01, 1], [0.1, 2],
                            [math.inf, 3]]
    assert h["count"] == 3 and h["sum"] == pytest.approx(99.023)
    assert m.values(["c", "ghost"]) == {"c": 5, "ghost": 0}


def test_registry_disabled_gates_instrumentation_not_state():
    m = MetricsRegistry(enabled=False)
    m.inc("c")
    m.set_gauge("g", 1.0)
    m.observe("h", 0.5)
    assert m.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}
    # the property store path (engine STATE) is unconditional
    m.set_counter("c", 3)
    m.force_gauge("g", 2.0)
    assert m.counter("c") == 3 and m.gauge("g") == 2.0


def test_histogram_rejects_bad_buckets_and_skips_nan():
    m = MetricsRegistry()
    with pytest.raises(ValueError, match="buckets"):
        m.observe("h", 1.0, buckets=())
    with pytest.raises(ValueError, match="buckets"):
        m.observe("h", 1.0, buckets=(2.0, 1.0))
    m.observe("ok", float("nan"))
    assert "ok" not in m.snapshot()["histograms"] or \
        m.snapshot()["histograms"]["ok"]["count"] == 0


def test_prometheus_render():
    m = MetricsRegistry()
    m.inc("serving_ticks_total", 3)
    m.set_gauge("serving_requests_active", 2)
    m.observe("serving_ttft_seconds", 0.004, buckets=(0.001, 0.01))
    m.observe("serving_ttft_seconds", 5.0)
    text = m.render()
    assert "# TYPE serving_ticks_total counter" in text
    assert "serving_ticks_total 3" in text
    assert "# TYPE serving_requests_active gauge" in text
    assert "# TYPE serving_ttft_seconds histogram" in text
    assert 'serving_ttft_seconds_bucket{le="0.001"} 0' in text
    assert 'serving_ttft_seconds_bucket{le="0.01"} 1' in text
    assert 'serving_ttft_seconds_bucket{le="+Inf"} 2' in text
    assert "serving_ttft_seconds_count 2" in text
    assert "serving_ttft_seconds_sum 5.004" in text
    assert text.endswith("\n")
    assert MetricsRegistry().render() == ""


def test_counter_attr_routes_through_registry():
    class Thing:
        hits = counter_attr("thing_hits_total")
        level = gauge_attr("thing_level")

        def __init__(self):
            self.metrics = MetricsRegistry(enabled=False)
            self.hits = 0

    t = Thing()
    t.hits += 2
    t.hits += 3
    t.level = 9
    # the attribute and the registry are the SAME cell — even disabled
    # (state store is unconditional)
    assert t.hits == 5 and t.metrics.counter("thing_hits_total") == 5
    assert t.level == 9 and t.metrics.gauge("thing_level") == 9


def test_quantile():
    assert quantile([3.0, 1.0, 2.0], 0.5) == 2.0
    assert quantile([1.0, 2.0], 0.5) == 1.5
    # NaN/None dropped, not zeroed
    assert quantile([1.0, float("nan"), 3.0, None], 0.5) == 2.0
    assert math.isnan(quantile([], 0.5))
    assert math.isnan(quantile([float("nan")], 0.9))
    xs = [0.3, 7.0, 1.5, 2.2, 9.9, 4.1, 0.01]
    for q in (0.0, 0.25, 0.5, 0.9, 1.0):
        assert quantile(xs, q) == pytest.approx(
            float(np.quantile(xs, q, method="linear")))
    with pytest.raises(ValueError, match="quantile"):
        quantile([1.0], 1.5)


# ---------------------------------------------------------------------------
# SLO arithmetic (synthetic timelines — no engine)
# ---------------------------------------------------------------------------


def test_slo_validates_deadlines():
    SLO()                                   # both absent is fine
    SLO(ttft_ms=100.0)
    with pytest.raises(ValueError, match="ttft_ms"):
        SLO(ttft_ms=0.0)
    with pytest.raises(ValueError, match="tpot_ms"):
        SLO(tpot_ms=-5.0)


def test_slo_attainment_arithmetic():
    nan = float("nan")
    slo = SLO(ttft_ms=100.0, tpot_ms=10.0)
    assert slo_attainment(0.05, 0.005, slo) == (True, True, True)
    assert slo_attainment(0.2, 0.005, slo) == (False, False, True)
    assert slo_attainment(0.05, 0.02, slo) == (False, True, False)
    assert slo_attainment(0.2, 0.02, slo) == (False, False, False)
    # deadline boundary is inclusive (<=)
    assert slo_attainment(0.1, 0.01, slo)[0]
    # NaN fails a present deadline, passes an absent one
    assert slo_attainment(nan, 0.005, slo) == (False, False, True)
    assert slo_attainment(nan, nan, SLO(tpot_ms=10.0)) == \
        (False, True, False)
    assert slo_attainment(nan, nan, SLO()) == (True, True, True)


# ---------------------------------------------------------------------------
# tracer (fake clock — deterministic timeline)
# ---------------------------------------------------------------------------


def make_clock(start=100.0):
    state = {"t": start}

    def clock():
        state["t"] += 1.0
        return state["t"]

    return clock


def test_tracer_event_schema():
    tr = Tracer(clock=make_clock())           # t0 = 101
    t0, t1 = tr.now(), tr.now()               # 102, 103
    tr.begin_request(5, t0, prompt_len=8)
    tr.request_span(5, "prefill_chunk", t0, t1, take=8, offset=0)
    tr.tick_span(t0, t1, index=0, preemptions=0)
    tr.instant("first_token", t1, req_id=5)
    tr.instant("compile", t1, group="decode")
    tr.end_request(5, t1, reason="length")
    evs = tr.events()
    meta = [e for e in evs if e["ph"] == "M"]
    # process_name + "ticks" thread + one "req 5" thread, named ONCE
    assert [m["args"]["name"] for m in meta] == \
        ["serving-engine", "ticks", "req 5"]
    b, = [e for e in evs if e["ph"] == "b"]
    e, = [e for e in evs if e["ph"] == "e"]
    assert b["cat"] == e["cat"] == "request" and b["id"] == e["id"] == 5
    assert b["tid"] == e["tid"] == 6         # tid = req_id + 1
    assert b["ts"] == pytest.approx(1e6) and b["args"]["prompt_len"] == 8
    span, tick = [e for e in evs if e["ph"] == "X"]
    assert span["cat"] == "phase" and span["name"] == "prefill_chunk"
    assert span["dur"] == pytest.approx(1e6)  # 1 fake-second
    assert tick["cat"] == "tick" and tick["tid"] == TICK_TID
    ft, comp = [e for e in evs if e["ph"] == "i"]
    assert ft["s"] == "t" and ft["tid"] == 6 and comp["tid"] == TICK_TID
    assert all(ev["pid"] == PID for ev in evs)
    doc = tr.to_json()
    assert doc["traceEvents"] is evs and doc["displayTimeUnit"] == "ms"
    json.dumps(doc)                          # must be serializable


def test_tracer_disabled_is_inert():
    tr = Tracer(enabled=False)
    assert tr.now() == 0.0
    tr.begin_request(0, 0.0)
    tr.request_span(0, "decode", 0.0, 1.0)
    tr.tick_span(0.0, 1.0)
    tr.instant("preempt", 0.0)
    tr.end_request(0, 0.0)
    assert tr.events() == []


def test_tracer_clamps_pre_epoch_timestamps(tmp_path):
    tr = Tracer(clock=make_clock())
    tr.request_span(0, "queued", -5.0, tr.now())  # t_submit predates t0
    span = [e for e in tr.events() if e["ph"] == "X"][0]
    assert span["ts"] == 0.0 and span["dur"] >= 0.0
    out = tmp_path / "t.json"
    tr.write(str(out))
    assert json.loads(out.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# engine integration: identity, reconciliation, registry-as-state
# ---------------------------------------------------------------------------


def _forced_preempt_drain(eng, ps, max_new=6):
    """Drive the engine, preempting a decoding request once mid-stream
    (deterministic — no reliance on pool-pressure timing)."""
    reqs = [eng.submit(p.copy(),
                       sampling=SamplingParams(max_new_tokens=max_new),
                       slo=SLO(ttft_ms=60_000.0, tpot_ms=60_000.0))
            for p in ps]
    fired = False
    for _ in range(500):
        if not (eng.queue or any(r is not None for r in eng.slot_req)):
            break
        eng.step()
        if not fired:
            victim = next(
                (r for r in eng.slot_req if r is not None
                 and r.state == RequestState.DECODING
                 and len(r.generated) >= 2), None)
            if victim is not None:
                eng._preempt(victim)
                fired = True
    assert fired, "no preemption fired — the scenario never ran"
    return [r.generated for r in reqs]


def test_tracing_identity_and_trace_registry_reconciliation():
    cfg = tiny_cfg()
    ps = prompts(cfg, 3, 16, seed=11)
    kw = dict(executor="disaggregated", n_pages=64, host_spill_pages=32)
    off = make_engine(cfg, **kw)
    ref = _forced_preempt_drain(off, ps)

    tracer = Tracer()
    eng = make_engine(cfg, tracer=tracer, **kw)
    # identity-preserving: bit-identical streams, zero extra compiles
    assert _forced_preempt_drain(eng, ps) == ref
    assert eng.executor.compile_count == off.executor.compile_count

    evs = tracer.events()
    ticks = [e for e in evs if e.get("cat") == "tick"]
    spans = [e for e in evs if e.get("cat") == "phase"]
    by_name = {}
    for s in spans:
        by_name.setdefault(s["name"], []).append(s)

    # the conservation laws: span/tick args sum to the lifetime counters
    assert len(ticks) == eng.n_ticks
    assert sum(s["args"]["take"] for s in by_name["prefill_chunk"]) \
        == eng.prefill_tokens_executed
    assert sum(s["args"]["tokens"] for s in by_name.get("decode", [])) \
        == eng.decode_tokens_emitted
    for key, want in (
            ("preemptions", eng.preemptions),
            ("migrated_bytes", eng.executor.migrated_bytes),
            ("migrated_pages", eng.executor.migrated_pages),
            ("swap_out_bytes", eng.counts()["swap_out_bytes"]),
            ("swap_in_bytes", eng.counts()["swap_in_bytes"]),
            ("prefill_tokens", eng.prefill_tokens_executed)):
        assert sum(t["args"][key] for t in ticks) == want, key

    # lifecycle envelopes: every request opened and closed exactly once
    begins = [e for e in evs if e["ph"] == "b"]
    ends = [e for e in evs if e["ph"] == "e"]
    assert len(begins) == len(ends) == len(ps)
    assert {e["id"] for e in begins} == {e["id"] for e in ends}
    # the preempted request shows the full story on its own track:
    # preempt instant, swap spans, and a second queued span
    preempts = [e for e in evs if e["ph"] == "i"
                and e["name"] == "preempt"]
    assert len(preempts) == eng.preemptions >= 1
    assert preempts[0]["args"]["swapped"] is True
    assert len(by_name["swap_out"]) == len(by_name["swap_in"]) >= 1
    assert sum(s["args"]["bytes"] for s in by_name["swap_out"]) \
        == eng.counts()["swap_out_bytes"]
    victim_tid = preempts[0]["tid"]
    assert len([s for s in by_name["queued"]
                if s["tid"] == victim_tid]) == 2
    # well-formed: every event serializes, durations non-negative
    json.dumps(tracer.to_json())
    assert all(e["dur"] >= 0 for e in evs if e["ph"] == "X")
    assert all(e["ts"] >= 0 for e in evs if "ts" in e)
    # compute-phase spans nest inside the tick that ran them ("queued"
    # opens at submit time, before any tick exists; swap spans may be
    # caller-driven between ticks, as the forced preempt here is)
    windows = [(t["ts"], t["ts"] + t["dur"]) for t in ticks]
    eps = 1.0                                # µs of float slack
    for s in spans:
        if s["name"] in ("queued", "swap_out", "swap_in"):
            continue
        assert any(lo - eps <= s["ts"] and s["ts"] + s["dur"] <= hi + eps
                   for lo, hi in windows), \
            f"{s['name']} span outside every tick window"


def test_counts_and_spec_stats_are_registry_views():
    # a mixed paged/prefix/speculative run: conservation must hold with
    # cached prefill tokens SKIPPED and decode tokens arriving via
    # verify windows rather than single-token decode spans
    cfg = tiny_cfg()
    from repro.serving import SpecConfig
    tracer = Tracer()
    eng = make_engine(cfg, spec=SpecConfig(k=2), n_pages=64,
                      prefix_cache=True, tracer=tracer)
    head = prompts(cfg, 1, 8, seed=9)[0]
    # drain sequentially so the second request HITS the first's cached
    # head (concurrent prefills would race the radix-tree insert)
    for p in prompts(cfg, 2, 12, seed=3):
        eng.submit(np.concatenate([head, p]),
                   sampling=SamplingParams(max_new_tokens=6))
        eng.run_until_drained()
    spans = [e for e in tracer.events() if e.get("cat") == "phase"]
    assert eng.prefix_stats()["hit_tokens"] > 0   # the cache actually hit
    assert sum(s["args"]["take"] for s in spans
               if s["name"] == "prefill_chunk") \
        == eng.prefill_tokens_executed
    assert (sum(s["args"]["tokens"] for s in spans
                if s["name"] == "decode")
            + sum(s["args"]["emitted"] for s in spans
                  if s["name"] == "verify_window")) \
        == eng.decode_tokens_emitted
    m = eng.metrics
    assert eng.decode_tokens_emitted \
        == m.counter("serving_decode_tokens_total") > 0
    ss = eng.spec_stats()
    assert ss["windows"] == m.counter("serving_spec_windows_total") > 0
    assert ss["accepted"] == m.counter("serving_spec_accepted_total")
    snap = eng.metrics_snapshot()
    assert snap["gauges"]["serving_requests_done"] == 2
    assert snap["gauges"]["serving_requests_active"] == 0
    assert snap["counters"]["serving_ticks_total"] == eng.n_ticks
    # latency histograms observed once per retired request / tick
    assert snap["histograms"]["serving_ttft_seconds"]["count"] == 2
    assert snap["histograms"]["serving_tick_wall_seconds"]["count"] \
        == eng.n_ticks
    # TickRecord deltas and lifetime counters tell one story
    assert sum(t.preemptions for t in eng.tick_log) == eng.preemptions
    assert sum(t.spec_drafted for t in eng.tick_log) \
        == m.counter("serving_spec_drafted_total")


def test_slo_goodput_end_to_end():
    cfg = tiny_cfg()
    eng = make_engine(cfg, n_pages=64)
    ps = prompts(cfg, 3, 12, seed=5)
    # generous deadline, impossible deadline, no SLO at all
    eng.submit(ps[0].copy(), sampling=SamplingParams(max_new_tokens=4),
               slo=SLO(ttft_ms=120_000.0, tpot_ms=120_000.0))
    eng.submit(ps[1].copy(), sampling=SamplingParams(max_new_tokens=4),
               slo=SLO(ttft_ms=1e-6))
    eng.submit(ps[2].copy(), sampling=SamplingParams(max_new_tokens=4))
    eng.run_until_drained()
    g = eng.goodput()
    assert g == {"slo_total": 2, "slo_attained": 1, "ttft_violations": 1,
                 "tpot_violations": 0, "goodput": 0.5}
    c = eng.counts()
    assert (c["slo_total"], c["slo_attained"], c["goodput"]) == (2, 1, 0.5)


def test_goodput_vacuous_and_abort_excluded():
    cfg = tiny_cfg()
    eng = make_engine(cfg, n_pages=64)
    assert eng.goodput()["goodput"] == 1.0       # no SLO'd requests ever
    r = eng.submit(prompts(cfg, 1, 12)[0],
                   sampling=SamplingParams(max_new_tokens=4),
                   slo=SLO(ttft_ms=1e-6))
    eng.abort(r.req_id)                          # client gave up pre-run
    eng.run_until_drained()
    # the aborted request neither met nor missed its deadline
    assert eng.goodput() == {"slo_total": 0, "slo_attained": 0,
                             "ttft_violations": 0, "tpot_violations": 0,
                             "goodput": 1.0}


def test_submit_rejects_non_slo():
    cfg = tiny_cfg()
    eng = make_engine(cfg)
    with pytest.raises(TypeError, match="slo"):
        eng.submit(prompts(cfg, 1, 8)[0], slo={"ttft_ms": 5.0})


def test_check_drained_failure_carries_diagnostics():
    cfg = tiny_cfg()
    eng = make_engine(cfg, n_pages=64)
    eng.submit(prompts(cfg, 1, 12)[0],
               sampling=SamplingParams(max_new_tokens=8))
    with pytest.raises(RuntimeError) as ei:
        eng.run_until_drained(max_ticks=1)
    msg = str(ei.value)
    assert "max_ticks=1" in msg
    assert "states=" in msg and "counts=" in msg and "last_tick=" in msg
    assert "decoding" in msg or "prefilling" in msg


# ---------------------------------------------------------------------------
# bench regression gate (stdlib-only script, loaded from scripts/)
# ---------------------------------------------------------------------------


def _load_gate():
    path = Path(__file__).resolve().parent.parent \
        / "scripts" / "check_bench_regression.py"
    spec = importlib.util.spec_from_file_location("check_bench_regression",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _bench_json(path, rows):
    path.write_text(json.dumps({
        "bench": "serving", "suites": ["s"],
        "rows": [{"name": n, "value": v, "unit": u, "paper": None}
                 for n, v, u in rows]}))
    return str(path)


def test_regression_gate_passes_and_fails(tmp_path, capsys):
    gate = _load_gate()
    base = _bench_json(tmp_path / "base.json",
                       [("a.compiled_shapes", 4.0, "count"),
                        ("a.ttft_p50_ms", 12.0, "ms"),
                        ("a.pad_waste_frac", 0.25, "frac")])
    same = _bench_json(tmp_path / "same.json",
                       [("a.compiled_shapes", 4.0, "count"),
                        ("a.ttft_p50_ms", 900.0, "ms"),   # timing: no gate
                        ("a.pad_waste_frac", 0.26, "frac")])  # 4% < 5%
    assert gate.main(["--compare", f"{base}={same}"]) == 0

    drift = _bench_json(tmp_path / "drift.json",
                        [("a.compiled_shapes", 8.0, "count"),
                         ("a.ttft_p50_ms", 12.0, "ms"),
                         ("a.pad_waste_frac", 0.25, "frac")])
    assert gate.main(["--compare", f"{base}={drift}"]) == 1
    assert "compiled_shapes" in capsys.readouterr().err
    # warn-only mode reports the same drift but exits 0
    assert gate.main(["--compare", f"{base}={drift}", "--warn-only"]) == 0
    assert "::warning" in capsys.readouterr().out
    # per-row override admits the intended change
    assert gate.main(["--compare", f"{base}={drift}",
                      "--tolerance", "a.compiled_shapes=1.5"]) == 0


def test_regression_gate_row_lifecycle(tmp_path, capsys):
    gate = _load_gate()
    base = _bench_json(tmp_path / "base.json",
                       [("a.rows", 10.0, "rows"), ("a.nan", float("nan"),
                                                   "count")])
    missing = _bench_json(tmp_path / "missing.json",
                          [("a.nan", float("nan"), "count")])
    assert gate.main(["--compare", f"{base}={missing}"]) == 1
    assert "missing" in capsys.readouterr().err
    extra = _bench_json(tmp_path / "extra.json",
                        [("a.rows", 10.0, "rows"),
                         ("a.nan", float("nan"), "count"),
                         ("a.new_metric", 1.0, "count")])
    assert gate.main(["--compare", f"{base}={extra}"]) == 0
    assert "new row" in capsys.readouterr().out
    # NaN -> number on a structural row is drift, not a silent pass
    flip = _bench_json(tmp_path / "flip.json",
                       [("a.rows", 10.0, "rows"), ("a.nan", 3.0, "count")])
    assert gate.main(["--compare", f"{base}={flip}"]) == 1
    unit = _bench_json(tmp_path / "unit.json",
                       [("a.rows", 10.0, "MB"), ("a.nan", float("nan"),
                                                 "count")])
    assert gate.main(["--compare", f"{base}={unit}"]) == 1
    assert gate.main(["--compare", f"{base}=/nonexistent.json"]) == 1
