"""Async continuous-serving front-end + SLO-aware admission + traffic
harness: mailbox determinism (async greedy streams bit-identical to the
sync engine), EDF/priority prefill ordering, shed-before-thrash admission
(strictly fewer preemptions AND strictly higher goodput than a
shedding-disabled twin under forced overload), client-cancellation abort
with page-refcount conservation, and the seeded trace generator.

No pytest-asyncio in the image: every async scenario runs under a plain
``asyncio.run`` inside a sync test function.
"""

import asyncio
import dataclasses
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving import (
    SLO,
    AdmissionConfig,
    AdmissionController,
    AsyncEngine,
    SamplingParams,
    ServeConfig,
    ServingEngine,
    TenantSpec,
    TrafficConfig,
    replay,
    synthesize,
)
from repro.serving.metrics import quantile
from repro.serving.scheduler import (
    PRIORITY_BATCH,
    PRIORITY_INTERACTIVE,
    PRIORITY_STANDARD,
    PhaseAwareConfig,
    PhaseScheduler,
)


def tiny_cfg(name="qwen3-1.7b"):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


_PARAMS = {}


def cached_params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def serve_cfg(max_batch=3, *, paged=True, page_size=8, n_pages=48,
              max_len=96, prefill_chunk=8, max_prefill_tokens=16, **sc_kw):
    return ServeConfig(max_batch=max_batch, max_len=max_len,
                       phase=PhaseAwareConfig(
                           max_decode_batch=max_batch,
                           prefill_chunk=prefill_chunk,
                           max_prefill_tokens=max_prefill_tokens),
                       paged=paged, page_size=page_size, n_pages=n_pages,
                       **sc_kw)


def prompts(cfg, n, L, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (L,), dtype=np.int32)
            for _ in range(n)]


class HostOnlyEngine(ServingEngine):
    """Device programs stubbed (every sampled token is 0) so the async
    machinery, admission accounting, paging, and abort paths run fast;
    same pattern as test_request_api.HostOnlyEngine."""

    _CACHE_ARG = {"chunk": 5, "chunk_paged": 5, "whole": 3,
                  "packed": 6, "packed_paged": 6,
                  "decode": 2, "decode_paged": 2, "verify": 5}

    def _program(self, group, kind):
        cache_arg = self._CACHE_ARG[kind]

        def run(*args):
            cache = args[cache_arg]
            if kind in ("packed", "packed_paged"):
                n = np.asarray(args[2]).shape[0]
            else:
                n = 1 if kind == "whole" else np.asarray(args[1]).shape[0]
            return jnp.zeros((n,), jnp.int32), cache

        return run

    def _copy_pages(self, copies):
        self.cow_copies += len(copies)


def host_engine(cfg, sc):
    return HostOnlyEngine(cfg, cached_params(cfg), sc)


def assert_pools_free(eng):
    for p in eng.pool.pools:
        p.check_invariants()
        assert p.free_pages() == p.n_pages, "pages leaked"


# ---------------------------------------------------------------------------
# scheduler: priority classes + EDF prefill ordering
# ---------------------------------------------------------------------------


def test_priority_constants_order():
    assert PRIORITY_INTERACTIVE < PRIORITY_STANDARD < PRIORITY_BATCH


def test_plan_tick_orders_by_priority_then_deadline_then_age():
    sched = PhaseScheduler(PhaseAwareConfig(
        max_decode_batch=4, prefill_chunk=8, max_prefill_tokens=16))
    waiting = [
        (1, 8, True, 0, PRIORITY_BATCH, math.inf),
        (2, 8, True, 0, PRIORITY_INTERACTIVE, 5.0),
        (3, 8, True, 0, PRIORITY_INTERACTIVE, 1.0),
        (4, 8, True, 0, PRIORITY_STANDARD, 0.5),
    ]
    plan = sched.plan_tick(waiting, [])
    # 16-token budget admits exactly two 8-token chunks: both INTERACTIVE
    # requests, EDF within the class (3 before 2); the earlier-deadline
    # STANDARD request cannot outrank a class above it
    assert plan.prefill_reqs == [3, 2]


def test_plan_tick_legacy_entries_keep_age_order():
    """Entries without priority/deadline fields must degrade to the
    pre-SLO pure req_id order — existing callers see identical plans."""
    sched = PhaseScheduler(PhaseAwareConfig(
        max_decode_batch=4, prefill_chunk=8, max_prefill_tokens=16))
    plan = sched.plan_tick([(7, 8), (5, 8, True, 0)], [])
    assert plan.prefill_reqs == [5, 7]


def test_plan_tick_deadline_breaks_ties_within_class():
    sched = PhaseScheduler(PhaseAwareConfig(
        max_decode_batch=4, prefill_chunk=8, max_prefill_tokens=8))
    waiting = [(1, 8, True, 0, PRIORITY_STANDARD, 9.0),
               (2, 8, True, 0, PRIORITY_STANDARD, 2.0)]
    assert sched.plan_tick(waiting, []).prefill_reqs == [2]


# ---------------------------------------------------------------------------
# admission controller (pure host logic)
# ---------------------------------------------------------------------------


def test_admission_config_validation():
    with pytest.raises(ValueError):
        AdmissionConfig(margin=0.0)
    with pytest.raises(ValueError):
        AdmissionConfig(tick_cost_s=-1.0)
    with pytest.raises(ValueError):
        AdmissionConfig(min_ema_ticks=-1)
    with pytest.raises(ValueError):
        AdmissionConfig(max_pending_tokens=0)


def _controller(**cfg_kw):
    return AdmissionController(
        AdmissionConfig(**cfg_kw),
        PhaseAwareConfig(max_decode_batch=4, prefill_chunk=8,
                         max_prefill_tokens=16))


def test_admission_tick_cost_resolution():
    ctl = _controller(min_ema_ticks=2)
    assert ctl.resolve_tick_cost(0.5, 0) is None      # cold start
    assert ctl.resolve_tick_cost(0.5, 1) is None      # below min_ema_ticks
    assert ctl.resolve_tick_cost(0.5, 2) == 0.5
    assert _controller(tick_cost_s=0.25).resolve_tick_cost(9.9, 100) == 0.25


def test_admission_projection_terms_and_monotonicity():
    ctl = _controller(tick_cost_s=1.0)
    # 16 prompt tokens = 1 prefill tick, idle otherwise
    assert ctl.project_ttft_s(16, backlog_tokens=0, tick_cost_s=1.0) == 1.0
    # backlog adds prefill ticks; decode backlog drains 4 tokens/tick;
    # live requests beyond the 4 decode slots add slot-wait ticks
    assert ctl.project_ttft_s(16, backlog_tokens=32,
                              tick_cost_s=1.0) == 3.0
    assert ctl.project_ttft_s(16, backlog_tokens=0,
                              decode_backlog_tokens=8,
                              tick_cost_s=1.0) == 3.0
    assert ctl.project_ttft_s(16, backlog_tokens=0, n_live=6,
                              tick_cost_s=1.0) == 4.0
    base = ctl.project_ttft_s(16, backlog_tokens=8, decode_backlog_tokens=8,
                              n_live=2, tick_cost_s=1.0)
    for kw in (dict(backlog_tokens=64), dict(decode_backlog_tokens=64),
               dict(n_live=9)):
        args = dict(backlog_tokens=8, decode_backlog_tokens=8, n_live=2)
        args.update(kw)
        assert ctl.project_ttft_s(16, tick_cost_s=1.0, **args) >= base


def test_admission_decide_shed_defer_admit():
    ctl = _controller(tick_cost_s=1.0, max_pending_tokens=32)
    # fits: 1 prefill tick vs 10 s deadline
    assert ctl.decide(16, ttft_deadline_s=10.0) == "admit"
    # deadline already lost -> shed, not defer
    assert ctl.decide(16, ttft_deadline_s=2.0, backlog_tokens=64) == "shed"
    # best-effort over the structural cap -> defer (no deadline to lose)
    assert ctl.decide(16, backlog_tokens=24) == "defer"
    # a prompt that alone exceeds the cap could never start
    assert ctl.decide(40) == "shed"
    # margin scales the deadline: projection 2 ticks = 2 s
    assert _controller(tick_cost_s=1.0, margin=0.4).decide(
        32, ttft_deadline_s=4.0) == "shed"
    # no usable estimate -> admit optimistically
    assert _controller().decide(16, ttft_deadline_s=1e-9) == "admit"
    assert _controller(enabled=False).decide(10_000,
                                             ttft_deadline_s=1e-9) == "admit"


def test_tick_ema_excludes_compile_ticks():
    cfg = tiny_cfg()
    eng = host_engine(cfg, serve_cfg(max_batch=2))
    for p in prompts(cfg, 2, 16):
        eng.submit(p, max_new_tokens=4)
    eng.run_until_drained()
    compile_ticks = sum(1 for t in eng.tick_log if t.new_compiles > 0)
    assert compile_ticks > 0
    # the EMA saw only the non-compile ticks, and is a real tick cost
    assert eng._tick_wall_n == eng.n_ticks - compile_ticks
    assert eng.tick_wall_ema > 0


# ---------------------------------------------------------------------------
# engine-level admission: shed/defer wiring, metrics, drain breakdown
# ---------------------------------------------------------------------------


def shed_cfg(**adm_kw):
    """Deterministic admission: fixed 1 s/tick makes every decision a
    pure function of queue occupancy."""
    return serve_cfg(max_batch=4, admission=AdmissionConfig(
        tick_cost_s=1.0, **adm_kw))


def test_submit_sheds_when_projection_busts_deadline():
    cfg = tiny_cfg()
    eng = host_engine(cfg, shed_cfg())
    for p in prompts(cfg, 4, 16):
        eng.submit(p, max_new_tokens=4)          # 64-token backlog, no SLO
    doomed = eng.submit(prompts(cfg, 1, 16, seed=1)[0], max_new_tokens=4,
                        slo=SLO(ttft_ms=1000.0))
    assert doomed.finish_reason == "shed" and doomed.state.name == "DONE"
    assert eng.counts()["shed"] == 1 and eng.admission_shed == 1
    # shed deadline-carrying demand counts, un-attained: goodput is a
    # fraction of everything ASKED, not everything served
    g = eng.goodput()
    assert (g["slo_total"], g["slo_attained"]) == (1, 0)
    eng.run_until_drained()
    assert sum(r.finish_reason == "length" for r in eng.done) == 4
    assert_pools_free(eng)


def test_best_effort_defers_then_drains():
    cfg = tiny_cfg()
    eng = host_engine(cfg, shed_cfg(max_pending_tokens=24))
    first = eng.submit(prompts(cfg, 1, 16)[0], max_new_tokens=4)
    parked = eng.submit(prompts(cfg, 1, 16, seed=1)[0], max_new_tokens=4)
    assert eng.counts()["deferred"] == 1 and eng.admission_deferred == 1
    assert parked.state.name == "WAITING" and parked not in eng.queue
    eng.run_until_drained()                      # reconsidered each tick
    assert first.finish_reason == "length"
    assert parked.finish_reason == "length"
    assert_pools_free(eng)


def test_drain_failure_reports_shed_and_deferred_distinctly():
    """Satellite: the RuntimeError breakdown must separate admission
    outcomes (deferred / shed) from live queued requests."""
    cfg = tiny_cfg()
    eng = host_engine(cfg, shed_cfg(max_pending_tokens=24))
    eng.submit(prompts(cfg, 1, 16)[0], max_new_tokens=4)
    eng.submit(prompts(cfg, 1, 16, seed=1)[0], max_new_tokens=4)  # defers
    eng.submit(prompts(cfg, 1, 16, seed=2)[0], max_new_tokens=4,
               slo=SLO(ttft_ms=1.0))                              # sheds
    with pytest.raises(RuntimeError) as exc:
        eng.run_until_drained(max_ticks=0)
    msg = str(exc.value)
    assert "1 deferred" in msg and "1 shed" in msg and "1 queued" in msg
    assert "'deferred': 1" in msg               # its own state bucket
    eng.run_until_drained()


# ---------------------------------------------------------------------------
# AsyncEngine: identity, interleaving, cancellation, shed streams
# ---------------------------------------------------------------------------


def _async_tokens(engine, prompt_list, max_new):
    """Submit every prompt as its own client task and consume streams
    concurrently; returns per-request token lists in submission order."""

    async def go():
        async with AsyncEngine(engine) as fe:
            async def client(p):
                handle = await fe.submit(p, max_new_tokens=max_new)
                outs = [out async for out in handle]
                assert outs[-1].finished
                return handle, outs

            return await asyncio.gather(*[client(p) for p in prompt_list])

    return asyncio.run(go())


def test_async_streams_bit_identical_to_sync_engine():
    """The tentpole identity: same prompts, same order, greedy — the
    async front-end's streams must match the synchronous engine token
    for token, and the streamed outputs must reassemble exactly to
    ``Request.generated``.  Real device programs, not the host stub."""
    cfg = tiny_cfg()
    ps = prompts(cfg, 4, 14) + prompts(cfg, 1, 20, seed=3)
    sync_eng = ServingEngine(cfg, cached_params(cfg), serve_cfg(max_batch=2))
    sync_reqs = [sync_eng.submit(p, max_new_tokens=3) for p in ps]
    sync_eng.run_until_drained()
    ref = [list(r.generated) for r in sync_reqs]

    async_eng = ServingEngine(cfg, cached_params(cfg), serve_cfg(max_batch=2))
    got = _async_tokens(async_eng, ps, max_new=3)
    # submission order == task creation order (each client posts to the
    # mailbox before its first await), so req_ids line up positionally
    assert [h.req_id for h, _ in got] == [r.req_id for r in sync_reqs]
    streamed = [[t for o in outs for t in o.new_token_ids]
                for _, outs in got]
    assert streamed == ref
    assert [h.token_ids() for h, _ in got] == ref


def test_concurrent_clients_interleave_incremental_outputs():
    cfg = tiny_cfg()
    eng = host_engine(cfg, serve_cfg(max_batch=2))
    got = _async_tokens(eng, prompts(cfg, 5, 16), max_new=6)
    assert [h.req_id for h, _ in got] == sorted(h.req_id for h, _ in got)
    for h, outs in got:
        assert h.finish_reason == "length"
        assert sum(len(o.new_token_ids) for o in outs) == 6
        # incremental streaming: tokens arrived across multiple outputs
        # (ticks), not as one terminal blob after the drain
        assert len(outs) >= 2 and not outs[0].finished
    assert_pools_free(eng)


def test_stream_cancellation_aborts_and_conserves_pages():
    cfg = tiny_cfg()
    eng = host_engine(cfg, serve_cfg(max_batch=2, n_pages=24))

    async def go():
        async with AsyncEngine(eng) as fe:
            started = asyncio.Event()

            async def doomed_client():
                async for _ in fe.stream(prompts(cfg, 1, 16)[0],
                                         max_new_tokens=50):
                    started.set()
                    await asyncio.sleep(3600)    # hold mid-stream

            task = asyncio.create_task(doomed_client())
            await started.wait()
            task.cancel()
            await asyncio.gather(task, return_exceptions=True)

            # the dropped stream turned into an abort; survivors unaffected
            survivor = await fe.submit(prompts(cfg, 1, 16, seed=2)[0],
                                       max_new_tokens=4)
            async for _ in survivor:
                pass
            await fe.drain()
            assert survivor.finish_reason == "length"
            assert len(survivor.token_ids()) == 4

    asyncio.run(go())
    reasons = sorted(r.finish_reason for r in eng.done)
    assert reasons == ["abort", "length"]
    assert_pools_free(eng)


def test_frontend_abort_returns_terminal_output():
    cfg = tiny_cfg()
    eng = host_engine(cfg, serve_cfg(max_batch=2))

    async def go():
        async with AsyncEngine(eng) as fe:
            handle = await fe.submit(prompts(cfg, 1, 16)[0],
                                     max_new_tokens=50)
            out = await fe.abort(handle.req_id)
            assert out is not None and out.finished
            assert out.finish_reason == "abort"
            assert await fe.abort(9999) is None          # unknown id
            outs = [o async for o in handle]             # stream terminates
            assert outs[-1].finish_reason == "abort"

    asyncio.run(go())
    assert_pools_free(eng)


def test_aclose_aborts_unconsumed_streams():
    cfg = tiny_cfg()
    eng = host_engine(cfg, serve_cfg(max_batch=2))

    async def go():
        async with AsyncEngine(eng) as fe:
            handle = await fe.submit(prompts(cfg, 1, 16)[0],
                                     max_new_tokens=50)
            return handle
        # aclose aborted the forgotten stream on the way out

    handle = asyncio.run(go())
    assert handle.request.finish_reason == "abort"
    assert_pools_free(eng)


def test_async_shed_stream_is_single_terminal_output():
    cfg = tiny_cfg()
    eng = host_engine(cfg, shed_cfg())

    async def go():
        async with AsyncEngine(eng) as fe:
            for p in prompts(cfg, 4, 16):
                await fe.submit(p, max_new_tokens=4)
            outs = []
            async for out in fe.stream(prompts(cfg, 1, 16, seed=1)[0],
                                       max_new_tokens=4,
                                       slo=SLO(ttft_ms=1000.0)):
                outs.append(out)
            assert len(outs) == 1 and outs[0].finished
            assert outs[0].finish_reason == "shed"
            assert outs[0].new_token_ids == []
            await fe.drain()

    asyncio.run(go())
    assert eng.counts()["shed"] == 1
    assert_pools_free(eng)


def test_admission_shed_set_is_deterministic():
    """Fixed tick_cost_s + trace-order replay: which requests shed is a
    pure function of the submission sequence — two fresh engines agree
    request by request."""
    cfg = tiny_cfg()
    tc = TrafficConfig(
        tenants=(TenantSpec(name="t", rate_rps=50.0, prompt_len=(12, 16),
                            output_len=(4, 8), slo=SLO(ttft_ms=3000.0)),),
        duration_s=0.5, seed=3, vocab_size=cfg.vocab_size)
    events = synthesize(tc)
    assert len(events) >= 10

    def run_once():
        eng = host_engine(cfg, shed_cfg())

        async def go():
            async with AsyncEngine(eng) as fe:
                return await replay(fe, events, time_scale=0)

        rep = asyncio.run(go())
        return [(r.req_id, r.finish_reason) for r in rep.results], rep

    a, rep_a = run_once()
    b, rep_b = run_once()
    assert a == b
    assert rep_a.n_shed == rep_b.n_shed > 0      # overloaded: some refused
    assert rep_a.n_served == rep_b.n_served > 0  # but never everything


# ---------------------------------------------------------------------------
# traffic synthesis
# ---------------------------------------------------------------------------


def test_synthesize_is_deterministic_and_tenant_independent():
    base = dict(rate_rps=20.0, prompt_len=(8, 16), output_len=(2, 4))
    one = TrafficConfig(tenants=(TenantSpec(name="a", **base),),
                        duration_s=1.0, seed=9)
    two = TrafficConfig(tenants=(TenantSpec(name="a", **base),
                                 TenantSpec(name="b", arrival="onoff",
                                            on_s=0.2, off_s=0.2, **base)),
                        duration_s=1.0, seed=9)
    ev1 = synthesize(one)
    ev1b = synthesize(one)
    assert [(e.t, e.max_new_tokens) for e in ev1] == \
        [(e.t, e.max_new_tokens) for e in ev1b]
    assert all(np.array_equal(x.prompt, y.prompt)
               for x, y in zip(ev1, ev1b))
    # per-tenant seeded streams: adding tenant b never perturbs tenant a
    a_only = [(e.t, tuple(e.prompt)) for e in ev1]
    a_in_two = [(e.t, tuple(e.prompt)) for e in synthesize(two)
                if e.tenant == "a"]
    assert a_in_two == a_only
    assert any(e.tenant == "b" for e in synthesize(two))
    for e in ev1:
        assert 8 <= len(e.prompt) <= 16 and 2 <= e.max_new_tokens <= 4
        assert 0.0 <= e.t < 1.0


def test_synthesize_shared_prefix_pools():
    tc = TrafficConfig(
        tenants=(TenantSpec(name="rag", rate_rps=40.0, prompt_len=(12, 20),
                            output_len=(2, 2), shared_prefix_len=8,
                            n_prefixes=2),),
        duration_s=1.0, seed=4)
    events = synthesize(tc)
    assert len(events) >= 10
    heads = {tuple(e.prompt[:8]) for e in events}
    assert 1 <= len(heads) <= 2                  # drawn from the fixed pool


def test_traffic_validation():
    with pytest.raises(ValueError):
        TenantSpec(name="x", rate_rps=0.0)
    with pytest.raises(ValueError):
        TenantSpec(name="x", rate_rps=1.0, arrival="uniform")
    with pytest.raises(ValueError):
        TenantSpec(name="x", rate_rps=1.0, prompt_len=(0, 4))
    with pytest.raises(ValueError):
        # a prompt needs at least one non-shared token
        TenantSpec(name="x", rate_rps=1.0, prompt_len=(8, 16),
                   shared_prefix_len=8)
    with pytest.raises(ValueError):
        TrafficConfig(tenants=(), duration_s=1.0)
    with pytest.raises(ValueError):
        TrafficConfig(tenants=(TenantSpec(name="a", rate_rps=1.0),
                               TenantSpec(name="a", rate_rps=2.0)),
                      duration_s=1.0)

    async def bad_scale():
        eng = host_engine(tiny_cfg(), serve_cfg())
        async with AsyncEngine(eng) as fe:
            await replay(fe, [], time_scale=-1.0)

    with pytest.raises(ValueError):
        asyncio.run(bad_scale())


def test_replay_report_windows_are_per_replay():
    """Counter snapshots: a second replay on the same engine reports its
    own window, not the engine's lifetime totals."""
    cfg = tiny_cfg()
    eng = host_engine(cfg, serve_cfg(max_batch=2))
    tc = TrafficConfig(
        tenants=(TenantSpec(name="t", rate_rps=20.0, prompt_len=(8, 12),
                            output_len=(2, 3), slo=SLO(ttft_ms=60_000.0)),),
        duration_s=0.4, seed=6, vocab_size=cfg.vocab_size)
    events = synthesize(tc)

    async def go():
        async with AsyncEngine(eng) as fe:
            r1 = await replay(fe, events, time_scale=0)
            r2 = await replay(fe, events, time_scale=0)
            return r1, r2

    r1, r2 = asyncio.run(go())
    assert r1.n_requests == r2.n_requests == len(events)
    assert r1.total_tokens == r2.total_tokens
    assert r2.slo_total == len(events)           # window, not 2x lifetime
    assert r1.goodput == r2.goodput == 1.0


# ---------------------------------------------------------------------------
# the acceptance experiment: shed-before-thrash under forced overload
# ---------------------------------------------------------------------------


class SlowHostOnlyEngine(HostOnlyEngine):
    """Host-only programs slowed to a deterministic per-phase cost so
    wall-clock SLOs and arrival pacing mean something without real
    device work (a tick costs 1-2 sleeps, far above timer jitter)."""

    TICK_SLEEP = 0.003

    def _program(self, group, kind):
        run = super()._program(group, kind)

        def slow_run(*args):
            time.sleep(self.TICK_SLEEP)          # inside the tick wall
            return run(*args)

        return slow_run


_OVERLOAD_SC = dict(max_batch=4, page_size=8, n_pages=14, max_len=64,
                    prefill_chunk=8, max_prefill_tokens=16)


def test_overload_shedding_beats_preemption_thrash():
    """Acceptance: on a forced-overload Poisson trace, the admission
    controller must yield STRICTLY fewer preemptions and STRICTLY higher
    SLO goodput than the shedding-disabled twin.  Same seeded trace, same
    engine geometry — the ONLY difference is ``ServeConfig.admission``.

    Geometry forces the off-twin to thrash: 4 decode slots of grown
    requests need ~18 pages of a 16-page pool, so every late admission
    evicts a victim; the deadline is calibrated from this machine's
    measured unloaded latency so the experiment is speed-independent."""
    cfg = tiny_cfg()

    def fresh(admission):
        return SlowHostOnlyEngine(cfg, cached_params(cfg),
                                  serve_cfg(admission=admission,
                                            **_OVERLOAD_SC))

    # calibrate unloaded service on this machine (3 requests < pool)
    cal = fresh(None)
    for p in prompts(cfg, 3, 24, seed=8):
        cal.submit(p, max_new_tokens=8)
    t0 = time.monotonic()
    cal.run_until_drained()
    wall_cal = time.monotonic() - t0
    reqs = cal.done
    ttft_cal = quantile([r.ttft for r in reqs], 0.5)
    tpot_cal = quantile([r.tpot for r in reqs], 0.5)

    slo = SLO(ttft_ms=max(6 * ttft_cal * 1e3, 1.0),
              tpot_ms=max(5 * tpot_cal * 1e3, 0.1))
    # ~6x the measured service rate, for a horizon of ~3 service waves:
    # the off-twin's queue outgrows its deadline within the first wave
    # and never recovers, while the shedding twin keeps attaining at
    # service rate for the whole horizon — that is the goodput gap
    events = synthesize(TrafficConfig(
        tenants=(TenantSpec(name="burst", rate_rps=6 * 3 / wall_cal,
                            prompt_len=(20, 28), output_len=(8, 8),
                            slo=slo),),
        duration_s=3 * wall_cal, seed=11, vocab_size=cfg.vocab_size))
    assert len(events) >= 12                     # genuinely overloaded

    def run_twin(admission):
        eng = fresh(admission)
        for ev in events[:4]:                    # compile/EMA warmup
            eng.submit(ev.prompt, max_new_tokens=ev.max_new_tokens)
        eng.run_until_drained()

        async def go():
            async with AsyncEngine(eng) as fe:
                return await replay(fe, events, time_scale=1.0)

        rep = asyncio.run(go())
        assert_pools_free(eng)
        return rep

    rep_off = run_twin(None)
    rep_on = run_twin(AdmissionConfig())
    assert rep_off.n_preemptions >= 1, "off-twin never thrashed"
    assert rep_on.n_shed > 0, "overload never tripped admission"
    assert rep_on.n_preemptions < rep_off.n_preemptions
    assert rep_on.goodput > rep_off.goodput
