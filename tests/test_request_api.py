"""Request-centric serving API: per-request SamplingParams (mixed
greedy/stochastic batches in one program), incremental RequestOutput
streaming, abort at every lifecycle stage, and the ttft/tpot guards."""

import dataclasses
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving import (
    RequestOutput,
    RequestState,
    SamplingParams,
    ServeConfig,
    ServingEngine,
    SpecConfig,
)
from repro.serving.sampling import (
    sample_tokens_rows,
    row_keys,
    verify_draft,
    verify_draft_rows,
)
from repro.serving.scheduler import PhaseAwareConfig


def tiny_cfg(name="qwen3-1.7b"):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


_PARAMS = {}


def cached_params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def make_engine(cfg, max_batch=3, *, paged=False, prefix_cache=False,
                spec=None, page_size=8, n_pages=48, max_len=96,
                prefill_chunk=16, max_prefill_tokens=32, **sc_kw):
    sc = ServeConfig(max_batch=max_batch, max_len=max_len,
                     phase=PhaseAwareConfig(
                         max_decode_batch=max_batch,
                         prefill_chunk=prefill_chunk,
                         max_prefill_tokens=max_prefill_tokens),
                     paged=paged, page_size=page_size, n_pages=n_pages,
                     prefix_cache=prefix_cache, speculative=spec, **sc_kw)
    return ServingEngine(cfg, cached_params(cfg), sc)


def prompts(cfg, n, L, seed=0, repeat_suffix=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        p = rng.integers(0, cfg.vocab_size, (L,), dtype=np.int32)
        if repeat_suffix > 0:
            # tile a short block so the n-gram drafter has hits
            block = p[:repeat_suffix]
            p = np.tile(block, -(-L // repeat_suffix))[:L]
        out.append(p)
    return out


# ---------------------------------------------------------------------------
# SamplingParams: validation and the greedy/temperature unification
# ---------------------------------------------------------------------------


def test_sampling_params_defaults_and_validation():
    sp = SamplingParams()
    assert sp.greedy and sp.temperature == 0.0 and sp.stop == ()
    assert not SamplingParams(temperature=0.5).greedy
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(max_new_tokens=-1)
    # stop normalizes to an int tuple (hashable, device-independent)
    assert SamplingParams(stop=[np.int32(3), 7]).stop == (3, 7)


def test_serveconfig_legacy_fields_shim_and_warning():
    cfg = tiny_cfg()
    # defaults: no warning, default sampling is greedy
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        eng = make_engine(cfg)
        assert not any(issubclass(x.category, DeprecationWarning)
                       for x in w)
    assert eng._default_sampling.greedy
    # legacy fields still work but warn, and map onto SamplingParams
    with pytest.warns(DeprecationWarning, match="SamplingParams"):
        eng = make_engine(cfg, greedy=False, temperature=0.7, top_k=5)
    sp = eng._default_sampling
    assert (sp.temperature, sp.top_k) == (0.7, 5) and not sp.greedy
    # legacy greedy=True maps to temperature 0 whatever temperature says
    assert ServeConfig(greedy=True, temperature=0.9).default_sampling() \
        .greedy


# ---------------------------------------------------------------------------
# vectorized sampling: per-row params in one program
# ---------------------------------------------------------------------------


def test_sample_tokens_rows_mixed_greedy_rows_are_argmax():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16)),
                         jnp.float32)
    keys = row_keys(jnp.arange(4, dtype=jnp.int32),
                    jnp.zeros((4,), jnp.int32))
    toks = sample_tokens_rows(
        logits, jnp.asarray([0.0, 1.0, 0.0, 1.0]),
        jnp.zeros((4,), jnp.int32), jnp.zeros((4,), jnp.float32), keys)
    am = np.argmax(np.asarray(logits), axis=-1)
    assert int(toks[0]) == am[0] and int(toks[2]) == am[2]


def test_sample_tokens_rows_per_row_top_k():
    # row 0: top_k=2 over [9, 8, 0, 0]; row 1: unrestricted over the
    # mirrored logits — candidate sets must stay per-row
    logits = jnp.array([[9.0, 8.0, 0.0, 0.0],
                        [0.0, 0.0, 8.0, 9.0]])
    seen0, seen1 = set(), set()
    for i in range(60):
        keys = row_keys(jnp.asarray([i, 1000 + i], jnp.int32),
                        jnp.zeros((2,), jnp.int32))
        a, b = np.asarray(sample_tokens_rows(
            logits, jnp.asarray([0.7, 0.7]), jnp.asarray([2, 0]),
            jnp.zeros((2,), jnp.float32), keys))
        seen0.add(int(a))
        seen1.add(int(b))
    assert seen0 <= {0, 1}
    assert seen1 <= {2, 3}               # peaked logits, any token legal


def test_sample_tokens_rows_reproducible_by_seed():
    logits = jnp.asarray(np.random.default_rng(1).normal(size=(1, 32)),
                         jnp.float32)

    def draw(seed, counter):
        keys = row_keys(jnp.asarray([seed], jnp.int32),
                        jnp.asarray([counter], jnp.int32))
        return int(sample_tokens_rows(
            logits, jnp.asarray([0.9]), jnp.zeros((1,), jnp.int32),
            jnp.zeros((1,), jnp.float32), keys)[0])

    assert draw(7, 3) == draw(7, 3)      # pure function of (seed, counter)
    draws = {draw(7, c) for c in range(20)}
    assert len(draws) > 1                # the counter advances the chain


def test_verify_draft_rows_greedy_rows_match_scalar():
    rng = np.random.default_rng(2)
    logits = jnp.asarray(rng.normal(size=(3, 4, 16)), jnp.float32)
    draft = jnp.asarray(rng.integers(0, 16, (3, 3)), jnp.int32)
    dlen = jnp.asarray([3, 2, 1], jnp.int32)
    t_ref, n_ref = verify_draft(logits, draft, dlen, greedy=True)
    keys = row_keys(jnp.arange(3, dtype=jnp.int32),
                    jnp.zeros((3,), jnp.int32))
    t_mix, n_mix = verify_draft_rows(
        logits, draft, dlen, jnp.asarray([0.0, 0.8, 0.0]),
        jnp.zeros((3,), jnp.int32), jnp.zeros((3,), jnp.float32), keys)
    # greedy rows (0, 2) are bit-identical to the scalar greedy rule
    for r in (0, 2):
        n = int(n_ref[r])
        assert int(n_mix[r]) == n
        assert np.asarray(t_mix[r][:n]).tolist() == \
            np.asarray(t_ref[r][:n]).tolist()
    assert 1 <= int(n_mix[1]) <= int(dlen[1]) + 1


# ---------------------------------------------------------------------------
# step() -> incremental RequestOutputs; stream()/generate() facades
# ---------------------------------------------------------------------------


def test_step_returns_incremental_outputs():
    cfg = tiny_cfg()
    eng = make_engine(cfg)
    rs = [eng.submit(p, max_new_tokens=5) for p in prompts(cfg, 3, 12)]
    streams, finals = {}, {}
    while eng.queue or any(r is not None for r in eng.slot_req):
        for out in eng.step():
            assert isinstance(out, RequestOutput)
            streams.setdefault(out.req_id, []).extend(out.new_token_ids)
            assert out.n_generated == len(streams[out.req_id])
            if out.finished:
                finals[out.req_id] = out.finish_reason
    for r in rs:
        assert streams[r.req_id] == r.generated
        assert finals[r.req_id] == "length" == r.finish_reason
    c = eng.counts()
    assert (c["queued"], c["active"], c["done"]) == (0, 0, 3)
    # a colocated engine with no host tier moves and spills nothing
    assert all(c[k] == 0 for k in (
        "migrated_pages", "migrated_bytes", "swap_out_bytes",
        "swap_in_bytes", "swap_resumes", "host_resident_pages"))


def test_stream_yields_before_drain_and_generate_orders():
    cfg = tiny_cfg()
    eng = make_engine(cfg)
    for p in prompts(cfg, 3, 10):
        eng.submit(p, max_new_tokens=6)
    pre_drain = 0
    for out in eng.stream():
        if not out.finished and eng.counts()["done"] == 0:
            pre_drain += 1
    assert pre_drain > 0                  # tokens observable mid-flight
    assert eng.counts()["done"] == 3

    eng2 = make_engine(cfg)
    rs = eng2.generate(prompts(cfg, 3, 10),
                       SamplingParams(max_new_tokens=6))
    assert [r.req_id for r in rs] == sorted(r.req_id for r in rs)
    assert all(r.state == RequestState.DONE for r in rs)
    with pytest.raises(ValueError):
        eng2.generate(prompts(cfg, 2, 8), [SamplingParams()])


def test_finish_reasons_eos_stop_length():
    cfg = tiny_cfg()
    p = prompts(cfg, 1, 12)[0]
    probe = make_engine(cfg)
    first = probe.generate([p.copy()],
                           SamplingParams(max_new_tokens=1))[0].generated[0]
    eng = make_engine(cfg)
    r_eos = eng.submit(p.copy(), sampling=SamplingParams(
        max_new_tokens=8, eos_id=first))
    r_stop = eng.submit(p.copy(), sampling=SamplingParams(
        max_new_tokens=8, stop=(first,)))
    r_len = eng.submit(p.copy(), sampling=SamplingParams(max_new_tokens=2))
    eng.run_until_drained()
    assert r_eos.finish_reason == "eos" and r_eos.generated == [first]
    assert r_stop.finish_reason == "stop" and r_stop.generated == [first]
    assert r_len.finish_reason == "length" and len(r_len.generated) == 2


def test_max_new_tokens_zero_and_latency_guards():
    cfg = tiny_cfg()
    eng = make_engine(cfg, paged=True)
    r = eng.generate([prompts(cfg, 1, 12)[0]],
                     SamplingParams(max_new_tokens=0))[0]
    assert r.state == RequestState.DONE and r.generated == []
    assert r.finish_reason == "length"
    assert math.isnan(r.ttft) and math.isnan(r.tpot)   # no sentinel garbage
    assert eng.pool.free_pages() == eng.pool.n_pages   # pages all returned
    # a request with exactly one token has a defined ttft and tpot
    eng2 = make_engine(cfg)
    r1 = eng2.generate([prompts(cfg, 1, 12)[0]],
                       SamplingParams(max_new_tokens=1))[0]
    assert r1.ttft > 0 and not math.isnan(r1.tpot)


# ---------------------------------------------------------------------------
# mixed-batch identity: greedy rows bit-identical to an all-greedy run
# across dense / paged / prefix-cache / speculative engines
# ---------------------------------------------------------------------------


MODES = ["dense", "paged", "prefix", "spec"]


def _mode_engine(cfg, mode, max_batch=2):
    # fewer slots than requests: later admissions see published prefix
    # pages (the cache has something to hit) and slots get recycled
    if mode == "dense":
        return make_engine(cfg, max_batch)
    if mode == "paged":
        return make_engine(cfg, max_batch, paged=True)
    if mode == "prefix":
        return make_engine(cfg, max_batch, paged=True, prefix_cache=True)
    return make_engine(cfg, max_batch, paged=True, spec=SpecConfig(k=3))


def _mode_prompts(cfg):
    # a shared 16-token head (prefix-cache hits) and a repeated suffix
    # (n-gram drafter hits) so every mode exercises its machinery
    head = prompts(cfg, 1, 16, seed=11, repeat_suffix=5)[0]
    return [np.concatenate([head, t]) for t in
            prompts(cfg, 4, 8, seed=12)]


@pytest.fixture(scope="module")
def greedy_reference():
    """All-greedy streams from the dense engine — the cross-mode oracle
    (dense==paged, cache on/off, spec on/off identities already hold)."""
    cfg = tiny_cfg()
    eng = make_engine(cfg, 4)
    rs = eng.generate([p.copy() for p in _mode_prompts(cfg)],
                      SamplingParams(max_new_tokens=10))
    return [r.generated for r in rs]


@pytest.mark.parametrize("mode", MODES)
def test_mixed_batch_greedy_rows_bit_identical(mode, greedy_reference):
    cfg = tiny_cfg()
    ps = _mode_prompts(cfg)
    sps = [SamplingParams(max_new_tokens=10) if i % 2 == 0 else
           SamplingParams(temperature=0.8, seed=40 + i, max_new_tokens=10)
           for i in range(len(ps))]
    eng = _mode_engine(cfg, mode)
    rs = eng.generate([p.copy() for p in ps], sps)
    for i, r in enumerate(rs):
        if sps[i].greedy:
            assert r.generated == greedy_reference[i], \
                f"{mode}: mixed batch changed greedy row {i}"
    if mode == "prefix":
        assert eng.prefix_stats()["hit_rate"] > 0
    if mode == "spec":
        assert eng.spec_windows > 0      # verify windows actually ran


def test_stochastic_rows_reproducible_across_modes_and_batches():
    """A seeded stochastic request draws from its own (seed, counter)
    chain: the same stream whatever engine layout or batch it rides in
    (speculative excluded — resampling consumes draws differently)."""
    cfg = tiny_cfg()
    ps = _mode_prompts(cfg)
    sps = [SamplingParams(max_new_tokens=10) if i % 2 == 0 else
           SamplingParams(temperature=0.8, seed=40 + i, max_new_tokens=10)
           for i in range(len(ps))]
    streams = {}
    for mode in ("dense", "paged", "prefix"):
        eng = _mode_engine(cfg, mode)
        rs = eng.generate([p.copy() for p in ps], sps)
        streams[mode] = [r.generated for i, r in enumerate(rs)
                         if not sps[i].greedy]
    assert streams["dense"] == streams["paged"] == streams["prefix"]
    # ...and solo: same request alone reproduces its batched stream
    solo = make_engine(cfg, 1).generate([ps[1].copy()], sps[1])[0]
    assert solo.generated == streams["dense"][0]


def test_mixed_batch_keeps_single_host_transfer(monkeypatch):
    """Per-slot sampling runs INSIDE the jitted program: a mixed decode
    tick still moves exactly one [B]-shaped token array to the host."""
    cfg = tiny_cfg()
    eng = make_engine(cfg, max_batch=3, max_prefill_tokens=64)
    ps = prompts(cfg, 3, 8)
    for i, p in enumerate(ps):
        eng.submit(p, sampling=SamplingParams(
            temperature=0.0 if i % 2 == 0 else 0.9, seed=i,
            max_new_tokens=8))
    eng.step()                            # prefill tick: all decoding
    assert all(r is not None and r.state == RequestState.DECODING
               for r in eng.slot_req)
    transfers = []
    orig = ServingEngine._to_host

    def counting(self, arr):
        transfers.append(np.asarray(arr).shape)
        return orig(self, arr)

    monkeypatch.setattr(ServingEngine, "_to_host", counting)
    eng.step()                            # pure mixed decode tick
    assert transfers == [(eng.sc.max_batch,)]


def test_mixed_batch_host_transfers_match_all_greedy():
    """Acceptance criterion: per-request sampling must not add host
    transfers — an equal-tick mixed run moves exactly as many arrays as
    the all-greedy run."""
    cfg = tiny_cfg()
    ps = prompts(cfg, 4, 12)
    runs = {}
    for label, stoch in (("greedy", False), ("mixed", True)):
        eng = make_engine(cfg, 4, paged=True)
        sps = [SamplingParams(temperature=0.9 if stoch and i % 2 else 0.0,
                              seed=i, max_new_tokens=8)
               for i in range(len(ps))]
        eng.generate([p.copy() for p in ps], sps)
        runs[label] = (eng.n_ticks, eng.host_transfers)
    assert runs["mixed"] == runs["greedy"]


# ---------------------------------------------------------------------------
# abort: every lifecycle stage releases pages, pins, and drafter state
# ---------------------------------------------------------------------------


def test_abort_waiting_request_never_runs():
    cfg = tiny_cfg()
    eng = make_engine(cfg, max_batch=1)
    a = eng.submit(prompts(cfg, 1, 8)[0], max_new_tokens=4)
    b = eng.submit(prompts(cfg, 1, 8, seed=1)[0], max_new_tokens=4)
    out = eng.abort(b.req_id)             # still WAITING in the queue
    assert out.finished and out.finish_reason == "abort"
    assert out.n_generated == 0 and b.finish_reason == "abort"
    assert math.isnan(b.ttft)
    eng.run_until_drained()
    assert len(a.generated) == 4 and b.generated == []
    assert eng.abort(b.req_id) is None    # already finished: no-op
    assert eng.abort(12345) is None       # unknown id: no-op


@pytest.mark.parametrize("stage", ["prefilling", "decoding", "mid_verify"])
def test_abort_stages_conserve_pages_and_survivors(stage):
    """Abort mid-PREFILL, mid-DECODE, and between speculative verify
    windows: pages return to the pool, pool invariants hold, and the
    surviving greedy streams are bit-identical to an abort-free run."""
    cfg = tiny_cfg()
    spec = SpecConfig(k=3) if stage == "mid_verify" else None
    ps = [np.concatenate(pair) for pair in zip(
        prompts(cfg, 3, 24, seed=21, repeat_suffix=5),
        prompts(cfg, 3, 8, seed=22))]
    ref_eng = make_engine(cfg, 3, paged=True, spec=spec)
    ref = [r.generated for r in ref_eng.generate(
        [p.copy() for p in ps], SamplingParams(max_new_tokens=10))]

    eng = make_engine(cfg, 3, paged=True, spec=spec)
    rs = [eng.submit(p.copy(), sampling=SamplingParams(max_new_tokens=10))
          for p in ps]
    victim = rs[1]
    if stage == "prefilling":
        eng.step()                        # chunk 16 of 32: mid-prefill
        assert victim.state == RequestState.PREFILLING
        eng.abort(victim.req_id)
    else:
        while victim.state != RequestState.DECODING:
            eng.step()
        if stage == "mid_verify":
            while not victim.generated:   # at least one window committed
                eng.step()
        eng.abort(victim.req_id)
    assert victim.slot == -1 and victim.finish_reason == "abort"
    for p_ in eng.pool.pools:
        p_.check_invariants()
    eng.run_until_drained()
    assert eng.pool.free_pages() == eng.pool.n_pages
    for i, r in enumerate(rs):
        if r is not victim:
            assert r.generated == ref[i], f"abort changed survivor {i}"


def test_abort_never_strands_prefix_pins():
    """Aborting requests that attached cached prefix pages must leave the
    cache's pins intact and reclaimable: after drain + flush, every page
    is free again, and surviving requests still hit the cache."""
    cfg = tiny_cfg()
    eng = make_engine(cfg, 2, paged=True, prefix_cache=True, n_pages=32)
    head = prompts(cfg, 1, 16, seed=31)[0]
    ps = [np.concatenate([head, t]) for t in prompts(cfg, 4, 8, seed=32)]
    # first request publishes the head; the rest attach to it
    r0 = eng.generate([ps[0].copy()], SamplingParams(max_new_tokens=4))[0]
    assert r0.finish_reason == "length"
    rs = [eng.submit(p.copy(), sampling=SamplingParams(max_new_tokens=6))
          for p in ps[1:]]
    eng.step()                            # attach + begin prefill
    eng.abort(rs[0].req_id)               # holder of shared pages aborts
    for p_ in eng.pool.pools:
        p_.check_invariants()
    eng.run_until_drained()
    assert all(r.cached_tokens > 0 for r in rs[1:])   # cache still serves
    assert eng.prefix_stats()["hit_rate"] > 0
    # cache pins are the only remaining references; flushing frees all
    eng.prefix.flush(eng.pool)
    assert eng.pool.free_pages() == eng.pool.n_pages
    for p_ in eng.pool.pools:
        p_.check_invariants()


def test_abort_releases_draft_pool_state():
    cfg = tiny_cfg()
    spec = SpecConfig(k=3, drafter="model", draft_arch="qwen3-1.7b")
    eng = make_engine(cfg, 2, paged=True, spec=spec)
    ps = prompts(cfg, 2, 12, seed=41)
    rs = [eng.submit(p.copy(), sampling=SamplingParams(max_new_tokens=8))
          for p in ps]
    while not rs[0].generated:            # drafter has slot state now
        eng.step()
    eng.abort(rs[0].req_id)
    assert eng.drafter.owner[0] == -1 or eng.drafter.lens[0] == 0
    eng.run_until_drained()
    assert eng.drafter.pool.free_pages() == eng.drafter.pool.n_pages
    assert eng.pool.free_pages() == eng.pool.n_pages


def test_derived_seeds_are_plain_ints_without_overflow():
    """Regression: the derived-seed mix used np.uint32 scalar arithmetic,
    which overflows for any ServeConfig.seed >= 2 (NumPy 2 warns per
    submit, and raises OverflowError for a negative seed)."""
    cfg = tiny_cfg()
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # any warning -> failure
        for base in (2, 12345, -3):
            eng = make_engine(cfg, seed=base)
            reqs = [eng.submit(p, max_new_tokens=1)
                    for p in prompts(cfg, 3, 6)]
            seeds = [r.seed for r in reqs]
            assert all(0 <= s < 2**31 for s in seeds)
            assert len(set(seeds)) == len(seeds)  # distinct per request


# ---------------------------------------------------------------------------
# hypothesis: abort interleaved with submit/step/preempt/evict conserves
# refcounts (host-only engine: device programs stubbed, accounting real)
# ---------------------------------------------------------------------------


class HostOnlyEngine(ServingEngine):
    """ServingEngine with the device programs stubbed out: every sampled
    token is 0 and the KV arrays are never touched, but admission, page
    accounting, COW, prefix attach/publish, preemption, speculative
    grow/truncate, and abort all run for real — fast enough to drive
    under hypothesis."""

    _CACHE_ARG = {"chunk": 5, "chunk_paged": 5, "whole": 3,
                  "packed": 6, "packed_paged": 6,
                  "decode": 2, "decode_paged": 2, "verify": 5}

    def _program(self, group, kind):
        cache_arg = self._CACHE_ARG[kind]

        def run(*args):
            cache = args[cache_arg]
            if kind == "verify":
                draft = np.asarray(args[7])
                out = np.zeros((draft.shape[0], draft.shape[1] + 2),
                               np.int32)
                out[:, -1] = 1            # accept nothing, emit one token
                return jnp.asarray(out), cache
            if kind in ("packed", "packed_paged"):
                n = np.asarray(args[2]).shape[0]  # one row per segment
            else:
                n = 1 if kind == "whole" else np.asarray(args[1]).shape[0]
            return jnp.zeros((n,), jnp.int32), cache

        return run

    def _copy_pages(self, copies):
        self.cow_copies += len(copies)    # accounting only, no device copy


def test_same_tick_preemption_still_reports_gained_tokens():
    """Regression: a request that completed prefill (gaining its seeding
    token) and was then chosen as a preemption victim later in the SAME
    tick ended the tick back in the queue — outside both the slot-holder
    and retired-this-tick lists — so its token never appeared in any
    RequestOutput and the reassembled stream disagreed with
    ``Request.generated``."""
    cfg = tiny_cfg()

    class PreemptAfterPrefill(HostOnlyEngine):
        preempt_next = False

        def _run_prefill_tick(self, plan):
            super()._run_prefill_tick(plan)
            if self.preempt_next:
                for r in self.slot_req:
                    if r is not None and r.state == RequestState.DECODING \
                            and r.generated:
                        self.preempt_next = False
                        self._preempt(r)
                        break

    eng = PreemptAfterPrefill(cfg, cached_params(cfg), ServeConfig(
        max_batch=2, max_len=64,
        phase=PhaseAwareConfig(max_decode_batch=2, prefill_chunk=8,
                               max_prefill_tokens=16),
        paged=True, page_size=4, n_pages=16))
    eng.preempt_next = True
    r = eng.submit(prompts(cfg, 1, 8)[0], max_new_tokens=4)
    streamed = []
    for out in eng.stream():
        streamed.extend(out.new_token_ids)
    assert r.n_preempted == 1             # the scenario actually fired
    assert r.state == RequestState.DONE
    assert streamed == r.generated        # nothing dropped, nothing doubled


try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(ops=st.lists(
        st.tuples(st.integers(0, 3),      # 0 submit, 1 step, 2 abort,
                                          # 3 step+abort-youngest
                  st.integers(0, 7),      # prompt selector / abort target
                  st.integers(1, 30)),    # prompt length
        max_size=30))
    def test_abort_interleavings_conserve_refcounts(ops):
        """ANY interleaving of submit / step / abort on a small paged
        pool with the prefix cache and n-gram speculation on (so
        attach/publish, COW, window grow/truncate, and preemption all
        fire) keeps every run pool's refcount conservation, and ends
        with every page free once the cache is flushed."""
        cfg = tiny_cfg()
        eng = HostOnlyEngine(cfg, cached_params(cfg), ServeConfig(
            max_batch=2, max_len=64,
            phase=PhaseAwareConfig(max_decode_batch=2, prefill_chunk=8,
                                   max_prefill_tokens=16),
            paged=True, page_size=4, n_pages=12, prefix_cache=True,
            speculative=SpecConfig(k=2)))
        submitted = []
        for kind, sel, length in ops:
            if kind == 0:
                # low-diversity prompts: shared prefixes are common, so
                # attach/publish/COW paths all run
                prompt = np.full((min(length, 30),), sel % 3, np.int32)
                try:
                    submitted.append(eng.submit(
                        prompt, sampling=SamplingParams(max_new_tokens=6)))
                except ValueError:
                    pass                  # longer than the pool: rejected
            elif kind == 1:
                eng.step()
            elif kind == 2 and submitted:
                eng.abort(submitted[sel % len(submitted)].req_id)
            elif kind == 3:
                eng.step()
                live = [r for r in eng.slot_req if r is not None]
                if live:
                    eng.abort(max(live, key=lambda r: r.req_id).req_id)
            for p in eng.pool.pools:
                p.check_invariants()
        for _ in range(200):
            if not (eng.queue or any(r is not None for r in eng.slot_req)):
                break
            eng.step()
        eng.prefix.flush(eng.pool)
        for p in eng.pool.pools:
            p.check_invariants()
            assert p.free_pages() == p.n_pages, \
                "pages leaked across the interleaving"
