"""Shared-prefix KV reuse: radix cache indexing, refcounted copy-on-write
page sharing, cache-before-preemption eviction order, and the acceptance
bar — greedy token streams bit-identical with the cache on vs off (the
cache is a pure optimization) across GQA / sliding-window / MLA plans."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving.engine import RequestState, ServeConfig, ServingEngine
from repro.serving.kv_pool import KVPool
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import PhaseAwareConfig


def tiny_cfg(name="qwen3-1.7b"):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


_PARAMS = {}


def cached_params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def make_engine(cfg, max_batch=2, *, page_size=8, n_pages=24,
                prefill_chunk=16, max_prefill_tokens=32,
                prefix_cache=False):
    sc = ServeConfig(max_batch=max_batch, max_len=64,
                     phase=PhaseAwareConfig(max_decode_batch=max_batch,
                                            prefill_chunk=prefill_chunk,
                                            max_prefill_tokens=max_prefill_tokens),
                     paged=True, page_size=page_size, n_pages=n_pages,
                     prefix_cache=prefix_cache)
    return ServingEngine(cfg, cached_params(cfg), sc)


def shared_prefix_prompts(cfg, n, head_len, tail_len, seed=0):
    """n prompts opening with the same head (the system-prompt pattern)."""
    rng = np.random.default_rng(seed)
    head = rng.integers(0, cfg.vocab_size, (head_len,), dtype=np.int32)
    out = []
    for _ in range(n):
        tail = rng.integers(0, cfg.vocab_size, (tail_len,), dtype=np.int32)
        out.append(np.concatenate([head, tail]) if tail_len else head.copy())
    return out


# ---------------------------------------------------------------------------
# radix index over a real pool (host logic, no model)
# ---------------------------------------------------------------------------


def _pool_and_cache(n_pages=16, page_size=4):
    cfg = tiny_cfg()
    pool = KVPool(cfg, n_slots=4, n_pages=n_pages, page_size=page_size)
    return pool, PrefixCache(page_size, pool.shareable_capacity())


def test_radix_match_insert_dedupe():
    pool, pc = _pool_and_cache()
    toks = np.arange(12, dtype=np.int32)
    assert pc.match(toks) == (0, [])
    assert pool.grow(0, 12)
    assert pc.insert(toks, pool, 0) == 3          # 3 blocks of 4
    # full match (no cap): all 3 blocks
    matched, pages = pc.match(toks)
    assert matched == 12
    assert pages == pool.prefix_pages(0, 12)
    # partial prompt matches its whole blocks only
    matched, pages = pc.match(toks[:7])
    assert matched == 4 and len(pages[0]) == 1
    # a diverging block stops the walk
    other = toks.copy()
    other[5] = 99
    assert pc.match(other)[0] == 4
    # the max_tokens cap keeps >= 1 token to prefill
    assert pc.match(toks, max_tokens=11)[0] == 8
    # re-insert is a no-op (existing pages stay canonical)
    assert pc.insert(toks, pool, 0) == 0
    for p in pool.pools:
        p.check_invariants()


def test_cached_pages_survive_publisher_release():
    """Cache pins outlive the publishing request; eviction drops them and
    the pages return to the free list (no free-while-referenced)."""
    pool, pc = _pool_and_cache()
    toks = np.arange(8, dtype=np.int32)
    assert pool.grow(0, 8)
    pc.insert(toks, pool, 0)
    pool.release(0)                               # publisher retires
    assert pool.free_pages() < pool.n_pages       # cache still pins 2 pages
    matched, pages = pc.match(toks[:8])
    assert matched == 8                           # still matchable
    pool.attach(1, pages, 8)                      # new request shares them
    # pinned blocks are NOT evictable (freeing nothing, losing hits)...
    assert pc.evict(pool, 99) == 0
    assert len(pc) == 2
    # ...but flush drops them unconditionally; slot 1 then holds the
    # last references and the pages free with its release
    assert pc.flush(pool) == 0
    assert pool.free_pages() < pool.n_pages
    pool.release(1)
    assert pool.free_pages() == pool.n_pages
    for p in pool.pools:
        p.check_invariants()


def test_lru_evicts_leaf_first_oldest_first():
    pool, pc = _pool_and_cache()
    a = np.arange(8, dtype=np.int32)
    b = np.concatenate([a[:4], np.full(4, 77, np.int32)])
    assert pool.grow(0, 8) and pool.grow(1, 8)
    pc.insert(a, pool, 0)                         # chain: blk0 -> a1
    pc.insert(b, pool, 1)                         # shared blk0 -> b1
    pool.release(0)                               # publishers retire: only
    pool.release(1)                               # the cache pins the pages
    pc.match(a)                                   # a's chain is MRU
    n = len(pc)
    assert pc.evict(pool, 1) == 1                 # drops ONE leaf: b's tip
    assert len(pc) == n - 1
    assert pc.match(b)[0] == 4                    # b lost its tip
    assert pc.match(a)[0] == 8                    # a's chain intact
    pc.flush(pool)
    assert pool.free_pages() == pool.n_pages


def test_pinned_blocks_survive_transient_exhaustion():
    """Regression: when every cached page is pinned by live slots, a page
    shortage must NOT flush the cache block by block (each eviction frees
    nothing) — the blocks stay and serve hits once pressure passes."""
    pool, pc = _pool_and_cache(n_pages=4, page_size=4)
    toks = np.arange(16, dtype=np.int32)
    assert pool.grow(0, 16)                       # slot 0 holds the pool
    pc.insert(toks, pool, 0)                      # every block pinned
    assert pc.evict(pool, 1) == 0                 # nothing freeable
    assert len(pc) == 4                           # cache intact, hits live
    assert pc.match(toks)[0] == 16
    for p in pool.pools:
        p.check_invariants()


def test_prefix_cache_requires_paged():
    cfg = tiny_cfg()
    sc = ServeConfig(paged=False, prefix_cache=True)
    with pytest.raises(ValueError):
        ServingEngine(cfg, cached_params(cfg), sc)


# ---------------------------------------------------------------------------
# acceptance: identity cache-on vs cache-off, with real reuse happening
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,head,tail", [
    ("qwen3-1.7b", 24, 8),        # GQA
    ("gemma3-1b", 16, 6),         # sliding-window ring (COW on wrap)
    ("deepseek-v2-236b", 24, 6),  # MLA latent pages
])
def test_greedy_identity_cache_on_vs_off(arch, head, tail):
    """Greedy streams are bit-identical with the prefix cache on vs off,
    while the cache demonstrably works: hit rate > 0 and fewer prefill
    tokens executed on the same shared-system-prompt workload."""
    cfg = tiny_cfg(arch)
    ps = ([shared_prefix_prompts(cfg, 1, head, 0, seed=3)[0]]
          + shared_prefix_prompts(cfg, 3, head, tail, seed=3))
    outs, engines = {}, {}
    for pc in (False, True):
        eng = make_engine(cfg, prefix_cache=pc)
        rs = [eng.submit(p.copy(), max_new_tokens=8) for p in ps]
        eng.run_until_drained()
        outs[pc] = [r.generated for r in rs]
        engines[pc] = eng
    assert outs[False] == outs[True]
    s = engines[True].prefix_stats()
    assert s["hit_rate"] > 0
    assert s["hit_tokens"] > 0
    assert (engines[True].prefill_tokens_executed
            < engines[False].prefill_tokens_executed)
    # the pool is clean at drain: cached pages are the only residents
    pool = engines[True].pool
    for p in pool.pools:
        p.check_invariants()
        assert (p.ref[p.ref > 0] == p.external[p.ref > 0]).all(), \
            "a drained engine's only live refs are the cache's"


def test_cow_isolates_divergent_tails():
    """Two requests share a page-aligned prefix then diverge inside the
    next page; COW must keep the writers isolated (same outputs as the
    cache-off run) while the shared prefix pages stay deduplicated."""
    cfg = tiny_cfg()
    rng = np.random.default_rng(9)
    head = rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)
    ps = [np.concatenate([head, np.full((5,), t, np.int32)])
          for t in (7, 11, 13)]
    outs = {}
    for pc in (False, True):
        eng = make_engine(cfg, max_batch=1, prefix_cache=pc)  # sequential
        rs = [eng.submit(p.copy(), max_new_tokens=6) for p in ps]
        eng.run_until_drained()
        outs[pc] = [r.generated for r in rs]
        if pc:
            assert eng.prefix_stats()["hit_tokens"] >= 32  # 2 hits x 16
    assert outs[False] == outs[True]


def test_resumed_request_rematches_cache():
    """Recompute-on-resume goes through admission again, so a preempted
    request re-attaches the cached prefix instead of recomputing it."""
    cfg = tiny_cfg()
    ps = shared_prefix_prompts(cfg, 3, 16, 4, seed=5)
    solo = []
    for p in ps:
        eng = make_engine(cfg, max_batch=1, n_pages=24)
        r = eng.submit(p.copy(), max_new_tokens=10)
        eng.run_until_drained()
        solo.append(r.generated)
    # tight pool forces preemption mid-flight with the cache on
    eng = make_engine(cfg, max_batch=3, n_pages=8, prefix_cache=True)
    rs = [eng.submit(p.copy(), max_new_tokens=10) for p in ps]
    eng.run_until_drained(max_ticks=500)
    assert all(r.state == RequestState.DONE for r in rs)
    assert [r.generated for r in rs] == solo
    assert eng.preemptions > 0


# ---------------------------------------------------------------------------
# eviction order: cached pages yield before live requests are preempted
# ---------------------------------------------------------------------------


def test_cache_evicted_before_preemption():
    """A pool mostly squatted by cached pages must serve fresh no-reuse
    traffic by EVICTING the cache, not by preempting live requests."""
    cfg = tiny_cfg()
    eng = make_engine(cfg, max_batch=1, n_pages=8, prefix_cache=True)
    rng = np.random.default_rng(11)
    # publisher fills the cache (4 pages of prefix + pool churn)
    a = eng.submit(rng.integers(0, cfg.vocab_size, (32,), np.int32),
                   max_new_tokens=2)
    eng.run_until_drained()
    assert a.state == RequestState.DONE
    assert eng.prefix.cached_pages() > 0
    # an unrelated prompt needs more pages than the free list has left
    b = eng.submit(rng.integers(0, cfg.vocab_size, (40,), np.int32),
                   max_new_tokens=2)
    eng.run_until_drained(max_ticks=200)
    assert b.state == RequestState.DONE
    assert eng.cache_evicted_pages > 0           # the cache yielded
    assert eng.preemptions == 0                  # no live request did
    for p in eng.pool.pools:
        p.check_invariants()


def test_ring_wrap_gates_publication():
    """A sliding-window request whose prefilled length wrapped the ring
    publishes NOTHING (its early rows hold late positions); an unwrapped
    one publishes normally."""
    cfg = tiny_cfg("gemma3-1b")                  # window 16
    long_eng = make_engine(cfg, prefix_cache=True)
    p = shared_prefix_prompts(cfg, 1, 24, 0, seed=7)[0]   # 24 > ring 16
    long_eng.submit(p, max_new_tokens=2)
    long_eng.run_until_drained()
    assert long_eng.prefix.stats()["inserted_blocks"] == 0
    short_eng = make_engine(cfg, prefix_cache=True)
    short_eng.submit(p[:14], max_new_tokens=2)   # 14 + 2 <= 16: no wrap
    short_eng.run_until_drained()
    assert short_eng.prefix.stats()["inserted_blocks"] == 1
