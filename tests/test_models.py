"""Model behaviour: every reduced arch forward/train/prefill/decode, and the
core serving invariant — decode continuing a prefill reproduces the full
forward's logits (cache consistency), per family.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_archs
from repro.models.transformer import (
    build_plan,
    decode_step,
    forward_train,
    init_params,
    pad_cache,
    prefill,
)

ARCHS = list_archs()


def reduced(name):
    cfg = get_config(name).reduced()
    return dataclasses.replace(cfg, dtype="float32")


def make_batch(cfg, B, T, key):
    if cfg.n_codebooks > 1:
        tokens = jax.random.randint(key, (B, cfg.n_codebooks, T), 0,
                                    cfg.vocab_size)
    else:
        tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.frontend == "vision":
        F = cfg.n_frontend_tokens
        batch["tokens"] = tokens[:, : T - F]
        batch["vision_embeds"] = jax.random.normal(
            key, (B, F, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_forward_shapes_no_nan(arch):
    cfg = reduced(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = make_batch(cfg, 2, 32, key)
    logits, aux = forward_train(params, cfg, batch, remat=False)
    assert logits.shape[0] == 2
    assert not bool(jnp.any(jnp.isnan(logits))), arch
    assert float(aux) >= 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_remat_matches_no_remat(arch):
    cfg = reduced(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    batch = make_batch(cfg, 1, 32, key)
    a, _ = forward_train(params, cfg, batch, remat=False)
    b, _ = forward_train(params, cfg, batch, remat=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Greedy decode continuing a prefix must equal teacher-forced logits:
    prefill(t[0:P]) then decode_step(t[P]), ... vs forward over t[0:P+n].

    This exercises the KV/latent/SSM caches, ring buffers and rope offsets.
    """
    cfg = reduced(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    P, n_extra = 24, 4
    T = P + n_extra
    full = make_batch(cfg, 2, T, key)

    # teacher-forced full forward (train phase -> logits for every position)
    hidden_logits, _ = forward_train(params, cfg, full, remat=False)

    # prefill on the prefix
    if cfg.frontend == "vision":
        F = cfg.n_frontend_tokens
        pre = {"tokens": full["tokens"][:, : P - F],
               "vision_embeds": full["vision_embeds"]}
        toks = full["tokens"]
    elif cfg.n_codebooks > 1:
        pre = {"tokens": full["tokens"][..., :P]}
        toks = full["tokens"]
    else:
        pre = {"tokens": full["tokens"][:, :P]}
        toks = full["tokens"]
    pl_logits, cache = prefill(params, cfg, pre)
    cache = pad_cache(cfg, cache, P, T)

    np.testing.assert_allclose(
        np.asarray(pl_logits[:, -1], np.float32),
        np.asarray(hidden_logits[:, P - 1], np.float32),
        rtol=5e-3, atol=5e-3)

    # decode the remaining positions with teacher forcing
    for i in range(n_extra):
        pos = P + i
        if cfg.n_codebooks > 1:
            nt = toks[..., pos][..., None]
        elif cfg.frontend == "vision":
            nt = full["tokens"][:, pos - cfg.n_frontend_tokens][:, None]
        else:
            nt = toks[:, pos][:, None]
        dl, cache = decode_step(params, cfg, {"tokens": nt}, cache,
                                jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(dl[:, 0], np.float32),
            np.asarray(hidden_logits[:, pos], np.float32),
            rtol=5e-3, atol=5e-3)


def test_sliding_window_ring_buffer_wraps():
    """h2o-danube reduced has window 16 < T: the ring cache must agree with
    the full forward after wrapping."""
    cfg = reduced("h2o-danube-1.8b")
    assert cfg.attn.sliding_window == 16
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    T = 40                              # > 2x window -> several wraps
    batch = make_batch(cfg, 1, T, key)
    full_logits, _ = forward_train(params, cfg, batch, remat=False)
    pre = {"tokens": batch["tokens"][:, :32]}
    pl_logits, cache = prefill(params, cfg, pre)
    cache = pad_cache(cfg, cache, 32, T)
    np.testing.assert_allclose(np.asarray(pl_logits[:, -1]),
                               np.asarray(full_logits[:, 31]),
                               rtol=5e-3, atol=5e-3)
    for pos in range(32, T):
        nt = batch["tokens"][:, pos][:, None]
        dl, cache = decode_step(params, cfg, {"tokens": nt}, cache,
                                jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(dl[:, 0]),
                                   np.asarray(full_logits[:, pos]),
                                   rtol=5e-3, atol=5e-3)


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3-1b")
    kinds = cfg.layer_kinds()
    # 5 local then 1 global, repeating
    assert kinds[:6] == ["attn_local"] * 5 + ["attn_global"]
    assert kinds.count("attn_global") == cfg.n_layers // 6


def test_build_plan_run_structure():
    assert len(build_plan(get_config("qwen3-1.7b"))) == 1
    assert len(build_plan(get_config("deepseek-v2-236b"))) == 2  # dense|moe
    plan = build_plan(get_config("zamba2-2.7b"))
    kinds = [r.kind for r in plan]
    assert "shared_attn" in kinds and "ssm" in kinds


def test_param_counts_match_published_sizes():
    """Full-config parameter counts should land near the published sizes."""
    expect = {
        "mamba2-2.7b": (2.7e9, 0.08),
        "qwen3-1.7b": (2.0e9, 0.25),     # qwen3-1.7b is ~2.0B with embeddings
        "minicpm-2b": (2.7e9, 0.15),     # +embeddings (122k vocab)
        "gemma3-1b": (1.0e9, 0.30),
        "h2o-danube-1.8b": (1.8e9, 0.10),
        "internvl2-76b": (70e9, 0.12),   # backbone only (llama3-70b-like)
        "zamba2-2.7b": (2.7e9, 0.15),
        "arctic-480b": (480e9, 0.05),
        "deepseek-v2-236b": (236e9, 0.05),
        "musicgen-medium": (1.5e9, 0.35),  # 2048-vocab codebooks are small
        "llama2-7b": (6.7e9, 0.05),
        "qwen3-8b": (8.2e9, 0.10),
    }
    for name, (want, tol) in expect.items():
        got = get_config(name).param_count()
        assert abs(got - want) / want < tol, (
            f"{name}: {got/1e9:.2f}B vs published {want/1e9:.2f}B")


def test_moe_active_params_smaller():
    for name in ("arctic-480b", "deepseek-v2-236b"):
        cfg = get_config(name)
        assert cfg.active_param_count() < 0.2 * cfg.param_count()
