"""Dry-run launch-stack regression: compile real cells on the production
mesh (512 forced host devices) in a subprocess, assert the roofline row is
sane.  Slowish (~1 min) but this is the deliverable path — it must not rot.
"""

import os
import subprocess
import sys


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cells(code: str, timeout=560):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)        # dryrun.py sets its own, first thing
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout[-4000:]}\nSTDERR:\n{p.stderr[-4000:]}"
    return p.stdout


CODE = r"""
import json
from repro.launch import dryrun   # sets XLA_FLAGS before jax init

row = dryrun.run_cell("qwen3-1.7b", "decode_32k")
assert row["n_chips"] == 256
assert row["bottleneck"] == "memory", row["bottleneck"]
assert row["t_memory_s"] > row["t_compute_s"]
assert 0.5 < row["useful_flops_frac"] < 1.5, row["useful_flops_frac"]
print("CELL1-OK")

row = dryrun.run_cell("qwen3-1.7b", "decode_32k", multi_pod=True)
assert row["n_chips"] == 512
print("CELL2-OK")

row = dryrun.run_cell("mamba2-2.7b", "long_500k")
assert row["bottleneck"] == "memory"
print("CELL3-OK")
"""


def test_dryrun_cells_compile_and_analyze():
    out = run_cells(CODE)
    for tag in ("CELL1-OK", "CELL2-OK", "CELL3-OK"):
        assert tag in out


TRAIN_CODE = r"""
from repro.launch import dryrun

row = dryrun.run_cell("qwen3-1.7b", "train_4k")
# train at 1M tokens/step: every roofline term must be nonzero and the
# step must carry optimizer + gradient collectives
assert row["t_compute_s"] > 0.1
assert row["collectives"]["bytes_by_kind"].get("all-reduce", 0) > 0
assert 0.3 < row["useful_flops_frac"] < 1.0
print("TRAIN-OK")
"""


def test_dryrun_train_cell():
    out = run_cells(TRAIN_CODE)
    assert "TRAIN-OK" in out


Q8_CODE = r"""
from repro.launch import dryrun

base = dryrun.run_cell("llama2-7b", "decode_32k")
q8 = dryrun.run_cell("llama2-7b", "decode_32k", q8_kv=True)
# the HALO-faithful int8 arena must cut the decode memory term >= 2x
assert q8["t_memory_s"] < base["t_memory_s"] / 2, (
    base["t_memory_s"], q8["t_memory_s"])
print("Q8-DRYRUN-OK")
"""


def test_dryrun_q8_decode_memory_reduction():
    out = run_cells(Q8_CODE)
    assert "Q8-DRYRUN-OK" in out
