"""Dry-run launch-stack regression: compile real cells on the production
mesh (512 forced host devices) in a subprocess, assert the roofline row is
sane.  Slowish (~1 min) but this is the deliverable path — it must not rot.
"""

import os
import subprocess
import sys


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cells(code: str, timeout=560):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)        # dryrun.py sets its own, first thing
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout[-4000:]}\nSTDERR:\n{p.stderr[-4000:]}"
    return p.stdout


CODE = r"""
import json
from repro.launch import dryrun   # sets XLA_FLAGS before jax init

row = dryrun.run_cell("qwen3-1.7b", "decode_32k")
assert row["n_chips"] == 256
assert row["bottleneck"] == "memory", row["bottleneck"]
assert row["t_memory_s"] > row["t_compute_s"]
assert 0.5 < row["useful_flops_frac"] < 1.5, row["useful_flops_frac"]
print("CELL1-OK")

row = dryrun.run_cell("qwen3-1.7b", "decode_32k", multi_pod=True)
assert row["n_chips"] == 512
print("CELL2-OK")

row = dryrun.run_cell("mamba2-2.7b", "long_500k")
assert row["bottleneck"] == "memory"
print("CELL3-OK")
"""


def test_dryrun_cells_compile_and_analyze():
    out = run_cells(CODE)
    for tag in ("CELL1-OK", "CELL2-OK", "CELL3-OK"):
        assert tag in out


TRAIN_CODE = r"""
from repro.launch import dryrun

row = dryrun.run_cell("qwen3-1.7b", "train_4k")
# train at 1M tokens/step: every roofline term must be nonzero and the
# step must carry optimizer + gradient collectives
assert row["t_compute_s"] > 0.1
assert row["collectives"]["bytes_by_kind"].get("all-reduce", 0) > 0
assert 0.3 < row["useful_flops_frac"] < 1.0
print("TRAIN-OK")
"""


def test_dryrun_train_cell():
    out = run_cells(TRAIN_CODE)
    assert "TRAIN-OK" in out


Q8_CODE = r"""
from repro.launch import dryrun

base = dryrun.run_cell("llama2-7b", "decode_32k")
q8 = dryrun.run_cell("llama2-7b", "decode_32k", q8_kv=True)
# the int8 arena shrinks the decode memory term, but NOT by the naive 4x:
# at 32k llama2-7b the f32 KV (~125 MB/chip) only slightly outweighs the
# f32 weights (~110 MB/chip), so correct 1 B/elem costing of the s8 pages
# bounds the whole-step reduction near 1.6x.  The old >= 2x threshold was
# an artifact of _float_bytes dropping integer buffers entirely (s8 reads
# charged at ZERO bytes) — both bounds below pin the honest costing.
assert q8["t_memory_s"] < base["t_memory_s"] * 0.75, (
    base["t_memory_s"], q8["t_memory_s"])
assert q8["t_memory_s"] > base["t_memory_s"] / 4, (
    base["t_memory_s"], q8["t_memory_s"])
print("Q8-DRYRUN-OK")
"""


def test_dryrun_q8_decode_memory_reduction():
    out = run_cells(Q8_CODE)
    assert "Q8-DRYRUN-OK" in out


W8_CODE = r"""
from repro.launch import dryrun

base = dryrun.run_cell("llama2-7b", "decode_32k", q8_kv=True)
w8 = dryrun.run_cell("llama2-7b", "decode_32k", q8_kv=True,
                     int8_weights=True)
# int8 weights shrink the decode memory term (4 B -> 1 B per weight) ...
assert w8["t_memory_s"] < base["t_memory_s"], (
    base["t_memory_s"], w8["t_memory_s"])
# ... but the s8 banks must still be CHARGED: the analyzer used to drop
# integer entry parameters entirely (_float_bytes), which made quantized
# weights look free.  With KV already int8, weights dominate the remaining
# traffic, so a proper 1-byte costing keeps >= 25% of the baseline term.
assert w8["t_memory_s"] > base["t_memory_s"] / 4, (
    base["t_memory_s"], w8["t_memory_s"])
print("W8-DRYRUN-OK")
"""


def test_dryrun_int8_weight_bytes_costed():
    out = run_cells(W8_CODE)
    assert "W8-DRYRUN-OK" in out
