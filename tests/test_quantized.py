"""int8 KV-cache decode (the HALO-faithful datapath): correctness vs f32."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models.transformer import (
    decode_step,
    init_params,
    pad_cache,
    prefill,
)
from repro.serving.quantized_cache import (
    dequantize,
    init_quantized_cache,
    pack_int4,
    quantize_token,
    quantize_token_int4,
    unpack_int4,
)
from repro.serving.quantized_weights import quantize_weight


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3), d=st.sampled_from([16, 64, 128]))
def test_quantize_token_roundtrip_bound(scale, d):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 3, d)) * scale
    q, s = quantize_token(x)
    y = dequantize(q, s)
    err = np.abs(np.asarray(x) - np.asarray(y))
    bound = np.asarray(s)[..., None] * 0.5 + 1e-9
    assert (err <= bound * 1.01).all()
    assert q.dtype == jnp.int8


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3), k=st.sampled_from([17, 64, 128]))
def test_quantize_weight_roundtrip_bound(scale, k):
    """Per-output-channel int8 weights: |w - dq(w)| <= scale_n / 2."""
    w = jax.random.normal(jax.random.PRNGKey(3), (k, 33)) * scale
    q = quantize_weight(w)
    assert q["q"].dtype == jnp.int8 and q["scale"].shape == (33,)
    back = np.asarray(q["q"], np.float32) * np.asarray(q["scale"])[None, :]
    err = np.abs(np.asarray(w) - back)
    bound = np.asarray(q["scale"])[None, :] * 0.5 + 1e-9
    assert (err <= bound * 1.01).all()


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3), d=st.sampled_from([16, 64, 128]))
def test_quantize_token_int4_roundtrip_bound(scale, d):
    """Packed int4 KV round trip: |x - dq(unpack(pack(q)))| <= s / 2."""
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, d)) * scale
    q, s = quantize_token_int4(x)
    assert int(jnp.max(jnp.abs(q))) <= 7
    y = dequantize(unpack_int4(pack_int4(q)), s)
    err = np.abs(np.asarray(x) - np.asarray(y))
    bound = np.asarray(s)[..., None] * 0.5 + 1e-9
    assert (err <= bound * 1.01).all()


def _quantize_f32_cache(cfg, cache, B, S):
    qc = init_quantized_cache(cfg, B, S)
    out = []
    for piece, qpiece in zip(cache, qc):
        if isinstance(piece, dict) and "k" in piece and "k_scale" in qpiece:
            kq, ks = quantize_token(piece["k"])
            vq, vs = quantize_token(piece["v"])
            out.append({"k": kq, "k_scale": ks, "v": vq, "v_scale": vs})
        else:
            out.append(piece)
    return out


@pytest.mark.parametrize("arch", ["llama2-7b", "qwen3-1.7b",
                                  "h2o-danube-1.8b"])
def test_q8_decode_matches_f32(arch):
    """int8 arena decode: <5% max relative logit error, argmax-exact."""
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, P, S = 2, 16, 32
    tokens = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    logits, cache = prefill(params, cfg, {"tokens": tokens})
    cache = pad_cache(cfg, cache, P, S)
    nt = jnp.argmax(logits[:, -1:], -1)
    ref, _ = decode_step(params, cfg, {"tokens": nt}, cache, jnp.int32(P))

    q8_cache = _quantize_f32_cache(cfg, cache, B, S)
    got, new_cache = decode_step(params, cfg, {"tokens": nt}, q8_cache,
                                 jnp.int32(P))
    rel = (np.abs(np.asarray(got) - np.asarray(ref)).max()
           / (np.abs(np.asarray(ref)).max() + 1e-9))
    assert rel < 0.05, f"{arch}: rel err {rel}"
    np.testing.assert_array_equal(np.argmax(np.asarray(got), -1),
                                  np.argmax(np.asarray(ref), -1))
    # the updated arena stays int8
    for piece in new_cache:
        if isinstance(piece, dict) and "k" in piece and "k_scale" in piece:
            assert piece["k"].dtype == jnp.int8


def test_q8_multi_step_decode_stays_accurate():
    """Quantization error must not compound over steps (fresh per-token
    scales): 8 decode steps still argmax-match f32."""
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    B, P, S = 1, 12, 32
    tokens = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    logits, cache = prefill(params, cfg, {"tokens": tokens})
    cache = pad_cache(cfg, cache, P, S)
    q8 = _quantize_f32_cache(cfg, cache, B, S)
    nt_f = nt_q = jnp.argmax(logits[:, -1:], -1)
    for i in range(8):
        lf, cache = decode_step(params, cfg, {"tokens": nt_f}, cache,
                                jnp.int32(P + i))
        lq, q8 = decode_step(params, cfg, {"tokens": nt_q}, q8,
                             jnp.int32(P + i))
        nt_f = jnp.argmax(lf[:, -1:], -1)
        nt_q = jnp.argmax(lq[:, -1:], -1)
        assert int(nt_f[0, 0]) == int(nt_q[0, 0]), f"diverged at step {i}"
