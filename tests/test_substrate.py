"""Substrate tests: optimizers, schedules, data pipeline, checkpointing,
fault-tolerance logic, trainer restart equivalence."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, make_loader
from repro.optim.optimizers import adafactor, adamw, sgd_momentum
from repro.optim.schedules import cosine_schedule, wsd_schedule
from repro.checkpoint.store import CheckpointManager, load_pytree, save_pytree


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def _quadratic_losses(opt, steps=60):
    """Minimize ||Wx - y||^2; returns the loss trajectory."""
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    W = jax.random.normal(k1, (8, 8)) * 0.1
    x = jax.random.normal(k2, (8, 16))
    W_true = jax.random.normal(k3, (8, 8)) * 0.5
    y = W_true @ x                      # realizable target: optimum loss = 0
    params = {"W": W}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.mean((p["W"] @ x - y) ** 2)

    losses = []
    step = jnp.zeros((), jnp.int32)
    for _ in range(steps):
        l, g = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(g, state, params, step)
        step = step + 1
        losses.append(float(l))
    return losses


@pytest.mark.parametrize("make", [
    lambda: adamw(lambda s: jnp.float32(0.05)),
    lambda: adamw(lambda s: jnp.float32(0.05), state_dtype="bfloat16"),
    lambda: adafactor(lambda s: jnp.float32(0.3)),
    lambda: adafactor(lambda s: jnp.float32(0.3), momentum_dtype="bfloat16"),
    lambda: sgd_momentum(lambda s: jnp.float32(0.05)),
])
def test_optimizers_reduce_quadratic(make):
    losses = _quadratic_losses(make())
    assert losses[-1] < 0.3 * losses[0], losses[::10]


def test_adamw_state_dtype():
    opt = adamw(lambda s: jnp.float32(1e-3), state_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    st = opt.init(params)
    assert st["m"]["w"].dtype == jnp.bfloat16
    assert st["v"]["w"].dtype == jnp.bfloat16


def test_adafactor_state_is_factored():
    opt = adafactor(lambda s: jnp.float32(1e-3))
    params = {"w": jnp.ones((64, 32)), "b": jnp.ones((64,))}
    st = opt.init(params)
    assert st["v"]["w"]["vr"].shape == (64,)
    assert st["v"]["w"]["vc"].shape == (32,)
    assert st["v"]["b"]["v"].shape == (64,)
    # factored state is ~ (n+m)/(n*m) of full Adam
    full = 2 * 64 * 32
    fact = 64 + 32
    assert fact < full / 10


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def test_cosine_schedule_shape():
    f = cosine_schedule(1.0, 100, warmup_steps=10)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-6
    assert float(f(50)) < 1.0
    assert abs(float(f(100)) - 0.1) < 1e-2


def test_wsd_schedule_shape():
    f = wsd_schedule(1.0, 100, warmup_steps=10, decay_frac=0.2)
    assert float(f(0)) == 0.0
    np.testing.assert_allclose(float(f(40)), 1.0, rtol=1e-6)   # stable
    np.testing.assert_allclose(float(f(79)), 1.0, rtol=1e-6)   # still stable
    assert float(f(95)) < 0.5                                   # decaying
    np.testing.assert_allclose(float(f(100)), 0.01, rtol=1e-2)  # final


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def _cfg(**kw):
    d = dict(seq_len=16, global_batch=8, vocab_size=97, seed=3)
    d.update(kw)
    return DataConfig(**d)


def test_loader_deterministic():
    a = make_loader(_cfg()).next_batch()["tokens"]
    b = make_loader(_cfg()).next_batch()["tokens"]
    np.testing.assert_array_equal(a, b)


def test_loader_resume_replays_stream():
    l1 = make_loader(_cfg())
    for _ in range(3):
        l1.next_batch()
    st = l1.state_dict()
    want = l1.next_batch()["tokens"]
    l2 = make_loader(_cfg())
    l2.load_state_dict(st)
    got = l2.next_batch()["tokens"]
    np.testing.assert_array_equal(want, got)


def test_loader_workers_partition_global_batch():
    """N workers' shards concatenate to exactly the 1-worker global batch."""
    full = make_loader(_cfg()).next_batch()["tokens"]
    parts = [make_loader(_cfg(), worker=w, n_workers=4).next_batch()["tokens"]
             for w in range(4)]
    np.testing.assert_array_equal(full, np.concatenate(parts, 0))


def test_loader_elastic_rescale_preserves_stream():
    """Rescaling 4 workers -> 2 workers mid-stream keeps the global stream."""
    l4 = [make_loader(_cfg(), worker=w, n_workers=4) for w in range(4)]
    for l in l4:
        for _ in range(2):
            l.next_batch()
    # rescale: two workers take over, inheriting the step counter
    l2 = [l4[0].with_workers(w, 2) for w in range(2)]
    got = np.concatenate([l.next_batch()["tokens"] for l in l2], 0)
    ref = make_loader(_cfg())
    for _ in range(2):
        ref.next_batch()
    want = ref.next_batch()["tokens"]
    np.testing.assert_array_equal(want, got)


def test_packed_documents():
    cfg = _cfg(kind="packed", seq_len=8, global_batch=2)
    tokens = np.arange(100, dtype=np.int32)
    l = make_loader(cfg, tokens=tokens)
    b = l.next_batch()["tokens"]
    np.testing.assert_array_equal(b[0], np.arange(8))
    np.testing.assert_array_equal(b[1], np.arange(8, 16))


def test_synthetic_is_learnable_signal():
    """Same (a,b,m) across sequences: transition table is consistent."""
    cfg = _cfg(seq_len=64, global_batch=4)
    b = make_loader(cfg).next_batch()["tokens"]
    # for any token value appearing at the same (t % m) phase, the successor
    # is identical across sequences
    src = make_loader(cfg).source
    m = src.m
    mapping = {}
    for row in b:
        for t in range(63):
            key = (int(row[t]), t % m)
            nxt = int(row[t + 1])
            assert mapping.setdefault(key, nxt) == nxt


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (4, 8)),
            "nested": {"b": jax.random.normal(k2, (3,)).astype(jnp.bfloat16),
                       "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_pytree(str(tmp_path / "ck"), tree, step=5)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    got = load_pytree(str(tmp_path / "ck"), like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_manager_retention_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(jax.random.PRNGKey(1))
    for s in (10, 20, 30):
        m.save(s, tree)
    assert m.latest_step() == 30
    assert m.all_steps() == [20, 30]          # step 10 garbage-collected


def test_checkpoint_async(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree(jax.random.PRNGKey(2))
    m.save(1, tree, blocking=False)
    m.wait()
    got = m.restore(jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(tree["a"]), np.asarray(got["a"]))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"a": jnp.ones((4,))}
    save_pytree(str(tmp_path / "ck"), tree)
    with pytest.raises(ValueError):
        load_pytree(str(tmp_path / "ck"), {"a": jnp.ones((5,))})


# ---------------------------------------------------------------------------
# trainer: restart-from-checkpoint == uninterrupted run (exact replay)
# ---------------------------------------------------------------------------


def test_trainer_restart_equivalence(tmp_path):
    import dataclasses
    from repro.configs.base import get_config
    from repro.optim.optimizers import adamw
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              dtype="float32")
    dc = DataConfig(seq_len=32, global_batch=4, vocab_size=cfg.vocab_size,
                    seed=1)

    def make_trainer(ckdir, steps):
        opt = adamw(lambda s: jnp.float32(1e-3))
        tc = TrainerConfig(total_steps=steps, checkpoint_every=5,
                           checkpoint_dir=ckdir, log_every=0,
                           async_checkpoint=False, remat=False)
        return Trainer(cfg, opt, dc, tc)

    # uninterrupted 10 steps
    t_full = make_trainer(str(tmp_path / "full"), 10)
    t_full.run()

    # interrupted at 5 (checkpoint), then a fresh trainer resumes
    t_a = make_trainer(str(tmp_path / "resume"), 5)
    t_a.run()
    t_b = make_trainer(str(tmp_path / "resume"), 10)
    t_b.run()

    la = jax.tree.leaves(t_full.state["params"])
    lb = jax.tree.leaves(t_b.state["params"])
    for a, b in zip(la, lb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_cluster_monitor_state_machine():
    from repro.runtime.fault_tolerance import ClusterMonitor, WorkerState

    t = [0.0]
    mon = ClusterMonitor(4, timeout_s=10, suspect_s=4, clock=lambda: t[0])
    t[0] = 5.0
    mon.heartbeat(0)
    mon.heartbeat(1)
    assert mon.sweep() == []
    assert mon.workers[2].state == WorkerState.SUSPECT
    t[0] = 12.0
    mon.heartbeat(0)
    mon.heartbeat(1)
    dead = mon.sweep()
    assert set(dead) == {2, 3}
    assert set(mon.healthy()) == {0, 1}


def test_restart_policy_decisions():
    from repro.runtime.fault_tolerance import Action, RestartPolicy

    p = RestartPolicy(8, min_quorum=0.5, max_in_place=2)
    assert p.decide([], 8) == Action.CONTINUE
    assert p.decide([3], 7) == Action.RESTART_IN_PLACE
    assert p.decide([3], 7) == Action.RESTART_IN_PLACE
    assert p.decide([3], 7) == Action.ELASTIC_DOWN     # 3rd flake
    assert p.decide([0, 1, 2, 4, 5], 3) == Action.ABORT


def test_straggler_mitigation():
    from repro.runtime.fault_tolerance import StragglerMitigator

    s = StragglerMitigator(4, threshold=1.5, patience=3)
    evicted = []
    for _ in range(5):
        evicted = s.record_step({0: 1.0, 1: 1.0, 2: 1.0, 3: 3.0})
    assert evicted == [3]
    # healthy workers never flagged
    assert s.strikes[0] == 0


def test_elastic_rescale_plan():
    from repro.runtime.fault_tolerance import plan_elastic_rescale

    p = plan_elastic_rescale(32, model_parallel=16, chips_per_worker=8)
    assert p.new_mesh_shape == (16, 16)
    assert p.new_workers == 32
    # lose 5 hosts -> fall to the next power-of-two data axis
    p = plan_elastic_rescale(27, model_parallel=16, chips_per_worker=8)
    assert p.new_mesh_shape == (8, 16)
    assert p.new_workers == 16
