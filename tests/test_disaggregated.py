"""Disaggregated serving & tiered KV (PR 8).

The DisaggregatedExecutor pins prefill/decode programs to separate device
groups and accounts KV page ownership crossing the prefill -> decode
handoff (HALO's 2.5D interposer link); the host spill tier lets
preemption SWAP pages out and resume with zero recomputation.  Every
placement/tier variant must keep greedy token streams bit-identical —
placement and spill are performance knobs, never semantics.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving import (
    ColocatedExecutor,
    DisaggregatedExecutor,
    SamplingParams,
    ServeConfig,
    ServingEngine,
    SpecConfig,
    make_executor,
)
from repro.serving.engine import RequestState
from repro.serving.scheduler import PhaseAwareConfig


def tiny_cfg(name="qwen3-1.7b"):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


_PARAMS = {}


def cached_params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def make_engine(cfg, max_batch=2, *, executor="colocated", paged=True,
                page_size=4, n_pages=32, host_spill_pages=0,
                prefix_cache=False, spec=None, kv_dtype="f32",
                max_len=96, prefill_chunk=8, max_prefill_tokens=16):
    sc = ServeConfig(max_batch=max_batch, max_len=max_len,
                     phase=PhaseAwareConfig(
                         max_decode_batch=max_batch,
                         prefill_chunk=prefill_chunk,
                         max_prefill_tokens=max_prefill_tokens),
                     paged=paged, page_size=page_size, n_pages=n_pages,
                     prefix_cache=prefix_cache, speculative=spec,
                     kv_dtype=kv_dtype, executor=executor,
                     host_spill_pages=host_spill_pages)
    return ServingEngine(cfg, cached_params(cfg), sc)


def prompts(cfg, n, L, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (L,), dtype=np.int32)
            for _ in range(n)]


def run_greedy(eng, ps, max_new=4):
    reqs = [eng.submit(p.copy(),
                       sampling=SamplingParams(max_new_tokens=max_new))
            for p in ps]
    eng.run_until_drained()
    return [r.generated for r in reqs]


# ---------------------------------------------------------------------------
# executor layer: construction, placement, and the migration accounting
# ---------------------------------------------------------------------------


def test_make_executor_validates_name():
    assert isinstance(make_executor("colocated", {}), ColocatedExecutor)
    assert isinstance(make_executor("disaggregated", {}),
                      DisaggregatedExecutor)
    with pytest.raises(ValueError, match="executor="):
        make_executor("remote", {})
    with pytest.raises(ValueError, match="executor="):
        ServeConfig(max_batch=1, max_len=8,
                    phase=PhaseAwareConfig(max_decode_batch=1),
                    executor="remote")


def test_phase_classification_and_placement():
    ex = DisaggregatedExecutor({})
    for kind in ("chunk", "whole", "packed", "packed_paged",
                 "chunk_paged", "verify"):
        assert ex.phase_of(kind) == "prefill"
    for kind in ("decode", "decode_paged"):
        assert ex.phase_of(kind) == "decode"
    # single-device host: both groups resolve to the same device, so
    # pinning is a no-op and streams stay bit-identical by construction
    assert ex.prefill_devices and ex.decode_devices
    assert ex.device_for("decode_paged") is not None
    assert ColocatedExecutor({}).device_for("decode_paged") is None
    assert not ColocatedExecutor({}).migrates_kv and ex.migrates_kv


def test_handoff_batches_per_tick():
    ex = DisaggregatedExecutor({})
    ex.begin_tick()
    ex.record_handoff(2, 100)
    ex.record_handoff(3, 200)             # same tick: one link transaction
    assert (ex.migrated_pages, ex.migrated_bytes) == (5, 300)
    assert ex.migration_batches == 1
    ex.begin_tick()
    ex.record_handoff(0, 0)               # empty handoff: not a batch
    assert ex.migration_batches == 1
    ex.record_handoff(1, 50)
    assert ex.migration_batches == 2


# ---------------------------------------------------------------------------
# bit-identity: colocated vs disaggregated, across attention families and
# the paper's model pair
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b",        # GQA
                                  "gemma3-1b",         # sliding-window ring
                                  "deepseek-v2-236b",  # MLA latents
                                  "llama2-7b",         # paper model (CiM)
                                  "qwen3-8b"])         # paper model (CiD)
def test_colocated_vs_disaggregated_bit_identity(arch):
    cfg = tiny_cfg(arch)
    ps = prompts(cfg, 3, 12, seed=5)
    ref = run_greedy(make_engine(cfg), ps)
    eng = make_engine(cfg, executor="disaggregated")
    assert run_greedy(eng, ps) == ref
    # every request's fresh KV crossed the link exactly once
    c = eng.counts()
    assert c["migrated_bytes"] > 0 and c["migrated_pages"] > 0
    assert eng.executor.migration_batches >= 1


@pytest.mark.parametrize("variant", ["prefix", "speculative", "int8kv",
                                     "dense"])
def test_disaggregated_identity_across_serving_modes(variant):
    cfg = tiny_cfg()
    kw = {}
    if variant == "prefix":
        kw = dict(prefix_cache=True)
    elif variant == "speculative":
        kw = dict(spec=SpecConfig(k=2))
    elif variant == "int8kv":
        kw = dict(kv_dtype="int8")
    elif variant == "dense":
        kw = dict(paged=False, prefill_chunk=16, max_prefill_tokens=32)
    ps = prompts(cfg, 3, 12, seed=9)
    ref = run_greedy(make_engine(cfg, **kw), ps)
    eng = make_engine(cfg, executor="disaggregated", **kw)
    assert run_greedy(eng, ps) == ref
    # dense handoffs move bytes but no pages (the arena has none)
    c = eng.counts()
    assert c["migrated_bytes"] > 0
    assert (c["migrated_pages"] == 0) == (variant == "dense")


def test_prefix_hits_shrink_migrated_bytes():
    """Cached prefixes are already decode-side resident: only the tail a
    request actually prefilled crosses the link, so a shared-prompt wave
    migrates fewer bytes than the cold wave that built the cache."""
    cfg = tiny_cfg()
    eng = make_engine(cfg, executor="disaggregated", prefix_cache=True,
                      n_pages=64)
    head = prompts(cfg, 1, 16, seed=1)[0]
    rng = np.random.default_rng(2)
    wave = [np.concatenate([head, rng.integers(0, cfg.vocab_size, (4,),
                                               dtype=np.int32)])
            for _ in range(2)]
    run_greedy(eng, wave[:1])
    cold = eng.counts()["migrated_bytes"]
    run_greedy(eng, wave[1:])
    warm = eng.counts()["migrated_bytes"] - cold
    assert 0 < warm < cold


# ---------------------------------------------------------------------------
# tiered KV: swap-resume vs recompute-resume
# ---------------------------------------------------------------------------


def _forced_preempt_drain(eng, ps, max_new=6):
    """Drive the engine, preempting a decoding request once mid-stream
    (deterministic — no reliance on pool-pressure timing)."""
    reqs = [eng.submit(p.copy(),
                       sampling=SamplingParams(max_new_tokens=max_new))
            for p in ps]
    fired = False
    for _ in range(500):
        if not (eng.queue or any(r is not None for r in eng.slot_req)):
            break
        eng.step()
        if not fired:
            victim = next(
                (r for r in eng.slot_req if r is not None
                 and r.state == RequestState.DECODING
                 and len(r.generated) >= 2), None)
            if victim is not None:
                eng._preempt(victim)
                fired = True
    assert fired, "no preemption fired — the scenario never ran"
    return [r.generated for r in reqs]


def test_swap_resume_is_bit_identical_with_zero_reprefill():
    cfg = tiny_cfg()
    ps = prompts(cfg, 3, 16, seed=11)
    total_prompt = sum(int(p.shape[-1]) for p in ps)
    ref = run_greedy(make_engine(cfg, n_pages=64), ps, max_new=6)

    swap = make_engine(cfg, n_pages=64, host_spill_pages=32)
    assert _forced_preempt_drain(swap, ps) == ref
    c = swap.counts()
    assert swap.swap_outs >= 1 and c["swap_resumes"] >= 1
    assert c["recompute_preemptions"] == 0
    assert c["swap_out_bytes"] > 0 and c["swap_in_bytes"] > 0
    # THE tiered-KV claim: the swapped request resumed from its host
    # pages — not one prompt token was prefilled a second time
    assert swap.prefill_tokens_executed == total_prompt
    # handle-free steady state: every host page returned to the tier
    assert c["host_resident_pages"] == 0
    swap.host_tier.check_invariants()

    rec = make_engine(cfg, n_pages=64, host_spill_pages=0)
    assert _forced_preempt_drain(rec, ps) == ref
    rc = rec.counts()
    assert rc["recompute_preemptions"] >= 1 and rc["swap_resumes"] == 0
    # recompute-on-resume re-prefills the victim's whole effective stream
    assert rec.prefill_tokens_executed > total_prompt


def test_swap_falls_back_to_recompute_when_tier_full():
    cfg = tiny_cfg()
    ps = prompts(cfg, 3, 16, seed=11)
    ref = run_greedy(make_engine(cfg, n_pages=64), ps, max_new=6)
    # a 1-page tier cannot hold any victim (>= 16 tokens = 4+ pages)
    eng = make_engine(cfg, n_pages=64, host_spill_pages=1)
    assert _forced_preempt_drain(eng, ps) == ref
    c = eng.counts()
    assert c["recompute_preemptions"] >= 1 and c["swap_resumes"] == 0
    assert eng.host_tier.used_pages() == 0


def test_abort_of_swapped_request_frees_host_pages():
    cfg = tiny_cfg()
    eng = make_engine(cfg, n_pages=64, host_spill_pages=32)
    reqs = [eng.submit(p.copy(),
                       sampling=SamplingParams(max_new_tokens=6))
            for p in prompts(cfg, 2, 16, seed=3)]
    victim = None
    for _ in range(200):
        eng.step()
        victim = next((r for r in eng.slot_req if r is not None
                       and r.state == RequestState.DECODING), None)
        if victim is not None:
            break
    eng._preempt(victim)
    assert victim.swap is not None and eng.host_tier.used_pages() > 0
    eng.abort(victim.req_id)
    assert victim.swap is None and eng.host_tier.used_pages() == 0
    eng.run_until_drained()
    assert all(r.state == RequestState.DONE for r in reqs)
    eng.host_tier.check_invariants()


# ---------------------------------------------------------------------------
# prefix cache: demote -> promote round trip through the host tier
# ---------------------------------------------------------------------------


def test_prefix_demote_promote_round_trip():
    cfg = tiny_cfg()
    eng = make_engine(cfg, prefix_cache=True, host_spill_pages=32,
                      n_pages=64)
    p = prompts(cfg, 1, 16, seed=21)[0]
    first = run_greedy(eng, [p], max_new=4)[0]
    assert eng.prefix.stats()["inserted_blocks"] > 0

    # evict everything: with the host tier attached, eviction DEMOTES
    # blocks (device pages freed, KV parked on host) instead of dropping
    freed = eng.prefix.evict(eng.pool, eng.pool.n_pages)
    s = eng.prefix.stats()
    assert freed > 0 and s["demoted_blocks"] > 0 and s["demoted_nodes"] > 0
    assert eng.host_tier.used_pages() > 0
    assert eng.pool.free_pages() == eng.pool.n_pages

    # re-hit: match promotes the demoted blocks back to fresh device
    # pages — the resubmit starts past the cached prefix and the stream
    # is identical to the cold run
    req = eng.submit(p.copy(), sampling=SamplingParams(max_new_tokens=4))
    eng.run_until_drained()
    assert req.cached_tokens > 0
    assert req.generated == first
    s = eng.prefix.stats()
    assert s["promoted_blocks"] > 0
    assert s["demoted_nodes"] < s["demoted_blocks"] or s["demoted_nodes"] == 0

    # promoted pages are externally owned: flush returns every page
    eng.prefix.flush(eng.pool)
    assert eng.pool.free_pages() == eng.pool.n_pages
    assert eng.host_tier.used_pages() == 0
    for pp in eng.pool.pools:
        pp.check_invariants()
    eng.host_tier.check_invariants()


def test_demoted_prefix_hit_identity_vs_cold_cache():
    """A stream served through promote must equal the same stream served
    by a cacheless engine — promotion restores the EXACT bytes."""
    cfg = tiny_cfg()
    ps = prompts(cfg, 2, 16, seed=33)
    ref = run_greedy(make_engine(cfg, n_pages=64), ps, max_new=5)
    eng = make_engine(cfg, prefix_cache=True, host_spill_pages=32,
                      n_pages=64)
    out0 = run_greedy(eng, ps[:1], max_new=5)
    eng.prefix.evict(eng.pool, eng.pool.n_pages)      # demote to host
    out1 = run_greedy(eng, ps[1:], max_new=5)
    # resubmit the first prompt: served THROUGH the promoted prefix
    out2 = run_greedy(eng, ps[:1], max_new=5)
    assert out0 + out1 == ref
    assert out2 == ref[:1]
    assert eng.prefix.stats()["promoted_blocks"] > 0


# ---------------------------------------------------------------------------
# hypothesis: interleavings conserve refcounts across BOTH tiers
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                       # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    class TieredHostOnlyEngine(ServingEngine):
        """Device programs and page I/O stubbed (tokens are all 0, page
        uploads are no-ops) so hypothesis can drive it fast; admission,
        page accounting, prefix attach/publish/demote/promote, swap
        in/out, preemption, and abort all run for real."""

        _CACHE_ARG = {"chunk": 5, "chunk_paged": 5, "whole": 3,
                      "packed": 6, "packed_paged": 6,
                      "decode": 2, "decode_paged": 2, "verify": 5}

        def _program(self, group, kind):
            cache_arg = self._CACHE_ARG[kind]

            def run(*args):
                import jax.numpy as jnp
                cache = args[cache_arg]
                if kind == "verify":
                    draft = np.asarray(args[7])
                    out = np.zeros((draft.shape[0], draft.shape[1] + 2),
                                   np.int32)
                    out[:, -1] = 1
                    return jnp.asarray(out), cache
                if kind in ("packed", "packed_paged"):
                    n = np.asarray(args[2]).shape[0]
                else:
                    n = 1 if kind == "whole" else np.asarray(args[1]).shape[0]
                return jnp.zeros((n,), jnp.int32), cache

            return run

        def _copy_pages(self, copies):
            self.cow_copies += len(copies)

        def _read_page(self, r, page):
            # host-tier leaf shapes without touching device arrays
            return {k: np.zeros((v.shape[0],) + tuple(v.shape[2:]),
                                v.dtype)
                    for k, v in self.host_tier._store[r].items()}

        def _write_page(self, r, page, data):
            pass

    @settings(max_examples=25, deadline=None)
    @given(ops=st.lists(
        st.tuples(st.integers(0, 4),      # 0 submit, 1 step, 2 abort,
                                          # 3 step+abort-youngest,
                                          # 4 step+preempt-youngest (swap)
                  st.integers(0, 7),      # prompt selector / abort target
                  st.integers(1, 30)),    # prompt length
        max_size=30))
    def test_tiered_interleavings_conserve_refcounts(ops):
        """ANY interleaving of submit / step / abort / preempt on a small
        paged pool with the prefix cache, speculation, AND the host tier
        on keeps refcount conservation in every device run pool and in
        the host tier, and ends with both tiers completely free."""
        cfg = tiny_cfg()
        eng = TieredHostOnlyEngine(cfg, cached_params(cfg), ServeConfig(
            max_batch=2, max_len=64,
            phase=PhaseAwareConfig(max_decode_batch=2, prefill_chunk=8,
                                   max_prefill_tokens=16),
            paged=True, page_size=4, n_pages=12, prefix_cache=True,
            speculative=SpecConfig(k=2), host_spill_pages=8))
        submitted = []
        for kind, sel, length in ops:
            if kind == 0:
                prompt = np.full((min(length, 30),), sel % 3, np.int32)
                try:
                    submitted.append(eng.submit(
                        prompt, sampling=SamplingParams(max_new_tokens=6)))
                except ValueError:
                    pass                  # longer than the pool: rejected
            elif kind == 1:
                eng.step()
            elif kind == 2 and submitted:
                eng.abort(submitted[sel % len(submitted)].req_id)
            elif kind == 3:
                eng.step()
                live = [r for r in eng.slot_req if r is not None]
                if live:
                    eng.abort(max(live, key=lambda r: r.req_id).req_id)
            elif kind == 4:
                eng.step()
                holders = [r for r in eng.slot_req if r is not None
                           and eng.pool.len_of(r.slot) > 0]
                if holders:
                    eng._preempt(max(holders, key=lambda r: r.req_id))
            for p in eng.pool.pools:
                p.check_invariants()
            eng.host_tier.check_invariants()
            # a swapped queue entry's host pages + the cache's demoted
            # blocks account for every used host page
            handle_pages = sum(
                len(pages) for r in eng.queue if r.swap is not None
                for pages in r.swap.pages)
            assert eng.host_tier.used_pages() >= handle_pages
        for _ in range(200):
            if not (eng.queue or any(r is not None for r in eng.slot_req)):
                break
            eng.step()
        eng.prefix.flush(eng.pool)
        for p in eng.pool.pools:
            p.check_invariants()
            assert p.free_pages() == p.n_pages, \
                "device pages leaked across the interleaving"
        eng.host_tier.check_invariants()
        assert eng.host_tier.used_pages() == 0, \
            "host-tier pages leaked across the interleaving"
