"""Speculative decoding: verify_draft acceptance rule, top-p sampling,
draft/verify engine identity across attention families, KV rollback
(truncate) under sharing/COW, drafter behavior, and scheduler charging."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving.engine import RequestState, ServeConfig, ServingEngine
from repro.serving.kv_pool import KVPool
from repro.serving.sampling import sample_tokens, verify_draft
from repro.serving.scheduler import PhaseAwareConfig, PhaseScheduler
from repro.serving.speculative import ModelDrafter, NGramDrafter, SpecConfig


def tiny_cfg(name="qwen3-1.7b"):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


_PARAMS = {}


def cached_params(cfg):
    if cfg.name not in _PARAMS:
        _PARAMS[cfg.name] = init_params(jax.random.PRNGKey(0), cfg)
    return _PARAMS[cfg.name]


def make_engine(cfg, max_batch=2, *, spec=None, page_size=8, n_pages=32,
                prefill_chunk=16, max_prefill_tokens=32,
                prefix_cache=False, greedy=True, temperature=1.0,
                top_k=0, top_p=0.0):
    params = cached_params(cfg)
    sc = ServeConfig(max_batch=max_batch, max_len=96,
                     phase=PhaseAwareConfig(max_decode_batch=max_batch,
                                            prefill_chunk=prefill_chunk,
                                            max_prefill_tokens=max_prefill_tokens),
                     greedy=greedy, temperature=temperature, top_k=top_k,
                     top_p=top_p, paged=True, page_size=page_size,
                     n_pages=n_pages, prefix_cache=prefix_cache,
                     speculative=spec)
    return ServingEngine(cfg, params, sc)


def prompts(cfg, n, L, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, (L,), dtype=np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# verify_draft: the acceptance rule (pure device logic)
# ---------------------------------------------------------------------------


def _logits_for(seq, V=16, hot=10.0):
    """[1, C, V] logits whose argmax stream is ``seq``."""
    out = np.zeros((1, len(seq), V), np.float32)
    for i, t in enumerate(seq):
        out[0, i, t] = hot
    return jnp.asarray(out)


def test_verify_greedy_accepts_matching_prefix():
    # target argmax stream: [5, 6, 7]; draft proposes [5, 9]
    logits = _logits_for([5, 6, 7])
    toks, n = verify_draft(logits, jnp.asarray([[5, 9]]),
                           jnp.asarray([2]), greedy=True)
    assert int(n[0]) == 2                      # d_1 accepted + correction
    assert np.asarray(toks)[0, :2].tolist() == [5, 6]


def test_verify_greedy_full_acceptance_emits_bonus():
    logits = _logits_for([5, 6, 7])
    toks, n = verify_draft(logits, jnp.asarray([[5, 6]]),
                           jnp.asarray([2]), greedy=True)
    assert int(n[0]) == 3                      # both drafts + bonus
    assert np.asarray(toks)[0].tolist() == [5, 6, 7]


def test_verify_greedy_respects_draft_len():
    # row padded to K=2 but only 1 valid draft: the match at j=1 must not
    # count, and the emission is d_1 + the position-1 bonus
    logits = _logits_for([5, 6, 7])
    toks, n = verify_draft(logits, jnp.asarray([[5, 6]]),
                           jnp.asarray([1]), greedy=True)
    assert int(n[0]) == 2
    assert np.asarray(toks)[0, :2].tolist() == [5, 6]


def test_verify_greedy_rejection_at_first_position():
    logits = _logits_for([5, 6, 7])
    toks, n = verify_draft(logits, jnp.asarray([[4, 6]]),
                           jnp.asarray([2]), greedy=True)
    assert int(n[0]) == 1                      # nothing accepted
    assert int(np.asarray(toks)[0, 0]) == 5    # the correction


def test_verify_stochastic_certain_draft_always_accepts():
    # p(draft) ~ 1 at every position -> Leviathan accepts everything and
    # the bonus comes from the last window position
    logits = _logits_for([5, 6, 7], hot=50.0)
    for i in range(20):
        toks, n = verify_draft(logits, jnp.asarray([[5, 6]]),
                               jnp.asarray([2]), greedy=False,
                               temperature=1.0, key=jax.random.PRNGKey(i))
        assert int(n[0]) == 3
        assert np.asarray(toks)[0].tolist() == [5, 6, 7]


def test_verify_stochastic_residual_excludes_rejected_token():
    # p(draft token) ~ 0 -> always rejected at position 0, and the
    # residual resample (p with the draft token removed) can never emit
    # the rejected token itself
    logits = _logits_for([5, 6, 7], hot=50.0)
    for i in range(50):
        toks, n = verify_draft(logits, jnp.asarray([[9, 6]]),
                               jnp.asarray([2]), greedy=False,
                               temperature=1.0, key=jax.random.PRNGKey(i))
        assert int(n[0]) == 1
        assert int(np.asarray(toks)[0, 0]) != 9


# ---------------------------------------------------------------------------
# top-p (nucleus) sampling
# ---------------------------------------------------------------------------


def test_top_p_keeps_minimal_nucleus():
    # probs ~ [0.5, 0.3, 0.15, 0.05]: top_p=0.6 keeps {0, 1} (the mass
    # before token 1 is 0.5 < 0.6; before token 2 it is 0.8 >= 0.6)
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))
    seen = {int(sample_tokens(logits, greedy=False, temperature=1.0,
                              top_p=0.6, key=jax.random.PRNGKey(i))[0])
            for i in range(200)}
    assert seen == {0, 1}


def test_top_p_tiny_reduces_to_argmax():
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))
    seen = {int(sample_tokens(logits, greedy=False, temperature=1.0,
                              top_p=1e-6, key=jax.random.PRNGKey(i))[0])
            for i in range(50)}
    assert seen == {0}


def test_top_p_one_is_off():
    # top_p >= 1 keeps the full distribution (any token reachable)
    logits = jnp.zeros((1, 4))                 # uniform
    seen = {int(sample_tokens(logits, greedy=False, temperature=1.0,
                              top_p=1.0, key=jax.random.PRNGKey(i))[0])
            for i in range(200)}
    assert seen == {0, 1, 2, 3}


def test_top_p_composes_with_top_k():
    # top_k=3 first, then top_p=0.75 over the renormalized survivors:
    # survivors {0,1,2} have probs ~[0.526, 0.316, 0.158] -> nucleus {0,1}
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.15, 0.05]]))
    seen = {int(sample_tokens(logits, greedy=False, temperature=1.0,
                              top_k=3, top_p=0.75,
                              key=jax.random.PRNGKey(i))[0])
            for i in range(200)}
    assert seen == {0, 1}


# ---------------------------------------------------------------------------
# engine identity: speculative on/off, every attention family
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b",       # GQA (qk_norm)
                                  "llama2-7b",        # MHA (paper model)
                                  "qwen3-8b",         # GQA (paper model)
                                  "gemma3-1b",        # sliding-window ring
                                  "deepseek-v2-236b"  # MLA latent pages
                                  ])
def test_spec_greedy_token_identity(arch):
    """Greedy streams are bit-identical with speculation on or off —
    verification accepts exactly the target's argmax prefix, whatever
    the drafter proposes.  Short prompts so the sliding-window config
    actually speculates inside its ring before the rollback bound."""
    cfg = tiny_cfg(arch)
    ps = prompts(cfg, 3, 6, seed=2)
    outs = {}
    for label, spec in (("off", None), ("on", SpecConfig(k=3))):
        eng = make_engine(cfg, max_batch=2, spec=spec)
        rs = [eng.submit(p.copy(), max_new_tokens=16) for p in ps]
        eng.run_until_drained()
        outs[label] = [r.generated for r in rs]
        assert all(r.state == RequestState.DONE for r in rs)
    assert outs["off"] == outs["on"]


def test_spec_identity_with_prefix_cache_on_and_off():
    """Prefix cache and speculation compose: shared-prompt requests with
    the cache on/off and speculation on/off all emit the same greedy
    streams, and the pool invariants survive the combination."""
    cfg = tiny_cfg()
    rng = np.random.default_rng(3)
    head = rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)
    ps = [np.concatenate([head, rng.integers(0, cfg.vocab_size, (6,),
                                             dtype=np.int32)])
          for _ in range(4)]
    outs = {}
    for label, spec, pc in (("base", None, False),
                            ("spec", SpecConfig(k=4), False),
                            ("spec+pc", SpecConfig(k=4), True)):
        eng = make_engine(cfg, max_batch=2, spec=spec, n_pages=40,
                          prefill_chunk=8, max_prefill_tokens=16,
                          prefix_cache=pc)
        rs = [eng.submit(p.copy(), max_new_tokens=14) for p in ps]
        eng.run_until_drained()
        outs[label] = [r.generated for r in rs]
        for p in eng.pool.pools:
            p.check_invariants()
        if pc:
            assert eng.prefix_stats()["hit_rate"] > 0
    assert outs["base"] == outs["spec"] == outs["spec+pc"]


def test_spec_rollback_never_mutates_cached_pages():
    """Rollback under sharing: requests decode speculatively on top of an
    attached/published prefix; rejected tokens roll back via truncate.
    The cached pages must survive bit-intact — a LATER request matching
    the same prefix produces exactly the cache-off stream, and the
    cache's external pins are still conserved after the drain."""
    cfg = tiny_cfg()
    rng = np.random.default_rng(9)
    head = rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)
    ps = [np.concatenate([head, rng.integers(0, cfg.vocab_size, (5,),
                                             dtype=np.int32)])
          for _ in range(3)]
    # reference: no cache, no speculation
    eng0 = make_engine(cfg, max_batch=1, n_pages=48)
    r0 = [eng0.submit(p.copy(), max_new_tokens=12) for p in ps]
    eng0.run_until_drained()
    base = [r.generated for r in r0]
    # speculation + cache, requests SERIALIZED (max_batch=1) so later
    # requests read pages published and speculated-over by earlier ones
    eng = make_engine(cfg, max_batch=1, spec=SpecConfig(k=4), n_pages=48,
                      prefix_cache=True)
    rs = [eng.submit(p.copy(), max_new_tokens=12) for p in ps]
    eng.run_until_drained()
    assert [r.generated for r in rs] == base
    assert eng.prefix_stats()["hit_rate"] > 0     # later reqs hit the cache
    assert eng.spec_stats()["windows"] > 0        # and speculation ran
    for p in eng.pool.pools:
        p.check_invariants()
        # after the drain only cache pins hold pages: every live page is
        # externally referenced (nothing leaked by truncate)
        live = np.nonzero(p.ref > 0)[0]
        assert all(p.external[q] > 0 for q in live)


def test_spec_pool_returns_clean_after_drain():
    cfg = tiny_cfg()
    eng = make_engine(cfg, max_batch=2, spec=SpecConfig(k=4), n_pages=24)
    for p in prompts(cfg, 3, 10, seed=4):
        eng.submit(p, max_new_tokens=10)
    eng.run_until_drained()
    assert eng.pool.free_pages() == 24            # no page leaked
    for p in eng.pool.pools:
        p.check_invariants()
    # the model-free drafter holds nothing either
    assert isinstance(eng.drafter, NGramDrafter)


def test_spec_eos_and_max_new_clip_inside_windows():
    """A window may emit several tokens; eos and max_new must clip the
    emission exactly where non-speculative decode would stop."""
    cfg = tiny_cfg()
    p = prompts(cfg, 1, 10, seed=5)[0]
    probe = make_engine(cfg, max_batch=1)
    r = probe.submit(p.copy(), max_new_tokens=12)
    probe.run_until_drained()
    eos = r.generated[6]
    want = r.generated[: r.generated.index(eos) + 1]
    eng = make_engine(cfg, max_batch=1, spec=SpecConfig(k=4))
    rs = eng.submit(p.copy(), max_new_tokens=12, eos_id=eos)
    eng.run_until_drained()
    assert rs.generated == want
    # max_new smaller than a full window
    eng = make_engine(cfg, max_batch=1, spec=SpecConfig(k=4))
    rs = eng.submit(p.copy(), max_new_tokens=3)
    eng.run_until_drained()
    assert rs.generated == r.generated[:3]


# ---------------------------------------------------------------------------
# drafters
# ---------------------------------------------------------------------------


def test_ngram_drafter_prompt_lookup():
    d = NGramDrafter(SpecConfig(k=4, ngram_max=3, ngram_min=1))
    # suffix [7, 8] occurred earlier, followed by [9, 1, 2, 3]
    ctx = np.asarray([7, 8, 9, 1, 2, 3, 7, 8], np.int32)
    out = d._propose_one(ctx, 4)
    assert out.tolist() == [9, 1, 2, 3]
    # most RECENT occurrence wins
    ctx = np.asarray([5, 1, 5, 2, 5], np.int32)
    assert d._propose_one(ctx, 2).tolist() == [2, 5][:2]
    # no recurring n-gram: no proposal
    assert d._propose_one(np.arange(8, dtype=np.int32), 4).size == 0


def test_ngram_acceptance_positive_on_repetitive_stream():
    """The acceptance-rate sanity check: greedy decode of the tiny model
    falls into loops, and prompt-lookup drafting feeds on them — over a
    long generation the n-gram drafter must land >> 0 acceptance and
    push mean tokens per (request, decode-tick) above 1."""
    cfg = tiny_cfg()
    eng = make_engine(cfg, max_batch=2, spec=SpecConfig(k=4), n_pages=64,
                      prefill_chunk=32, max_prefill_tokens=64)
    for p in prompts(cfg, 3, 16, seed=0):
        eng.submit(p, max_new_tokens=40)
    eng.run_until_drained()
    ss = eng.spec_stats()
    assert ss["windows"] > 0 and ss["drafted"] > 0
    assert ss["acceptance_rate"] > 0.02
    assert ss["tokens_per_tick"] > 1.0


def test_model_drafter_self_draft_acceptance():
    """Self-drafting (draft model == target model, same seed) is the
    acceptance ceiling: the drafter's greedy stream IS the target's, so
    acceptance should be ~1 and windows commit k+1 tokens — and the
    token stream still matches non-speculative decode exactly."""
    cfg = tiny_cfg()
    ps = prompts(cfg, 2, 12, seed=6)
    base_eng = make_engine(cfg, max_batch=2, n_pages=48)
    rb = [base_eng.submit(p.copy(), max_new_tokens=16) for p in ps]
    base_eng.run_until_drained()
    spec = SpecConfig(k=4, drafter="model", draft_arch="qwen3-1.7b",
                      draft_seed=0)
    eng = make_engine(cfg, max_batch=2, spec=spec, n_pages=48)
    rs = [eng.submit(p.copy(), max_new_tokens=16) for p in ps]
    eng.run_until_drained()
    assert [r.generated for r in rs] == [r.generated for r in rb]
    ss = eng.spec_stats()
    assert ss["acceptance_rate"] > 0.5
    assert ss["tokens_per_tick"] > 2.0
    # the draft pool drains clean too
    assert eng.drafter.pool.free_pages() == eng.drafter.pool.n_pages


def test_spec_identity_at_pool_length_bound():
    """Regression: a fully-accepted window landing exactly at the pool's
    length bound must still emit EVERY accepted token before retiring —
    the position-bound retire check fires after the emission loop, not
    inside it (the window commits its slot_pos jump up front, so an
    in-loop _finished() would break after one token and drop the rest).
    Self-drafting keeps acceptance ~1 so the final window is full."""
    cfg = tiny_cfg()
    ps = prompts(cfg, 1, 10, seed=8)
    outs = {}
    spec = SpecConfig(k=4, drafter="model", draft_arch="qwen3-1.7b",
                      draft_seed=0)
    for label, sp in (("off", None), ("on", spec)):
        # 8 pages x 4 = a 32-token length bound the request must hit
        eng = make_engine(cfg, max_batch=1, spec=sp, page_size=4,
                          n_pages=8)
        r = eng.submit(ps[0].copy(), max_new_tokens=40)
        eng.run_until_drained()
        assert r.state == RequestState.DONE
        outs[label] = r.generated
    assert len(outs["off"]) == 32 - 10          # bound-limited, not max_new
    assert outs["off"] == outs["on"]


def test_model_drafter_ring_guard():
    """The draft pool rolls back after every verify just like the target
    arena, so a sliding-window draft arch must stop drafting at its own
    ring span — writing past it would clobber live draft context that
    truncate cannot restore (acceptance would silently collapse)."""
    cfg = tiny_cfg("gemma3-1b")
    drafter = ModelDrafter(cfg, cached_params(cfg), n_slots=1, n_pages=8,
                           page_size=4)
    assert drafter._safe_len == cfg.attn.sliding_window
    long_ctx = np.arange(20, dtype=np.int32)    # T-1+k = 23 > ring 16
    assert drafter.propose_batch([(0, 1, long_ctx)], 4) == {}
    short_ctx = np.arange(8, dtype=np.int32)    # T-1+k = 11 <= 16
    out = drafter.propose_batch([(0, 1, short_ctx)], 4)
    assert 0 in out and out[0].shape == (4,)
    for p in drafter.pool.pools:
        p.check_invariants()


def test_model_drafter_bounded_catch_up():
    """A slot far behind the committed context (fresh slot, resume after
    preemption) catches up one bounded chunk per tick — never a single
    unbounded prompt-sized prefill mid-decode — and only drafts once
    caught up."""
    cfg = tiny_cfg()
    drafter = ModelDrafter(cfg, cached_params(cfg), n_slots=1, n_pages=16,
                           page_size=4, draft_chunk=4)
    ctx = np.asarray(prompts(cfg, 1, 12, seed=1)[0])
    assert drafter.propose_batch([(0, 1, ctx)], 3) == {}   # 4 of 11 tokens
    assert int(drafter.lens[0]) == 4
    assert drafter.propose_batch([(0, 1, ctx)], 3) == {}   # 8 of 11
    assert int(drafter.lens[0]) == 8
    out = drafter.propose_batch([(0, 1, ctx)], 3)          # caught up
    assert 0 in out and out[0].shape == (3,)
    # pool holds ctx[:11] plus the 3 fed tokens (ctx[-1] + 2 drafts)
    assert int(drafter.lens[0]) == 11 + 3


def test_spec_identity_under_preemption_pressure():
    """Speculation + pool exhaustion + preemption still reproduce the
    non-speculative stream, and occupancy is counted at emission so
    tokens_per_tick never dips below the 1.0 non-speculative floor."""
    cfg = tiny_cfg()
    ps = prompts(cfg, 3, 14, seed=7)
    outs = {}
    for label, spec in (("off", None), ("on", SpecConfig(k=3))):
        eng = make_engine(cfg, max_batch=3, spec=spec, n_pages=6,
                          prefill_chunk=8, max_prefill_tokens=16)
        rs = [eng.submit(p.copy(), max_new_tokens=12) for p in ps]
        eng.run_until_drained(max_ticks=500)
        assert all(r.state == RequestState.DONE for r in rs)
        outs[label] = [r.generated for r in rs]
        assert eng.spec_stats()["tokens_per_tick"] >= 1.0
        assert eng.preemptions > 0          # the pool really was starved
    assert outs["off"] == outs["on"]


def test_spec_config_validation():
    with pytest.raises(ValueError):
        SpecConfig(k=0)
    with pytest.raises(ValueError):
        SpecConfig(drafter="oracle")
    with pytest.raises(ValueError):
        SpecConfig(drafter="model")               # needs draft_arch
    with pytest.raises(ValueError):
        SpecConfig(ngram_min=2, ngram_max=1)


def test_spec_requires_paged():
    cfg = tiny_cfg()
    params = cached_params(cfg)
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, ServeConfig(
            max_batch=2, max_len=64,
            phase=PhaseAwareConfig(max_decode_batch=2),
            speculative=SpecConfig(k=4)))


# ---------------------------------------------------------------------------
# KVPool.truncate: rollback accounting
# ---------------------------------------------------------------------------


def test_truncate_frees_only_whole_rejected_pages():
    cfg = tiny_cfg()
    pool = KVPool(cfg, n_slots=2, n_pages=8, page_size=4)
    assert pool.grow(0, 10)                       # 3 pages
    assert pool.truncate(0, 9) == 0               # same page count
    assert pool.truncate(0, 8) == len(pool.pools)  # 1 page per run
    assert pool.len_of(0) == 8
    with pytest.raises(ValueError):
        pool.truncate(0, 9)                       # cannot grow via truncate
    for p in pool.pools:
        p.check_invariants()


def test_truncate_respects_shared_and_pinned_pages():
    """A rejected token never frees a page its sharers or the prefix
    cache still hold: truncating the speculating slot drops only ITS
    references."""
    cfg = tiny_cfg()
    pool = KVPool(cfg, n_slots=2, n_pages=8, page_size=4)
    assert pool.grow(0, 8)
    pages = pool.prefix_pages(0, 8)
    pool.attach(1, pages, 8)                      # slot 1 shares both pages
    for r, pp in enumerate(pages):
        pool.retain(r, pp[0])                     # cache pins page 0
    assert pool.truncate(1, 0) == 0               # shared: nothing frees
    # page 1 is now slot-0-only: truncating slot 0 below it frees it
    assert pool.truncate(0, 4) == len(pool.pools)
    assert pool.len_of(0) == 4
    pool.release(0)
    # page 0 is still pinned by the cache reference
    for r, pp in enumerate(pages):
        assert int(pool.pools[r].ref[pp[0]]) == 1
        pool.release_ref(r, pp[0])
    assert pool.free_pages() == 8
    for p in pool.pools:
        p.check_invariants()


def test_rollback_bound_ring_vs_full():
    ring_cfg = tiny_cfg("gemma3-1b")              # mixed local/global
    pool = KVPool(ring_cfg, n_slots=1, n_pages=8, page_size=4)
    assert pool.rollback_bound() == ring_cfg.attn.sliding_window
    full_cfg = tiny_cfg()                         # pure GQA
    pool = KVPool(full_cfg, n_slots=1, n_pages=8, page_size=4)
    assert pool.rollback_bound() == pool.length_bound


def test_headroom_reserves_spec_growth():
    cfg = tiny_cfg()
    pool = KVPool(cfg, n_slots=2, n_pages=8, page_size=4)
    assert pool.grow(0, 4)                        # page-aligned decode slot
    # one-token growth needs 1 fresh page; a k=4 verify window (5 tokens)
    # needs 2 — the reservation shrinks prefill headroom accordingly
    assert pool.headroom_pages([4], growth=1) == 6
    assert pool.headroom_pages([4], growth=5) == 5


try:
    import hypothesis  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                               # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(ops=st.lists(
        st.tuples(st.integers(0, 3),              # 0 grow, 1 truncate,
                                                  # 2 release, 3 attach
                  st.integers(0, 2),              # slot
                  st.integers(0, 40)),            # length / target
        max_size=40))
    def test_truncate_interleavings_conserve_refcounts(ops):
        """ANY interleaving of grow/truncate/release/attach (the whole
        speculative lifecycle: window claim, rejection rollback, retire,
        prefix share) preserves every run pool's refcount conservation —
        rings and position-indexed runs alike (gemma3's mixed plan)."""
        cfg = tiny_cfg("gemma3-1b")
        pool = KVPool(cfg, n_slots=3, n_pages=8, page_size=4)
        for kind, slot, arg in ops:
            if kind == 0:
                pool.grow(slot, pool.len_of(slot) + arg)
            elif kind == 1:
                pool.truncate(slot, min(pool.len_of(slot), arg))
            elif kind == 2:
                pool.release(slot)
            else:
                src = (slot + 1) % 3
                span = min(pool.len_of(src), pool.shareable_capacity())
                span -= span % pool.page_size
                if span > 0 and pool.len_of(slot) == 0:
                    pool.attach(slot, pool.prefix_pages(src, span), span)
            for p in pool.pools:
                p.check_invariants()
        for p in pool.pools:
            assert p.used_pages() + p.free_pages() == p.n_pages


# ---------------------------------------------------------------------------
# scheduler: verify windows are planned onto the CiM-analogue group
# ---------------------------------------------------------------------------


def test_plan_tick_stamps_spec_window_and_verify_group():
    for strategy, vg in (("halo", "prefill"), ("cent", "decode"),
                         ("attacc", "prefill")):
        s = PhaseScheduler(PhaseAwareConfig(strategy))
        plan = s.plan_tick([], [1, 2], spec_k=4)
        assert plan.spec_k == 4
        assert plan.verify_group == vg            # verify = prefill-shaped
        assert plan.decode_reqs == [1, 2]
    assert PhaseScheduler(PhaseAwareConfig("halo")).plan_tick(
        [], []).spec_k == 0
