import os

# smoke tests and kernels must see the single real CPU device; ONLY the
# dedicated sharded tests spawn subprocesses with a forced device count.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax

jax.config.update("jax_enable_x64", False)
