"""Serving engine: continuous batching, ragged decode, phase scheduler."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import decode_step, init_cache, init_params, prefill
from repro.serving.engine import Request, RequestState, ServeConfig, ServingEngine
from repro.serving.scheduler import PhaseAwareConfig, PhaseScheduler


def tiny_cfg(name="qwen3-1.7b"):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


def make_engine(cfg, max_batch=3, max_len=64, strategy="halo"):
    params = init_params(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(max_batch=max_batch, max_len=max_len,
                     phase=PhaseAwareConfig(strategy=strategy,
                                            max_decode_batch=max_batch))
    return ServingEngine(cfg, params, sc), params


def prompts(cfg, n, L, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        if cfg.n_codebooks > 1:
            out.append(rng.integers(0, cfg.vocab_size,
                                    (cfg.n_codebooks, L), dtype=np.int32))
        else:
            out.append(rng.integers(0, cfg.vocab_size, (L,), dtype=np.int32))
    return out


def test_engine_drains_all_requests():
    cfg = tiny_cfg()
    eng, _ = make_engine(cfg)
    for p in prompts(cfg, 7, 16):
        eng.submit(p, max_new_tokens=5)
    done = eng.run_until_drained()
    assert len(done) == 7
    for r in done:
        assert r.state == RequestState.DONE
        assert len(r.generated) == 5
        assert r.ttft > 0 and r.t_done >= r.t_first_token


def test_engine_matches_straight_decode():
    """Engine output for one request == direct prefill+greedy decode."""
    cfg = tiny_cfg()
    eng, params = make_engine(cfg, max_batch=2, max_len=64)
    p = prompts(cfg, 1, 20, seed=3)[0]
    req = eng.submit(p, max_new_tokens=6)
    eng.run_until_drained()

    # oracle: straight greedy decode
    logits, cache = prefill(params, cfg, {"tokens": jnp.asarray(p[None])})
    from repro.models.transformer import pad_cache
    cache = pad_cache(cfg, cache, 20, 64)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = 20
    for _ in range(5):
        dl, cache = decode_step(params, cfg,
                                {"tokens": jnp.asarray([[toks[-1]]])},
                                cache, jnp.int32(pos))
        toks.append(int(jnp.argmax(dl[0, -1])))
        pos += 1
    assert req.generated == toks


def test_engine_ragged_batches_are_isolated():
    """Interleaved requests of different lengths must produce the same
    outputs as running each alone (slot isolation)."""
    cfg = tiny_cfg()
    solo_outputs = []
    for i, L in enumerate((12, 20)):
        eng, _ = make_engine(cfg, max_batch=1)
        p = prompts(cfg, 1, L, seed=10 + i)[0]
        r = eng.submit(p, max_new_tokens=4)
        eng.run_until_drained()
        solo_outputs.append(r.generated)

    eng, _ = make_engine(cfg, max_batch=2)
    p0 = prompts(cfg, 1, 12, seed=10)[0]
    p1 = prompts(cfg, 1, 20, seed=11)[0]
    r0 = eng.submit(p0, max_new_tokens=4)
    r1 = eng.submit(p1, max_new_tokens=4)
    eng.run_until_drained()
    assert r0.generated == solo_outputs[0]
    assert r1.generated == solo_outputs[1]


def test_continuous_batching_refills_slots():
    cfg = tiny_cfg()
    eng, _ = make_engine(cfg, max_batch=2)
    for p in prompts(cfg, 5, 8):
        eng.submit(p, max_new_tokens=3)
    peak_active = 0
    ticks = 0
    while (eng.queue or any(eng.slot_req)) and ticks < 100:
        stats = eng.step()
        peak_active = max(peak_active, stats["active"])
        ticks += 1
    assert len(eng.done) == 5
    assert peak_active == 2               # slots stayed saturated


def test_eos_stops_generation():
    cfg = tiny_cfg()
    eng, params = make_engine(cfg)
    p = prompts(cfg, 1, 16)[0]
    # run once to learn what the first generated token will be
    probe = eng.submit(p, max_new_tokens=1)
    eng.run_until_drained()
    first = probe.generated[0]
    eng2, _ = make_engine(cfg)
    r = eng2.submit(p, max_new_tokens=10, eos_id=first)
    eng2.run_until_drained()
    assert len(r.generated) == 1          # stopped at eos immediately


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-2.7b",
                                  "deepseek-v2-236b", "gemma3-1b"])
def test_engine_other_families(arch):
    """SSM / hybrid / MLA / local-global archs serve correctly too."""
    cfg = tiny_cfg(arch)
    eng, _ = make_engine(cfg, max_batch=2, max_len=48)
    for p in prompts(cfg, 3, 12):
        eng.submit(p, max_new_tokens=3)
    done = eng.run_until_drained()
    assert len(done) == 3
    assert all(len(r.generated) == 3 for r in done)


# ---------------------------------------------------------------------------
# phase scheduler (pure logic)
# ---------------------------------------------------------------------------


def test_scheduler_strategy_groups():
    assert PhaseScheduler(PhaseAwareConfig("halo")).groups_for() == (
        "prefill", "decode")
    assert PhaseScheduler(PhaseAwareConfig("cent")).groups_for() == (
        "decode", "decode")
    assert PhaseScheduler(PhaseAwareConfig("attacc")).groups_for() == (
        "prefill", "prefill")


def test_scheduler_decode_priority_and_budget():
    s = PhaseScheduler(PhaseAwareConfig(
        "halo", max_decode_batch=2, max_prefill_tokens=1000,
        prefill_chunk=600))
    plan = s.plan_tick(waiting=[(10, 600), (11, 600), (12, 600)],
                       decoding=[1, 2, 3])
    assert plan.decode_reqs == [1, 2]     # capped at max_decode_batch
    assert plan.prefill_reqs == [10, 11]  # 600+600 > 1000 budget stops at 2
