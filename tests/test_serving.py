"""Serving engine: continuous batching, ragged decode, phase scheduler,
chunked prefill, device-side sampling, strategy group routing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models.transformer import decode_step, init_params, prefill
from repro.serving.engine import RequestState, ServeConfig, ServingEngine
from repro.serving.scheduler import PhaseAwareConfig, PhaseScheduler


def tiny_cfg(name="qwen3-1.7b"):
    return dataclasses.replace(get_config(name).reduced(), dtype="float32")


def make_engine(cfg, max_batch=3, max_len=64, strategy="halo",
                prefill_chunk=2048, max_prefill_tokens=8192):
    params = init_params(jax.random.PRNGKey(0), cfg)
    sc = ServeConfig(max_batch=max_batch, max_len=max_len,
                     phase=PhaseAwareConfig(strategy=strategy,
                                            max_decode_batch=max_batch,
                                            prefill_chunk=prefill_chunk,
                                            max_prefill_tokens=max_prefill_tokens))
    return ServingEngine(cfg, params, sc), params


def prompts(cfg, n, L, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        if cfg.n_codebooks > 1:
            out.append(rng.integers(0, cfg.vocab_size,
                                    (cfg.n_codebooks, L), dtype=np.int32))
        else:
            out.append(rng.integers(0, cfg.vocab_size, (L,), dtype=np.int32))
    return out


def test_engine_drains_all_requests():
    cfg = tiny_cfg()
    eng, _ = make_engine(cfg)
    for p in prompts(cfg, 7, 16):
        eng.submit(p, max_new_tokens=5)
    done = eng.run_until_drained()
    assert len(done) == 7
    for r in done:
        assert r.state == RequestState.DONE
        assert len(r.generated) == 5
        assert r.ttft > 0 and r.t_done >= r.t_first_token


def test_engine_matches_straight_decode():
    """Engine output for one request == direct prefill+greedy decode."""
    cfg = tiny_cfg()
    eng, params = make_engine(cfg, max_batch=2, max_len=64)
    p = prompts(cfg, 1, 20, seed=3)[0]
    req = eng.submit(p, max_new_tokens=6)
    eng.run_until_drained()

    # oracle: straight greedy decode
    logits, cache = prefill(params, cfg, {"tokens": jnp.asarray(p[None])})
    from repro.models.transformer import pad_cache
    cache = pad_cache(cfg, cache, 20, 64)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = 20
    for _ in range(5):
        dl, cache = decode_step(params, cfg,
                                {"tokens": jnp.asarray([[toks[-1]]])},
                                cache, jnp.int32(pos))
        toks.append(int(jnp.argmax(dl[0, -1])))
        pos += 1
    assert req.generated == toks


def test_engine_ragged_batches_are_isolated():
    """Interleaved requests of different lengths must produce the same
    outputs as running each alone (slot isolation)."""
    cfg = tiny_cfg()
    solo_outputs = []
    for i, L in enumerate((12, 20)):
        eng, _ = make_engine(cfg, max_batch=1)
        p = prompts(cfg, 1, L, seed=10 + i)[0]
        r = eng.submit(p, max_new_tokens=4)
        eng.run_until_drained()
        solo_outputs.append(r.generated)

    eng, _ = make_engine(cfg, max_batch=2)
    p0 = prompts(cfg, 1, 12, seed=10)[0]
    p1 = prompts(cfg, 1, 20, seed=11)[0]
    r0 = eng.submit(p0, max_new_tokens=4)
    r1 = eng.submit(p1, max_new_tokens=4)
    eng.run_until_drained()
    assert r0.generated == solo_outputs[0]
    assert r1.generated == solo_outputs[1]


def test_continuous_batching_refills_slots():
    cfg = tiny_cfg()
    eng, _ = make_engine(cfg, max_batch=2)
    for p in prompts(cfg, 5, 8):
        eng.submit(p, max_new_tokens=3)
    peak_active = 0
    ticks = 0
    while (eng.queue or any(eng.slot_req)) and ticks < 100:
        eng.step()
        peak_active = max(peak_active, eng.counts()["active"])
        ticks += 1
    assert len(eng.done) == 5
    assert peak_active == 2               # slots stayed saturated


def test_eos_stops_generation():
    cfg = tiny_cfg()
    eng, params = make_engine(cfg)
    p = prompts(cfg, 1, 16)[0]
    # run once to learn what the first generated token will be
    probe = eng.submit(p, max_new_tokens=1)
    eng.run_until_drained()
    first = probe.generated[0]
    eng2, _ = make_engine(cfg)
    r = eng2.submit(p, max_new_tokens=10, eos_id=first)
    eng2.run_until_drained()
    assert len(r.generated) == 1          # stopped at eos immediately


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "zamba2-2.7b",
                                  "deepseek-v2-236b", "gemma3-1b"])
def test_engine_other_families(arch):
    """SSM / hybrid / MLA / local-global archs serve correctly too."""
    cfg = tiny_cfg(arch)
    eng, _ = make_engine(cfg, max_batch=2, max_len=48)
    for p in prompts(cfg, 3, 12):
        eng.submit(p, max_new_tokens=3)
    done = eng.run_until_drained()
    assert len(done) == 3
    assert all(len(r.generated) == 3 for r in done)


# ---------------------------------------------------------------------------
# chunked prefill: the engine executes the scheduler's TickPlan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-1b",
                                  "deepseek-v2-236b"])
def test_chunked_prefill_token_identical(arch):
    """A prompt longer than prefill_chunk prefills across >= 2 ticks and
    produces EXACTLY the tokens of an unchunked (single-chunk) prefill —
    GQA, sliding-window ring, and MLA latent arenas alike."""
    cfg = tiny_cfg(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    p = prompts(cfg, 1, 40, seed=5)[0]
    outs, prefill_ticks = [], []
    for chunk in (64, 16, 7):
        sc = ServeConfig(max_batch=2, max_len=96,
                         phase=PhaseAwareConfig(max_decode_batch=2,
                                                prefill_chunk=chunk,
                                                max_prefill_tokens=chunk))
        eng = ServingEngine(cfg, params, sc)
        r = eng.submit(p.copy(), max_new_tokens=6)
        eng.run_until_drained()
        outs.append(r.generated)
        prefill_ticks.append(
            sum(1 for t in eng.tick_log if r.req_id in t.prefill_reqs))
    assert outs[0] == outs[1] == outs[2]
    assert prefill_ticks[0] == 1          # 40 <= 64: one chunk
    assert prefill_ticks[1] == 3          # ceil(40/16)
    assert prefill_ticks[2] == 6          # ceil(40/7)


def test_decode_interleaves_with_long_prefill():
    """Decode ticks run BETWEEN the chunks of a long prompt: a request
    already decoding keeps emitting one token per tick while a long
    prompt behind it prefills chunk by chunk (no head-of-line blocking)."""
    cfg = tiny_cfg()
    eng, _ = make_engine(cfg, max_batch=2, max_len=96,
                         prefill_chunk=8, max_prefill_tokens=8)
    a = eng.submit(prompts(cfg, 1, 8, seed=0)[0], max_new_tokens=30)
    eng.step()
    assert a.state == RequestState.DECODING
    b = eng.submit(prompts(cfg, 1, 40, seed=1)[0], max_new_tokens=4)
    n_before = len(a.generated)
    for _ in range(4):                    # b needs ceil(40/8) = 5 ticks
        eng.step()
        assert b.state == RequestState.PREFILLING
    assert len(a.generated) == n_before + 4   # a decoded on EVERY tick
    eng.step()
    assert b.state == RequestState.DECODING   # 5th chunk completed b
    mixed = [t for t in eng.tick_log if t.mixed]
    assert len(mixed) >= 5                # interleaved, not serialized
    assert eng.phase_occupancy()["mixed"] > 0


def test_short_request_ttft_improves_behind_long_prompt():
    """Chunked prefill shares the tick budget: a short prompt admitted
    while a long one is mid-prefill gets its first token without waiting
    for the long prefill to finish (measured in ticks, not wall time)."""
    cfg = tiny_cfg()

    def ticks_to_first_token(chunk, budget):
        eng, _ = make_engine(cfg, max_batch=2, max_len=96,
                             prefill_chunk=chunk, max_prefill_tokens=budget)
        long = eng.submit(prompts(cfg, 1, 48, seed=2)[0], max_new_tokens=4)
        eng.step()                        # long starts prefilling
        short = eng.submit(prompts(cfg, 1, 8, seed=3)[0], max_new_tokens=4)
        n = 0
        while not short.generated and n < 50:
            eng.step()
            n += 1
        return n, long, short

    # chunked: budget 16 fits one long chunk AND the whole short prompt
    n_chunked, long_c, _ = ticks_to_first_token(chunk=8, budget=16)
    assert n_chunked == 1                 # first tick after submission
    assert long_c.state == RequestState.PREFILLING  # still mid-prefill
    # unchunked (chunk >= prompt): the long prefill is atomic, but the
    # short request still cannot beat it — it lands strictly later in
    # the same tick ordering; assert the chunked TTFT is no worse
    n_unchunked, _, _ = ticks_to_first_token(chunk=2048, budget=8192)
    assert n_chunked <= n_unchunked


def test_strategy_groups_route_programs():
    """cent/attacc route phases onto one worker group; the engine must
    execute (and compile) only that group's programs, as the TickPlan says."""
    cfg = tiny_cfg()
    want = {"halo": ("prefill", "decode"),
            "cent": ("decode", "decode"),
            "attacc": ("prefill", "prefill")}
    for strategy, (pg, dg) in want.items():
        eng, _ = make_engine(cfg, max_batch=2, strategy=strategy)
        for p in prompts(cfg, 3, 12):
            eng.submit(p, max_new_tokens=3)
        eng.run_until_drained()
        assert all(t.prefill_group == pg and t.decode_group == dg
                   for t in eng.tick_log)
        groups_used = {g for g, _ in eng._programs}
        assert groups_used == {pg, dg}
        # packed prefill (the default) compiles the flat-stream program;
        # either way the prefill work must land on the routed group
        assert (pg, "packed") in eng._programs or \
            (pg, "chunk") in eng._programs
        assert (dg, "decode") in eng._programs


def test_decode_tick_is_single_host_transfer(monkeypatch):
    """Device-side sampling: a decode tick moves ONE [B]-shaped token
    array to the host — not one logits row per active slot."""
    cfg = tiny_cfg()
    eng, _ = make_engine(cfg, max_batch=3)
    for p in prompts(cfg, 3, 8):
        eng.submit(p, max_new_tokens=8)
    eng.step()                            # prefill tick: all 3 now decoding
    assert all(r is not None and r.state == RequestState.DECODING
               for r in eng.slot_req)

    transfers = []
    orig = ServingEngine._to_host

    def counting(self, arr):
        transfers.append(np.asarray(arr).shape)
        return orig(self, arr)

    monkeypatch.setattr(ServingEngine, "_to_host", counting)
    eng.step()                            # pure decode tick
    assert transfers == [(eng.sc.max_batch,)]


def test_prefill_tick_batches_multiple_requests():
    """Multi-request prefill is pad-and-pack: one program call (and one
    host transfer) covers every chunk of the tick."""
    cfg = tiny_cfg()
    eng, _ = make_engine(cfg, max_batch=3)
    for i, p in enumerate(prompts(cfg, 3, 10)):
        eng.submit(p, max_new_tokens=2)
    eng.step()
    assert eng.host_transfers == 1        # 3 prompts, one packed transfer
    assert len(eng.tick_log) == 1
    assert len(eng.tick_log[0].prefill_reqs) == 3
    assert eng.tick_log[0].prefill_tokens == 30


# ---------------------------------------------------------------------------
# device-side sampling (serving/sampling.py)
# ---------------------------------------------------------------------------


def test_top_k_ties_respect_candidate_budget():
    """Regression: with ties AT the k-th value, the old ``scaled < kth``
    mask kept every tied logit (more than k candidates).  lax.top_k's
    index set is exactly k wide — sampling must never leave it."""
    from repro.serving.sampling import sample_tokens

    # four-way tie at the top, k = 2: exactly 2 tokens may ever appear
    logits = jnp.array([[5.0, 5.0, 5.0, 5.0, 1.0, 0.0]])
    vals, idx = jax.lax.top_k(logits[0], 2)
    allowed = set(np.asarray(idx).tolist())
    seen = set()
    for i in range(200):
        t = sample_tokens(logits, greedy=False, temperature=1.0, top_k=2,
                          key=jax.random.PRNGKey(i))
        seen.add(int(t[0]))
    assert seen <= allowed
    assert len(seen) == 2                 # both survivors actually reachable


def test_top_k_masks_low_logits_and_clamps():
    from repro.serving.sampling import sample_tokens

    logits = jnp.array([[0.0, 10.0, 9.0, -3.0]])
    seen = {int(sample_tokens(logits, greedy=False, temperature=0.5,
                              top_k=2, key=jax.random.PRNGKey(i))[0])
            for i in range(100)}
    assert seen <= {1, 2}                 # only the top-2 survive
    # k > V clamps instead of crashing; greedy ignores k entirely
    t = sample_tokens(logits, greedy=False, temperature=1.0, top_k=99,
                      key=jax.random.PRNGKey(0))
    assert 0 <= int(t[0]) < 4
    assert int(sample_tokens(logits, greedy=True)[0]) == 1


def test_top_k_batch_rows_independent():
    """Each row's k-candidate set is its own (put_along_axis is per-row)."""
    from repro.serving.sampling import sample_tokens

    logits = jnp.array([[9.0, 8.0, 0.0, 0.0],
                        [0.0, 0.0, 8.0, 9.0]])
    for i in range(50):
        a, b = np.asarray(sample_tokens(
            logits, greedy=False, temperature=0.7, top_k=2,
            key=jax.random.PRNGKey(i)))
        assert int(a) in (0, 1) and int(b) in (2, 3)


# ---------------------------------------------------------------------------
# phase scheduler (pure logic)
# ---------------------------------------------------------------------------


def test_scheduler_strategy_groups():
    assert PhaseScheduler(PhaseAwareConfig("halo")).groups_for() == (
        "prefill", "decode")
    assert PhaseScheduler(PhaseAwareConfig("cent")).groups_for() == (
        "decode", "decode")
    assert PhaseScheduler(PhaseAwareConfig("attacc")).groups_for() == (
        "prefill", "prefill")


def test_scheduler_decode_priority_and_budget():
    s = PhaseScheduler(PhaseAwareConfig(
        "halo", max_decode_batch=2, max_prefill_tokens=1000,
        prefill_chunk=600))
    plan = s.plan_tick(waiting=[(10, 600), (11, 600), (12, 600)],
                       decoding=[1, 2, 3])
    assert plan.decode_reqs == [1, 2]     # capped at max_decode_batch
    assert plan.prefill_reqs == [10, 11]  # 600+600 > 1000 budget stops at 2
    assert plan.prefill_chunks == [(10, 600), (11, 400)]   # budget-clipped
    assert plan.prefill_tokens == 1000


def test_scheduler_chunks_long_prompts():
    s = PhaseScheduler(PhaseAwareConfig(
        "halo", max_decode_batch=4, max_prefill_tokens=512,
        prefill_chunk=128))
    plan = s.plan_tick(waiting=[(7, 1000)], decoding=[])
    assert plan.prefill_chunks == [(7, 128)]   # one chunk per tick
    # non-chunkable (SSM plan): scheduled atomically, whole prompt at once
    plan = s.plan_tick(waiting=[(8, 1000, False)], decoding=[])
    assert plan.prefill_chunks == [(8, 1000)]
    # ...but a spent budget defers FURTHER atomic prompts to later ticks
    # (no pile-up of whole-prompt prefills ahead of the decode phase)
    plan = s.plan_tick(waiting=[(8, 1000, False), (9, 800, False)],
                       decoding=[])
    assert plan.prefill_chunks == [(8, 1000)]
