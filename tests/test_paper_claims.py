"""Every quantitative claim in the paper, asserted against the analytical
HALO model (the reproduction gate).  Tolerances are ±25% on geometric-mean
ratios — the paper publishes gmeans over (L_in, L_out) grids whose exact
points are only partially specified, so exact equality is not expected;
what must hold is each claim's magnitude and direction.

Paper sources: Fig.5 (TTFT/energy fully-CiD vs fully-CiM), Fig.6 (TPOT/
energy), Fig.7 (end-to-end + phase split vs CENT/AttAcc), Fig.8 (energy),
Fig.9 (batch-size crossover), Fig.10 (CiM vs iso-area systolic array).
"""

import pytest

from repro.configs.base import get_config
from repro.core.scheduler import (
    DECODE_GRID,
    PREFILL_LENGTHS,
    evaluate,
    geomean,
    gmean_speedup,
)

llama = get_config("llama2-7b")
qwen = get_config("qwen3-8b")


def within(got, want, tol=0.25):
    assert want * (1 - tol) <= got <= want * (1 + tol), (
        f"got {got:.2f}, paper {want:.2f}")


# --- Section V-B: fully-CiD vs fully-CiM extremes ---------------------------


def test_fig5a_prefill_cim_speedup_6x():
    r = geomean([evaluate(llama, "full_cid", L, 1).ttft
                 / evaluate(llama, "full_cim", L, 1).ttft
                 for L in PREFILL_LENGTHS])
    within(r, 6.0)


def test_fig5b_prefill_energy_2p6x():
    r = geomean([evaluate(llama, "full_cid", L, 1).prefill_energy
                 / evaluate(llama, "full_cim", L, 1).prefill_energy
                 for L in PREFILL_LENGTHS])
    within(r, 2.6)


def test_fig6a_decode_cid_speedup_39x():
    r = geomean([evaluate(llama, "full_cim", li, lo).tpot
                 / evaluate(llama, "full_cid", li, lo).tpot
                 for li, lo in DECODE_GRID])
    within(r, 39.0)


def test_fig6b_decode_energy_3p9x():
    r = geomean([evaluate(llama, "full_cim", li, lo).decode_energy
                 / evaluate(llama, "full_cid", li, lo).decode_energy
                 for li, lo in DECODE_GRID])
    within(r, 3.9)


# --- Section V-C: vs prior-work mappings ------------------------------------


def test_fig7_prefill_halo_vs_cent_6p54x():
    within(gmean_speedup(llama, "cent", "halo1", metric="ttft"), 6.54)


def test_fig7_decode_halo_vs_attacc_34x():
    within(gmean_speedup(llama, "attacc1", "halo1", metric="tpot"), 34.0)


@pytest.mark.parametrize("model", [llama, qwen], ids=["llama2", "qwen3"])
def test_fig7_e2e_18x_vs_attacc(model):
    within(gmean_speedup(model, "attacc1", "halo1"), 18.0)


@pytest.mark.parametrize("model", [llama, qwen], ids=["llama2", "qwen3"])
def test_fig7_e2e_2p4x_vs_cent(model):
    within(gmean_speedup(model, "cent", "halo1"), 2.4)


def test_halo2_only_10pct_slower():
    within(gmean_speedup(llama, "halo2", "halo1"), 1.10, tol=0.08)


def test_fig8_energy_2x_vs_attacc():
    within(gmean_speedup(llama, "attacc1", "halo1", metric="energy"), 2.0)


def test_fig8_energy_1p8x_vs_cent():
    within(gmean_speedup(llama, "cent", "halo1", metric="energy"), 1.8)


def test_fig8_halo2_energy_comparable_to_cent():
    """HALO2's double ADC accesses make its energy ~CENT's (Sec V-C)."""
    r = gmean_speedup(llama, "cent", "halo2", metric="energy")
    assert 0.7 <= r <= 1.5


# --- Fig. 9: batch-size crossover --------------------------------------------


def test_fig9_attacc_wins_at_high_batch():
    """At batch>=64 (L_in=128, L_out=2048) AttAcc1 overtakes CENT; HALO
    stays competitive at low batch."""
    l_in, l_out = 128, 2048
    lo_b = 1
    hi_b = 64
    halo_lo = evaluate(llama, "halo1", l_in, l_out, lo_b).e2e
    attacc_lo = evaluate(llama, "attacc1", l_in, l_out, lo_b).e2e
    assert halo_lo < attacc_lo            # low batch: HALO wins
    halo_hi = evaluate(llama, "halo1", l_in, l_out, hi_b).e2e
    attacc_hi = evaluate(llama, "attacc1", l_in, l_out, hi_b).e2e
    cent_hi = evaluate(llama, "cent", l_in, l_out, hi_b).e2e
    assert attacc_hi < cent_hi            # high batch: CiM for non-attn wins
    # per-request latency improves with batch for the batched mappings
    assert evaluate(llama, "attacc1", l_in, l_out, 64).e2e / 64 \
        < evaluate(llama, "attacc1", l_in, l_out, 1).e2e


# --- Fig. 10: analog CiM vs iso-area systolic array ---------------------------


def test_fig10_cim_1p3x_over_systolic():
    within(gmean_speedup(llama, "halo_sa", "halo1"), 1.3, tol=0.15)


# --- structural claims --------------------------------------------------------


def test_fig4_phase_boundedness():
    """Fig 4's message: prefill GEMMs are COMPUTE-bound on CiM while decode
    GEMVs are WEIGHT-STREAM-bound — the premise of phase-aware mapping."""
    from repro.core.hardware import DEFAULT_HW
    from repro.core.opgraph import decode_ops, prefill_ops

    cim = DEFAULT_HW.cim

    def bound_fracs(ops):
        comp_flops = stream_flops = 0
        for op in ops:
            if op.kind not in ("matmul", "attn"):
                continue
            t_c = op.flops / cim.peak_ops
            t_f = op.total_stream / cim.fill_bw
            if t_c >= t_f:
                comp_flops += op.flops
            else:
                stream_flops += op.flops
        tot = comp_flops + stream_flops
        return comp_flops / tot if tot else 0.0

    assert bound_fracs(prefill_ops(llama, 2048, 1)) > 0.9   # compute-bound
    assert bound_fracs(decode_ops(llama, 2048, 1)) < 0.1    # stream-bound


def test_prefill_flops_linear_in_batch():
    from repro.core.opgraph import prefill_ops, total_flops

    f1 = total_flops(prefill_ops(llama, 512, 1))
    f4 = total_flops(prefill_ops(llama, 512, 4))
    assert 3.5 <= f4 / f1 <= 4.5
