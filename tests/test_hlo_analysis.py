"""HLO static analyzer: validated against programs with KNOWN flop counts.

The critical property: lax.scan bodies must be multiplied by their trip
count (XLA's own cost_analysis counts them once — the reason this analyzer
exists).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo, parse_stack_tables
from repro.launch.roofline import Roofline, model_flops_for, parse_collectives


def compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_plain_matmul_flops_exact():
    M, K, N = 128, 256, 64
    x = jnp.ones((M, K), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    text = compile_text(lambda a, b: a @ b, x, w)
    hs = analyze_hlo(text)
    assert hs.flops == 2 * M * K * N


def test_scan_multiplies_flops_by_trip_count():
    """10-iteration scan of a matmul must count 10x the single-dot flops."""
    M = 64
    x = jnp.ones((M, M), jnp.float32)
    ws = jnp.ones((10, M, M), jnp.float32)

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    text = compile_text(f, x, ws)
    hs = analyze_hlo(text)
    want = 10 * 2 * M * M * M
    assert hs.flops == pytest.approx(want, rel=0.01), (hs.flops, want)


def test_nested_scan_trip_counts_compose():
    M = 32
    x = jnp.ones((M, M), jnp.float32)
    ws = jnp.ones((4, 3, M, M), jnp.float32)

    def f(x, ws):
        def outer(c, wrow):
            def inner(ci, w):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, wrow)
            return c2, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out

    text = compile_text(f, x, ws)
    hs = analyze_hlo(text)
    want = 12 * 2 * M ** 3
    assert hs.flops == pytest.approx(want, rel=0.01)


def test_batched_dot_flops():
    B, M, K, N = 4, 32, 64, 16
    a = jnp.ones((B, M, K), jnp.float32)
    b = jnp.ones((B, K, N), jnp.float32)
    text = compile_text(
        lambda a, b: jnp.einsum("bmk,bkn->bmn", a, b), a, b)
    hs = analyze_hlo(text)
    assert hs.flops == 2 * B * M * K * N


def test_grad_flops_about_3x_forward():
    M = 64
    x = jnp.ones((M, M), jnp.float32)
    w = jnp.ones((M, M), jnp.float32)

    fwd_text = compile_text(lambda w: jnp.sum(x @ w), w)
    grad_text = compile_text(jax.grad(lambda w: jnp.sum(x @ w)), w)
    f_fwd = analyze_hlo(fwd_text).flops
    f_grad = analyze_hlo(grad_text).flops
    # d(loss)/dw = x^T @ dy : one extra matmul (dy is rank-1 broadcast here,
    # so grad-of-matmul costs 1 dot); ratio in [1, 3]
    assert f_fwd > 0 and f_grad >= f_fwd * 0.99


def test_hbm_bytes_counts_dot_streams():
    M, K, N = 128, 256, 64
    x = jnp.ones((M, K), jnp.float32)
    w = jnp.ones((K, N), jnp.float32)
    text = compile_text(lambda a, b: a @ b, x, w)
    hs = analyze_hlo(text)
    want = 4 * (M * K + K * N + M * N)       # operands + result, f32
    # + entry params counted once more (read-once charge)
    assert hs.hbm_bytes >= want
    assert hs.hbm_bytes <= 2.5 * want


def test_stack_tables_parse():
    def f(x):
        return jnp.sin(x) @ x

    text = compile_text(f, jnp.ones((8, 8)))
    frames = parse_stack_tables(text)
    all_fns = set()
    for s in frames.values():
        all_fns |= s
    # the traced function's name must appear (via the XLA stack tables
    # when emitted, else via the op_name-metadata fallback: "jit(f)/...")
    assert any("f" == fn or fn.endswith(".f") for fn in all_fns), all_fns


# ---------------------------------------------------------------------------
# collective parsing (synthetic HLO lines)
# ---------------------------------------------------------------------------


SYNTH = """
HloModule test
ENTRY %main (p0: f32[256,128]) -> f32[256,128] {
  %p0 = f32[256,128]{1,0} parameter(0)
  %ag = f32[256,128]{1,0} all-gather(%p0), dimensions={0}
  %ar = f32[256,128]{1,0} all-reduce(%ag), to_apply=%add
  %rs = f32[16,128]{1,0} reduce-scatter(%ar), dimensions={0}
  ROOT %cp = f32[256,128]{1,0} collective-permute(%ar)
}
"""


def test_parse_collectives_kinds_and_bytes():
    st = parse_collectives(SYNTH)
    b = 256 * 128 * 4
    assert st.bytes_by_kind["all-gather"] == b
    assert st.bytes_by_kind["all-reduce"] == b
    assert st.bytes_by_kind["reduce-scatter"] == 16 * 128 * 4
    assert st.bytes_by_kind["collective-permute"] == b
    # ring model: AR weighted 2x
    assert st.weighted_bytes == 2 * b + b + 16 * 128 * 4 + b


# ---------------------------------------------------------------------------
# roofline dataclass
# ---------------------------------------------------------------------------


def test_roofline_terms_and_bottleneck():
    r = Roofline(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=50e9 * 0.5,
                 model_flops=197e12 * 256, n_chips=256)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.useful_flops_frac == pytest.approx(1.0)
    assert r.roofline_frac == pytest.approx(0.5)   # useful time / bound


def test_model_flops_for_decode_includes_kv():
    from repro.configs.base import get_config

    cfg = get_config("qwen3-1.7b")
    base = 2.0 * cfg.active_param_count() * 128
    got = model_flops_for(cfg, "decode", 32768, 128)
    assert got > base                      # + attention over the cache
    # SSM archs: no KV attention term
    m = get_config("mamba2-2.7b")
    got_m = model_flops_for(m, "decode", 32768, 128)
    assert got_m == pytest.approx(2.0 * m.active_param_count() * 128)
