"""Train a small model end to end with the full substrate: data pipeline,
WSD/cosine schedule, AdamW, checkpointing, fault-tolerant trainer — then
kill it mid-run and restart from the checkpoint to demonstrate recovery.

Run:  PYTHONPATH=src python examples/train_small.py
"""

import dataclasses
import shutil
import tempfile


from repro.configs.base import get_config
from repro.data.pipeline import DataConfig
from repro.optim.optimizers import adamw
from repro.optim.schedules import wsd_schedule
from repro.runtime.trainer import Trainer, TrainerConfig


def make_trainer(cfg, data_cfg, ckdir, steps):
    opt = adamw(wsd_schedule(3e-3, steps, warmup_steps=10))
    tc = TrainerConfig(total_steps=steps, checkpoint_every=25,
                       checkpoint_dir=ckdir, log_every=20,
                       async_checkpoint=True, remat=False)
    return Trainer(cfg, opt, data_cfg, tc)


def main():
    cfg = dataclasses.replace(get_config("minicpm-2b").reduced(),
                              dtype="float32")
    data_cfg = DataConfig(seq_len=64, global_batch=8,
                          vocab_size=cfg.vocab_size, seed=0)
    ckdir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        # phase 1: run 50 steps (checkpoints at 25 and 50), then "crash"
        t1 = make_trainer(cfg, data_cfg, ckdir, 50)
        out1 = t1.run()
        print(f"[phase1] 50 steps, loss -> {out1['final_loss']:.4f} "
              f"(simulated failure here)")

        # phase 2: a NEW trainer process restores and continues to 100
        t2 = make_trainer(cfg, data_cfg, ckdir, 100)
        out2 = t2.run()
        print(f"[phase2] resumed from step 50, trained to 100, "
              f"loss -> {out2['final_loss']:.4f}")
        first = t1.history[0]["loss"]
        assert out2["final_loss"] < first * 0.5, "training did not converge"
        print(f"[ok] loss fell {first:.3f} -> {out2['final_loss']:.3f} "
              "across a checkpoint/restart boundary")
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)


if __name__ == "__main__":
    main()
