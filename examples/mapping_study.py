"""Reproduce the paper's architecture study (Sections V-B .. V-D):
sweep every mapping over the (L_in, L_out) grid and print the normalized
end-to-end table — Fig. 7's data — plus the fully-CiD vs fully-CiM extremes
(Fig. 5/6) and the batch crossover (Fig. 9).

Run:  PYTHONPATH=src python examples/mapping_study.py [--model qwen3-8b]
"""

import argparse

from repro.configs.base import get_config
from repro.core.scheduler import (
    DEFAULT_GRID,
    PREFILL_LENGTHS,
    evaluate,
    gmean_speedup,
)

MAPPINGS = ("halo1", "halo2", "cent", "attacc1", "attacc2")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="llama2-7b")
    args = ap.parse_args()
    cfg = get_config(args.model)

    print(f"=== {cfg.name}: normalized e2e per (L_in, L_out) — Fig. 7 ===")
    header = f"{'L_in':>6} {'L_out':>6}" + "".join(
        f"{m:>10}" for m in MAPPINGS)
    print(header)
    for li, lo in DEFAULT_GRID:
        res = {m: evaluate(cfg, m, li, lo).e2e for m in MAPPINGS}
        worst = max(res.values())
        print(f"{li:>6} {lo:>6}" + "".join(
            f"{res[m]/worst:>10.3f}" for m in MAPPINGS))

    print("\n=== fully-CiD vs fully-CiM (Fig. 5/6) ===")
    for L in PREFILL_LENGTHS:
        cid = evaluate(cfg, "full_cid", L, 1)
        cim = evaluate(cfg, "full_cim", L, 1)
        print(f"TTFT L={L:<6} CiD {cid.ttft*1e3:9.1f}ms  "
              f"CiM {cim.ttft*1e3:9.1f}ms  ({cid.ttft/cim.ttft:.1f}x)")
    t_cid = evaluate(cfg, "full_cid", 2048, 512)
    t_cim = evaluate(cfg, "full_cim", 2048, 512)
    print(f"TPOT @2048: CiD {t_cid.tpot*1e3:.2f}ms vs CiM "
          f"{t_cim.tpot*1e3:.2f}ms ({t_cim.tpot/t_cid.tpot:.0f}x)")

    print("\n=== batch-size crossover (Fig. 9; L_in=128, L_out=2048) ===")
    print(f"{'batch':>6}" + "".join(f"{m:>10}" for m in
                                    ("halo1", "cent", "attacc1")))
    for bs in (1, 4, 16, 64):
        vals = [evaluate(cfg, m, 128, 2048, batch=bs).e2e
                for m in ("halo1", "cent", "attacc1")]
        print(f"{bs:>6}" + "".join(f"{v:>10.2f}" for v in vals))

    print("\n=== headline gmeans ===")
    print(f"e2e vs AttAcc1: {gmean_speedup(cfg, 'attacc1', 'halo1'):5.1f}x "
          "(paper: 18x)")
    print(f"e2e vs CENT:    {gmean_speedup(cfg, 'cent', 'halo1'):5.1f}x "
          "(paper: 2.4x)")


if __name__ == "__main__":
    main()
