"""Quickstart: the three layers of the framework in one script.

1. the analytical HALO model — reproduce a paper number in two lines;
2. a JAX model forward/generate on a reduced config;
3. the phase-aware serving engine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# --- 1. paper model ----------------------------------------------------------
from repro.configs.base import get_config
from repro.core.scheduler import evaluate, gmean_speedup

llama = get_config("llama2-7b")
r = evaluate(llama, "halo1", l_in=2048, l_out=512)
print(f"[paper] HALO1 @ L_in=2048 L_out=512: "
      f"TTFT={r.ttft*1e3:.1f}ms TPOT={r.tpot*1e3:.2f}ms "
      f"E={r.energy:.1f}J")
print(f"[paper] e2e gmean speedup over CENT: "
      f"{gmean_speedup(llama, 'cent', 'halo1'):.2f}x (paper: 2.4x)")

# --- 2. a real model ----------------------------------------------------------
from repro.models.transformer import init_params, prefill, decode_step, pad_cache

cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(), dtype="float32")
params = init_params(jax.random.PRNGKey(0), cfg)
prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 24), 0, cfg.vocab_size)
logits, cache = prefill(params, cfg, {"tokens": prompt})
cache = pad_cache(cfg, cache, 24, 48)
toks = [int(jnp.argmax(logits[0, -1]))]
for i in range(8):
    logits, cache = decode_step(
        params, cfg, {"tokens": jnp.asarray([[toks[-1]]])}, cache,
        jnp.int32(24 + i))
    toks.append(int(jnp.argmax(logits[0, -1])))
print(f"[model] qwen3-1.7b (reduced) greedy continuation: {toks}")

# --- 3. serving engine ---------------------------------------------------------
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.scheduler import PhaseAwareConfig

engine = ServingEngine(cfg, params, ServeConfig(
    max_batch=2, max_len=64, phase=PhaseAwareConfig(strategy="halo")))
rng = np.random.default_rng(0)
for _ in range(4):
    engine.submit(rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32),
                  max_new_tokens=4)
done = engine.run_until_drained()
print(f"[serve] {len(done)} requests, "
      f"TTFT p50 = {np.median([r.ttft for r in done])*1e3:.0f} ms, "
      f"outputs: {[r.generated for r in done]}")
