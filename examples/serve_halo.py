"""End-to-end serving driver (the paper is an inference paper, so this is
the primary example): a small model serves a batched request stream through
the phase-disaggregated engine, comparing HALO's phase-aware strategy with
the CENT- and AttAcc-style mappings, and reporting TTFT / TPOT / throughput
per strategy — the measured counterpart of the paper's Fig. 7.  A second
table shows the chunked-prefill TTFT/TPOT trade-off on long prompts: with
chunking, decode ticks interleave between the chunks of a long prefill
(``mixed`` tick fraction > 0) instead of head-of-line blocking behind it.
Later tables show the paged-vs-dense KV arena, the radix prefix cache on
a shared-system-prompt stream, and speculative decoding (n-gram and
small-model drafters) — every variant must reproduce the reference token
streams exactly.  A quantized-serving table sweeps weight/KV dtype
combinations (int8 weights through the fused GEMV, int8/int4 KV pages):
those track the f32 reference within tolerance rather than exactly, and
report the per-page KV bytes they save.

Run:  PYTHONPATH=src python examples/serve_halo.py [--requests 24]
"""

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.models.transformer import init_params
from repro.serving.engine import ServeConfig, ServingEngine
from repro.serving.scheduler import PhaseAwareConfig


def run_stream(cfg, params, prompts, *, strategy="halo", max_new=12,
               max_batch=4, max_len=128, prefill_chunk=2048,
               max_prefill_tokens=8192, paged=False, page_size=16,
               n_pages=64, prefix_cache=False, speculative=None,
               kv_dtype="f32", weights_dtype="f32",
               executor="colocated", host_spill_pages=0,
               tracer=None, slo=None):
    engine = ServingEngine(cfg, params, ServeConfig(
        max_batch=max_batch, max_len=max_len,
        phase=PhaseAwareConfig(strategy=strategy,
                               max_decode_batch=max_batch,
                               prefill_chunk=prefill_chunk,
                               max_prefill_tokens=max_prefill_tokens),
        paged=paged, page_size=page_size, n_pages=n_pages,
        prefix_cache=prefix_cache, speculative=speculative,
        kv_dtype=kv_dtype, weights_dtype=weights_dtype,
        executor=executor, host_spill_pages=host_spill_pages),
        tracer=tracer)
    t0 = time.monotonic()
    for p in prompts:
        engine.submit(p.copy(), max_new_tokens=max_new, slo=slo)
    done = sorted(engine.run_until_drained(), key=lambda r: r.req_id)
    wall = time.monotonic() - t0
    return engine, done, wall


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="also write the observability section's Chrome "
                         "trace-event JSON here (open in Perfetto)")
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch).reduced(),
                              dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, (args.prompt_len,),
                            dtype=np.int32) for _ in range(args.requests)]
    max_len = args.prompt_len + args.max_new + 8

    print(f"{'strategy':10s} {'TTFT p50':>10s} {'TPOT p50':>10s} "
          f"{'tok/s':>8s}  outputs identical?")
    base_outputs = None
    for strategy in ("halo", "cent", "attacc"):
        _, done, wall = run_stream(cfg, params, prompts, strategy=strategy,
                                   max_new=args.max_new, max_len=max_len)
        outs = [r.generated for r in done]
        if base_outputs is None:
            base_outputs = outs
            same = "(reference)"
        else:
            same = "yes" if outs == base_outputs else "NO"
        toks = sum(len(o) for o in outs)
        print(f"{strategy:10s} "
              f"{np.median([r.ttft for r in done])*1e3:9.1f}ms "
              f"{np.median([r.tpot for r in done])*1e3:9.1f}ms "
              f"{toks/wall:8.1f}  {same}")

    print(f"\n{'prefill':10s} {'TTFT p50':>10s} {'TPOT p50':>10s} "
          f"{'tok/s':>8s} {'mixed ticks':>12s}")
    long_prompts = [rng.integers(0, cfg.vocab_size, (96,), dtype=np.int32)
                    for _ in range(args.requests)]
    for label, chunk, budget in (("unchunked", 2048, 8192),
                                 ("chunked", 16, 32)):
        eng, done, wall = run_stream(cfg, params, long_prompts,
                                     max_new=args.max_new,
                                     max_len=96 + args.max_new + 8,
                                     prefill_chunk=chunk,
                                     max_prefill_tokens=budget)
        toks = sum(len(r.generated) for r in done)
        occ = eng.phase_occupancy()
        print(f"{label:10s} "
              f"{np.median([r.ttft for r in done])*1e3:9.1f}ms "
              f"{np.median([r.tpot for r in done])*1e3:9.1f}ms "
              f"{toks/wall:8.1f} {occ['mixed']:11.2f}")

    print(f"\n{'kv arena':22s} {'prompt':>7s} {'reserved':>10s} "
          f"{'peak-res':>10s} {'preempt':>8s}  outputs identical?")
    for plen in (48, 96):
        stream = [rng.integers(0, cfg.vocab_size, (plen,), dtype=np.int32)
                  for _ in range(6)]
        ml = plen + args.max_new + 8
        ed, dd2, _ = run_stream(cfg, params, stream, max_new=args.max_new,
                                max_len=ml)
        # paged pool sized to ~half the dense arena's token capacity:
        # requests overlap, the pool preempts and recomputes as needed
        ep, dp, _ = run_stream(cfg, params, stream, max_new=args.max_new,
                               max_len=ml, paged=True, page_size=8,
                               n_pages=max(3 * (plen + args.max_new) // 16, 4))
        same = ("yes" if [r.generated for r in dp]
                == [r.generated for r in dd2] else "NO")
        for label, eng, done in (("dense", ed, dd2), ("paged", ep, dp)):
            kv = eng.kv_bytes()
            print(f"{label:22s} {plen:7d} {kv['reserved']/1e6:9.2f}M "
                  f"{kv['peak_resident']/1e6:9.2f}M "
                  f"{eng.preemptions:8d}  {same if label == 'paged' else ''}")

    # shared system prompt (the interactive workload HALO targets): every
    # request opens with the same 32-token head; the radix prefix cache
    # attaches the cached pages instead of recomputing them
    print(f"\n{'prefix cache':12s} {'TTFT p50':>10s} {'hit rate':>9s} "
          f"{'prefill tok':>12s} {'cow':>5s}  outputs identical?")
    head = rng.integers(0, cfg.vocab_size, (32,), dtype=np.int32)
    stream = [np.concatenate([head, rng.integers(0, cfg.vocab_size, (8,),
                                                 dtype=np.int32)])
              for _ in range(8)]
    base = None
    for label, pc in (("off", False), ("on", True)):
        eng, done, _ = run_stream(cfg, params, stream, max_new=args.max_new,
                                  prefill_chunk=16, max_prefill_tokens=32,
                                  paged=True, page_size=8, n_pages=64,
                                  prefix_cache=pc)
        outs = [r.generated for r in done]
        same = "(reference)" if base is None else (
            "yes" if outs == base else "NO")
        if base is None:
            base = outs
        ps = eng.prefix_stats()
        print(f"{label:12s} "
              f"{np.median([r.ttft for r in done])*1e3:9.1f}ms "
              f"{ps['hit_rate']:9.2f} "
              f"{ps['prefill_tokens_executed']:12.0f} "
              f"{ps['cow_copies']:5.0f}  {same}")

    # speculative decoding: the drafter proposes k tokens per decode tick
    # (n-gram prompt-lookup, or a small draft model), one verify window of
    # the target model accepts/rejects them all at once — multi-token
    # decode with bit-identical greedy streams
    from repro.serving.speculative import SpecConfig
    print(f"\n{'speculative':14s} {'TPOT p50':>10s} {'accept':>7s} "
          f"{'tok/tick':>9s} {'ticks':>6s}  outputs identical?")
    spec_stream = [rng.integers(0, cfg.vocab_size, (24,), dtype=np.int32)
                   for _ in range(6)]
    base = None
    for label, spec in (("off", None),
                        ("ngram k=4", SpecConfig(k=4)),
                        ("model k=4", SpecConfig(
                            k=4, drafter="model", draft_arch=args.arch,
                            draft_seed=0))):
        eng, done, _ = run_stream(cfg, params, spec_stream, max_new=32,
                                  prefill_chunk=16, max_prefill_tokens=32,
                                  paged=True, page_size=8, n_pages=64,
                                  speculative=spec)
        outs = [r.generated for r in done]
        same = "(reference)" if base is None else (
            "yes" if outs == base else "NO")
        if base is None:
            base = outs
        ss = eng.spec_stats()
        print(f"{label:14s} "
              f"{np.median([r.tpot for r in done])*1e3:9.1f}ms "
              f"{ss['acceptance_rate']:7.2f} "
              f"{ss['tokens_per_tick']:9.2f} {eng.n_ticks:6d}  {same}")

    # quantized serving (HALO IV-A: int8 end to end in CiD): int8 weights
    # route decode-shaped matmuls through the fused dequantizing GEMV,
    # int8/int4 KV pages shrink the decode-phase HBM bytes that bound
    # TPOT.  Quantized streams track the f32 reference within tolerance
    # (first tokens agree; later near-ties may flip), and KV bytes drop
    # 4x/8x vs f32 pages
    from repro.models.layers import gemv_route_count, reset_gemv_route_count
    print(f"\n{'quantized':18s} {'kv page':>9s} {'gemv':>5s} "
          f"{'agree':>6s}  first tokens match?")
    q_stream = [rng.integers(0, cfg.vocab_size, (24,), dtype=np.int32)
                for _ in range(6)]
    q_base = None
    for wdt, kdt in (("f32", "f32"), ("int8", "f32"),
                     ("f32", "int8"), ("int8", "int4")):
        reset_gemv_route_count()
        eng, done, _ = run_stream(cfg, params, q_stream,
                                  max_new=args.max_new,
                                  prefill_chunk=16, max_prefill_tokens=32,
                                  paged=True, page_size=8, n_pages=64,
                                  kv_dtype=kdt, weights_dtype=wdt)
        outs = [r.generated for r in done]
        if q_base is None:
            q_base, agree, first = outs, "(ref)", "(reference)"
        else:
            hits = sum(a == b for o, p in zip(outs, q_base)
                       for a, b in zip(o, p))
            agree = f"{hits / sum(len(o) for o in q_base):.2f}"
            first = "yes" if all(o[0] == p[0]
                                 for o, p in zip(outs, q_base)) else "NO"
        cache = next(c for c in eng.pool.caches if isinstance(c, dict))
        page_bytes = sum(v.nbytes for v in cache.values()) // 64
        print(f"w={wdt:4s} kv={kdt:4s}  {page_bytes:8d}B "
              f"{gemv_route_count():5d} {agree:>6s}  {first}")

    # disaggregated serving & tiered KV (HALO's 2.5D split, serving-level):
    # the disaggregated executor pins prefill and decode programs to
    # separate device groups, so each prefill -> decode handoff moves the
    # request's fresh KV pages across the interposer-link analogue; a host
    # spill tier lets a tight pool's preemptions SWAP pages out and resume
    # with zero recomputation instead of re-prefilling.  Streams must stay
    # bit-identical across all four rows
    print(f"\n{'executor / kv tier':24s} {'migrated':>9s} {'handoffs':>9s} "
          f"{'swap-res':>9s} {'recompute':>10s}  outputs identical?")
    d_stream = [rng.integers(0, cfg.vocab_size, (40,), dtype=np.int32)
                for _ in range(8)]
    d_base = None
    for label, ex, spill, npg in (
            ("colocated", "colocated", 0, 64),
            ("disaggregated", "disaggregated", 0, 64),
            ("tight pool, recompute", "disaggregated", 0, 26),
            ("tight pool, host tier", "disaggregated", 64, 26)):
        eng, done, _ = run_stream(cfg, params, d_stream,
                                  max_new=args.max_new,
                                  prefill_chunk=16, max_prefill_tokens=32,
                                  paged=True, page_size=8, n_pages=npg,
                                  executor=ex, host_spill_pages=spill)
        outs = [r.generated for r in done]
        same = "(reference)" if d_base is None else (
            "yes" if outs == d_base else "NO")
        if d_base is None:
            d_base = outs
        c = eng.counts()
        xs = eng.executor.stats()
        print(f"{label:24s} {c['migrated_bytes']/1e6:8.2f}M "
              f"{xs['migration_batches']:9d} {c['swap_resumes']:9d} "
              f"{c['recompute_preemptions']:10d}  {same}")

    # request-centric API: per-request SamplingParams (temperature=0 is
    # greedy) run in ONE program per tick, tokens stream incrementally
    # via engine.stream(), and abort() cancels mid-flight — the greedy
    # rows of the mixed batch must match the all-greedy reference
    from repro.serving import SamplingParams
    api_prompts = [rng.integers(0, cfg.vocab_size, (16,), dtype=np.int32)
                   for _ in range(4)]
    ref_eng = ServingEngine(cfg, params, ServeConfig(
        max_batch=4, max_len=64,
        phase=PhaseAwareConfig(max_decode_batch=4, prefill_chunk=16,
                               max_prefill_tokens=64)))
    ref = [r.generated for r in ref_eng.generate(
        [p.copy() for p in api_prompts],
        SamplingParams(max_new_tokens=12))]
    eng = ServingEngine(cfg, params, ServeConfig(
        max_batch=4, max_len=64,
        phase=PhaseAwareConfig(max_decode_batch=4, prefill_chunk=16,
                               max_prefill_tokens=64)))
    sps = [SamplingParams(max_new_tokens=12) if i % 2 == 0 else
           SamplingParams(temperature=0.8, seed=100 + i, max_new_tokens=12)
           for i in range(4)]
    reqs = [eng.submit(p.copy(), sampling=sp)
            for p, sp in zip(api_prompts, sps)]
    first_seen, streamed = {}, 0
    for out in eng.stream():
        streamed += 1
        first_seen.setdefault(out.req_id, eng.n_ticks)
        if out.req_id == reqs[3].req_id and out.n_generated >= 4:
            eng.abort(reqs[3].req_id)           # cancel one mid-decode
    print(f"\n{'request':8s} {'sampling':16s} {'tokens':>7s} "
          f"{'finish':>7s}  greedy rows match reference?")
    for i, r in enumerate(reqs):
        samp = "greedy" if r.sampling.greedy else \
            f"t={r.sampling.temperature} seed={r.sampling.seed}"
        same = ("yes" if r.generated == ref[i] else "NO") \
            if r.sampling.greedy else "-"
        print(f"{r.req_id:8d} {samp:16s} {len(r.generated):7d} "
              f"{r.finish_reason:>7s}  {same}")
    print(f"streamed {streamed} incremental RequestOutputs over "
          f"{eng.n_ticks} ticks; aborted request freed its slot "
          f"mid-flight (finish reason above)")

    # observability (docs/serving.md §Observability): rerun the hardest
    # stream above — disaggregated executor, tight pool, host spill tier —
    # with the lifecycle tracer ON and per-request SLOs attached.  Tracing
    # is identity-preserving (same tokens as the untraced run), and the
    # trace must RECONCILE with the engine's own accounting: summing the
    # per-tick span args reproduces the lifetime counters exactly
    from repro.serving import SLO, Tracer
    tracer = Tracer()
    eng, done, _ = run_stream(cfg, params, d_stream, max_new=args.max_new,
                              prefill_chunk=16, max_prefill_tokens=32,
                              paged=True, page_size=8, n_pages=26,
                              executor="disaggregated", host_spill_pages=64,
                              tracer=tracer,
                              slo=SLO(ttft_ms=60_000.0, tpot_ms=60_000.0))
    assert [r.generated for r in done] == d_base, \
        "tracing changed the token streams"
    evs = tracer.events()
    ticks = [e for e in evs if e.get("cat") == "tick"]
    spans = [e for e in evs if e.get("cat") == "phase"]

    def tick_sum(key):
        return sum(e["args"][key] for e in ticks)

    recon = [
        ("tick spans", len(ticks), eng.n_ticks),
        ("prefill tokens", sum(s["args"]["take"] for s in spans
                               if s["name"] == "prefill_chunk"),
         int(eng.prefill_tokens_executed)),
        ("decode tokens", sum(s["args"].get("tokens", 0) for s in spans
                              if s["name"] == "decode")
         + sum(s["args"].get("emitted", 0) for s in spans
               if s["name"] == "verify_window"),
         int(eng.decode_tokens_emitted)),
        ("preemptions", tick_sum("preemptions"), int(eng.preemptions)),
        ("migrated bytes", tick_sum("migrated_bytes"),
         int(eng.executor.migrated_bytes)),
        ("swap-out bytes", tick_sum("swap_out_bytes"),
         int(eng.counts()["swap_out_bytes"])),
        ("request envelopes",
         sum(1 for e in evs if e.get("ph") == "b"), len(d_stream)),
    ]
    print(f"\n{'trace <-> engine':18s} {'trace':>9s} {'engine':>9s}  "
          f"reconciles?")
    for name, got, want in recon:
        assert got == want, f"trace/{name}: {got} != engine {want}"
        print(f"{name:18s} {got:9d} {want:9d}  yes")
    g = eng.goodput()
    print(f"slo attained={g['slo_attained']:.0f}/{g['slo_total']:.0f} "
          f"goodput={g['goodput']:.2f} "
          f"(ttft-viol={g['ttft_violations']:.0f} "
          f"tpot-viol={g['tpot_violations']:.0f})  "
          f"events={len(evs)}")
    if args.trace_out:
        tracer.write(args.trace_out)
        print(f"trace written -> {args.trace_out}")

    # async front-end + traffic harness (docs/serving.md §Async front-end):
    # a seeded Poisson trace replayed through AsyncEngine as concurrent
    # client tasks must reproduce the sync engine's greedy streams bit for
    # bit — submissions reach the engine in trace order through the
    # mailbox and batch composition is scheduler-owned, so the event
    # loop's interleaving cannot perturb the tokens
    import asyncio

    from repro.serving import AsyncEngine
    from repro.serving.traffic import (TenantSpec, TrafficConfig, replay,
                                       synthesize)
    trace = synthesize(TrafficConfig(
        tenants=(TenantSpec(name="chat", rate_rps=8.0, prompt_len=(12, 24),
                            output_len=(4, 8), shared_prefix_len=8,
                            n_prefixes=2),),
        duration_s=1.0, seed=13, vocab_size=cfg.vocab_size))

    def fresh():
        return ServingEngine(cfg, params, ServeConfig(
            max_batch=4, max_len=96,
            phase=PhaseAwareConfig(max_decode_batch=4, prefill_chunk=16,
                                   max_prefill_tokens=32),
            paged=True, page_size=8, n_pages=64))

    sync_eng = fresh()
    for ev in trace:
        sync_eng.submit(ev.prompt.copy(), max_new_tokens=ev.max_new_tokens)
    sync_ref = [list(r.generated) for r in
                sorted(sync_eng.run_until_drained(), key=lambda r: r.req_id)]
    async_eng = fresh()

    async def _go():
        async with AsyncEngine(async_eng) as fe:
            return await replay(fe, trace, time_scale=0)

    rep = asyncio.run(_go())
    async_out = [list(r.generated) for r in
                 sorted(async_eng.done, key=lambda r: r.req_id)]
    assert async_out == sync_ref, "async replay diverged from sync engine"
    print(f"\nasync traffic replay: {rep.n_requests} arrivals over a "
          f"{trace[-1].t:.2f}s trace, streams identical to the sync "
          f"engine? yes")
    print(rep.render())

    print("\nNote: strategies schedule the same math onto different worker "
          "groups (separate compiled programs); outputs must match exactly. "
          "On TPU the groups run compute- vs bandwidth-sharded programs — "
          "see docs/serving.md §Strategy groups.  The paged arena "
          "(docs/serving.md §Paged) bounds capacity by POOL size, not "
          "max_len: same tokens, a fraction of the resident KV bytes.")


if __name__ == "__main__":
    main()
