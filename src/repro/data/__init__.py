from repro.data.pipeline import (
    DataConfig,
    SyntheticLM,
    PackedDocuments,
    ShardedLoader,
    make_loader,
)

__all__ = ["DataConfig", "SyntheticLM", "PackedDocuments", "ShardedLoader",
           "make_loader"]
