"""Deterministic, shardable, resumable data pipeline.

Design requirements at 1000-node scale:
  * every data-parallel worker must draw a DISJOINT slice of the global
    batch without coordination -> index-based addressing: batch ``i`` of
    worker ``w`` is a pure function of (seed, step, w);
  * restart from a checkpoint must replay EXACTLY the same stream ->
    the loader state is just the step counter (saved with the train state);
  * elastic rescale (N workers -> M workers) must not reshuffle history ->
    addressing is over the GLOBAL batch index space; workers map to slices
    of it, so changing the worker count only changes the slicing.

Two sources:
  * SyntheticLM — counting/ngram synthetic tokens (self-contained; used by
    examples and tests; learnable so training loss demonstrably falls);
  * PackedDocuments — document stream packed into fixed-length rows with
    EOS separators, from a token file (memory-mapped) or a generator.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    kind: str = "synthetic"            # "synthetic" | "packed"
    n_codebooks: int = 0               # musicgen-style parallel streams
    eos_id: int = 1


# ---------------------------------------------------------------------------
# synthetic LM task (learnable: affine-prev-token with position mixing)
# ---------------------------------------------------------------------------


class SyntheticLM:
    """tokens[t+1] = (a * tokens[t] + b + (t % m)) % V.

    (a, b, m) are GLOBAL (derived from the seed only); each sequence differs
    only in its start token.  The transition function is therefore a fixed
    table the model can memorize — cross-entropy demonstrably falls within
    tens of steps on the reduced configs, giving convergence tests a signal.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = self._rng_for("params")
        self.a = int(rng.integers(1, 8))
        self.b = int(rng.integers(0, cfg.vocab_size))
        self.m = int(rng.integers(2, 17))

    def _rng_for(self, *parts) -> np.random.Generator:
        h = hashlib.blake2b(
            ":".join([str(self.cfg.seed)] + [str(p) for p in parts]).encode(),
            digest_size=8)
        return np.random.default_rng(int.from_bytes(h.digest(), "little"))

    def sequence(self, global_idx: int, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = self._rng_for(step, global_idx)
        V = cfg.vocab_size
        t0 = int(rng.integers(0, V))
        T = cfg.seq_len
        toks = np.empty((T,), np.int32)
        toks[0] = t0
        a, b, m = self.a, self.b, self.m
        for t in range(T - 1):
            toks[t + 1] = (a * int(toks[t]) + b + (t % m)) % V
        if cfg.n_codebooks > 1:
            out = np.stack([(toks + k) % V for k in range(cfg.n_codebooks)])
            return out.astype(np.int32)
        return toks


# ---------------------------------------------------------------------------
# packed documents
# ---------------------------------------------------------------------------


class PackedDocuments:
    """Pack a flat token stream into [seq_len] rows.

    ``tokens`` is any 1D int array (np.memmap for file-backed corpora).
    Row ``i`` = tokens[i*L : (i+1)*L] with wraparound — stateless addressing.
    """

    def __init__(self, cfg: DataConfig, tokens: np.ndarray):
        assert tokens.ndim == 1 and tokens.size >= cfg.seq_len
        self.cfg = cfg
        self.tokens = tokens

    def sequence(self, global_idx: int, step: int) -> np.ndarray:
        L = self.cfg.seq_len
        n = self.tokens.size
        start = ((step * self.cfg.global_batch + global_idx) * L) % (n - L + 1)
        return np.asarray(self.tokens[start:start + L], np.int32)


# ---------------------------------------------------------------------------
# sharded loader
# ---------------------------------------------------------------------------


class ShardedLoader:
    """Per-worker view of the global batch stream.

    state = {"step": int}; save/restore it with the checkpoint.  The worker
    draws global indices [w*per, (w+1)*per) of each step's batch.
    """

    def __init__(self, source, cfg: DataConfig, worker: int = 0,
                 n_workers: int = 1):
        assert cfg.global_batch % n_workers == 0, (cfg.global_batch, n_workers)
        self.source = source
        self.cfg = cfg
        self.worker = worker
        self.n_workers = n_workers
        self.per_worker = cfg.global_batch // n_workers
        self.step = 0

    # -- persistence --------------------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    def load_state_dict(self, st: Dict[str, int]) -> None:
        self.step = int(st["step"])

    def with_workers(self, worker: int, n_workers: int) -> "ShardedLoader":
        """Elastic rescale: same stream, new slicing; keeps the step."""
        nl = ShardedLoader(self.source, self.cfg, worker, n_workers)
        nl.step = self.step
        return nl

    # -- iteration ----------------------------------------------------------
    def next_batch(self) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        lo = self.worker * self.per_worker
        seqs = [self.source.sequence(lo + i, self.step)
                for i in range(self.per_worker)]
        tokens = np.stack(seqs)            # [B_local, T] or [B_local, K, T]
        self.step += 1
        return {"tokens": tokens}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


def make_loader(cfg: DataConfig, worker: int = 0, n_workers: int = 1,
                tokens: Optional[np.ndarray] = None) -> ShardedLoader:
    if cfg.kind == "packed":
        assert tokens is not None, "packed loader needs a token array"
        src = PackedDocuments(cfg, tokens)
    else:
        src = SyntheticLM(cfg)
    return ShardedLoader(src, cfg, worker, n_workers)
