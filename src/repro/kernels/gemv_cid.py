"""Bandwidth-optimal fused GEMV kernel — the TPU adaptation of HALO's CiD
decode path.

HALO executes decode GEMVs inside the DRAM banks so every weight byte moves
at most once over the shortest possible path.  On TPU the equivalent
discipline is: (1) stream each weight tile HBM->VMEM exactly once (grid walks
the weight matrix, the small activation vector stays resident), and
(2) *shrink the bytes*: weights may be stored int8 with a per-output-channel
f32 scale (HALO computes int8 end-to-end); dequantization is fused into the
accumulation so the HBM traffic is halved vs bf16.

The roofline term this kernel attacks is the decode memory term
W_bytes / HBM_bw — exactly the quantity HALO's CiD reduces with in-bank
execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _masked_tile(x_ref, w_ref, *, bk: int, K: int):
    """Read the (x, w) tile pair at grid step k, zeroing the K-tail of a
    ragged final tile.  Pallas pads out-of-bounds block reads with
    unspecified values; 0 * non-finite would poison the accumulator, so
    both sides of the contraction are masked (same treatment as the dense
    decode-attention kernel's ragged final tile)."""
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    if K % bk:
        k0 = pl.program_id(1) * bk
        col = k0 + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where(col < K, x, 0.0)
        row = k0 + jax.lax.broadcasted_iota(jnp.int32, w.shape, 0)
        w = jnp.where(row < K, w, 0.0)
    return x, w


def _gemv_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int, bk: int, K: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x, w = _masked_tile(x_ref, w_ref, bk=bk, K=K)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _gemv_q_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, nk: int, bk: int,
                   K: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x, w = _masked_tile(x_ref, w_ref, bk=bk, K=K)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == nk - 1)
    def _done():
        # fused per-channel dequant on the f32 accumulator
        o_ref[...] = (acc_ref[...] * s_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "interpret"))
def gemv(x, w, scale=None, *, bn: int = 512, bk: int = 1024,
         interpret: bool = False):
    """x: [B, K] @ w: [K, N] (+ optional int8 w with per-col f32 ``scale``).

    B is the (small) decode batch; the grid is (ceil(N/bn), ceil(K/bk)) so
    each weight tile is read exactly once.  N and K need not divide the
    tile sizes: ragged final tiles are masked in-kernel (K tail) or
    dropped on the write (N tail).
    """
    B, K = x.shape
    K2, N = w.shape
    assert K == K2
    bn, bk = min(bn, N), min(bk, K)
    nk = pl.cdiv(K, bk)
    grid = (pl.cdiv(N, bn), nk)
    out_shape = jax.ShapeDtypeStruct((B, N), x.dtype)
    if scale is None:
        return pl.pallas_call(
            functools.partial(_gemv_kernel, nk=nk, bk=bk, K=K),
            grid=grid,
            in_specs=[
                pl.BlockSpec((B, bk), lambda j, k: (0, k)),
                pl.BlockSpec((bk, bn), lambda j, k: (k, j)),
            ],
            out_specs=pl.BlockSpec((B, bn), lambda j, k: (0, j)),
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((B, bn), jnp.float32)],
            interpret=interpret,
        )(x, w)
    assert scale.shape == (N,)
    return pl.pallas_call(
        functools.partial(_gemv_q_kernel, nk=nk, bk=bk, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bk), lambda j, k: (0, k)),
            pl.BlockSpec((bk, bn), lambda j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((B, bn), lambda j, k: (0, j)),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((B, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, scale[None, :])


def quantize_int8(w):
    """Per-output-channel symmetric int8 quantization: w [K,N] -> (q, scale)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[None, :]),
                 -127, 127).astype(jnp.int8)
    return q, scale
