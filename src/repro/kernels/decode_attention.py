"""Flash-decode Pallas kernels: one query token vs a long KV cache.

The decode GEMV sweep HALO maps to CiD.  Two layouts:

* ``decode_attention`` — dense per-slot cache [B, S, Hkv, D].  Grid:
  (B, ceil(S/bs)) — the cache is tiled along the sequence axis and each
  tile is read from HBM exactly once; the per-(head) online-softmax state
  rides in VMEM scratch across tiles.  Entries beyond ``length``
  (unwritten slots / padding) are masked out, so the kernel works with
  ring buffers and right-padded serving batches alike.  ``S`` need not be
  a multiple of ``bs``: the final tile is ragged (Pallas pads the block;
  the length mask already discards the tail).

* ``paged_decode_attention`` — block-pool cache [n_pages, P, Hkv, D]
  shared by every sequence, addressed through per-sequence block tables
  [B, W].  Grid: (B, W) — one step per logical page; the block table and
  lengths ride in SMEM via scalar prefetch, so each step's BlockSpec
  index_map GATHERS the physical page the table names (HALO reading: the
  block table is the CiD bank/row decoder — a page is a contiguous row
  burst, and the indirection happens in the address path, not the data
  path).  Same online-softmax scratch as the dense kernel; pages past
  ``length`` or mapped to the unallocated sentinel are skipped whole.

* ``paged_decode_attention_q4`` — same grid/indirection over PACKED INT4
  pages (uint8 nibble pairs [n_pages, P, Hkv, D//2] + per-token f32 scale
  pages): nibbles are sign-extended and dequantized in-register, so the
  HBM bytes per decode step are ~4x below f32 — the HALO low-precision
  CiD argument applied to the KV side.

Per-tile working set (bs=1024, Hkv=8, D=128, bf16): k/v 2x1024x8x128x2 = 4 MB.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, ns: int, bs: int, scale: float, Hkv: int, G: int, D: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    s_start = si * bs

    @pl.when(s_start < length)
    def _compute():
        q = q_ref[0].reshape(Hkv, G, D)                      # [Hkv,G,D]
        k = k_ref[0]                                         # [bs,Hkv,D]
        v = v_ref[0]
        # zero masked rows of v: a ragged final tile is padded with
        # unspecified values, and 0 * non-finite would poison p @ v
        row = s_start + jax.lax.broadcasted_iota(jnp.int32, (bs, 1, 1), 0)
        v = jnp.where(row < length, v, 0.0).astype(v.dtype)
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)              # [Hkv,G,bs]
        s = s * scale
        idx = s_start + jax.lax.broadcasted_iota(jnp.int32, (Hkv, G, bs), 2)
        s = jnp.where(idx < length, s, NEG_INF)

        m_prev = m_ref[...].reshape(Hkv, G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        p = jnp.exp(s - m_new)                               # [Hkv,G,bs]
        corr = jnp.exp(m_prev - m_new)                       # [Hkv,G,1]
        l_new = l_ref[...].reshape(Hkv, G, 1) * corr + jnp.sum(
            p, axis=2, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)              # [Hkv,G,D]
        acc = acc_ref[...].reshape(Hkv, G, D) * corr + pv
        acc_ref[...] = acc.reshape(Hkv * G, D)
        m_ref[...] = m_new.reshape(Hkv * G, 1)
        l_ref[...] = l_new.reshape(Hkv * G, 1)

    @pl.when(si == ns - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)                   # [Hkv*G,1]
        o_ref[0] = (acc_ref[...].reshape(Hkv * G, D) / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, bs: int = 1024,
                     interpret: bool = False):
    """q: [B,H,D]; caches: [B,S,Hkv,D]; lengths: [B].  Returns [B,H,D]."""
    B, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    bs = min(bs, S)
    ns = pl.cdiv(S, bs)          # final tile may be ragged (masked below)
    scale = 1.0 / math.sqrt(D)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, ns=ns, bs=bs, scale=scale,
                          Hkv=Hkv, G=G, D=D),
        grid=(B, ns),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hkv * G, 1), jnp.float32),
            pltpu.VMEM((Hkv * G, 1), jnp.float32),
            pltpu.VMEM((Hkv * G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, lengths.astype(jnp.int32))
    return out


# ---------------------------------------------------------------------------
# paged variant (block-pool cache)
# ---------------------------------------------------------------------------


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref,
                         *, nw: int, ps: int, n_pages: int, scale: float,
                         Hkv: int, G: int, D: int):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    s_start = i * ps
    # logical page i of sequence b lives in physical page bt[b, i]; entries
    # >= n_pages are the "never allocated" sentinel — skip the page whole
    allocated = bt_ref[b, i] < n_pages

    @pl.when((s_start < length) & allocated)
    def _compute():
        q = q_ref[0].reshape(Hkv, G, D)                      # [Hkv,G,D]
        k = k_ref[0]                                         # [ps,Hkv,D]
        v = v_ref[0]
        row = s_start + jax.lax.broadcasted_iota(jnp.int32, (ps, 1, 1), 0)
        v = jnp.where(row < length, v, 0.0).astype(v.dtype)
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)              # [Hkv,G,ps]
        s = s * scale
        idx = s_start + jax.lax.broadcasted_iota(jnp.int32, (Hkv, G, ps), 2)
        s = jnp.where(idx < length, s, NEG_INF)

        m_prev = m_ref[...].reshape(Hkv, G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        p = jnp.exp(s - m_new)                               # [Hkv,G,ps]
        corr = jnp.exp(m_prev - m_new)                       # [Hkv,G,1]
        l_new = l_ref[...].reshape(Hkv, G, 1) * corr + jnp.sum(
            p, axis=2, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)              # [Hkv,G,D]
        acc = acc_ref[...].reshape(Hkv, G, D) * corr + pv
        acc_ref[...] = acc.reshape(Hkv * G, D)
        m_ref[...] = m_new.reshape(Hkv * G, 1)
        l_ref[...] = l_new.reshape(Hkv * G, 1)

    @pl.when(i == nw - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)                   # [Hkv*G,1]
        o_ref[0] = (acc_ref[...].reshape(Hkv * G, D) / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           interpret: bool = False):
    """Flash-decode over a paged KV pool.

    q: [B, H, D]; k_pages/v_pages: [n_pages, ps, Hkv, D] — the pool shared
    by every sequence; block_tables: [B, W] int32 mapping logical page i of
    sequence b to a physical page (entries >= n_pages mean "unallocated");
    lengths: [B] valid logical entries per sequence.  Returns [B, H, D].

    The grid walks (B, W): one step per logical page.  The block table and
    lengths are scalar-prefetched into SMEM so the K/V BlockSpec index_maps
    can gather the physical page before the step's compute runs.
    """
    B, H, D = q.shape
    n_pages, ps, Hkv = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    W = block_tables.shape[1]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    bt = block_tables.astype(jnp.int32)

    def page_map(b, i, bt_ref, len_ref):
        # clamp the sentinel: the fetched page is ignored (pl.when masks
        # the whole step) but the DMA address must stay in bounds
        return (jnp.minimum(bt_ref[b, i], n_pages - 1), 0, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, W),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, i, bt_ref, len_ref: (b, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, D), page_map),
            pl.BlockSpec((1, ps, Hkv, D), page_map),
        ],
        out_specs=pl.BlockSpec((1, H, D),
                               lambda b, i, bt_ref, len_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv * G, 1), jnp.float32),
            pltpu.VMEM((Hkv * G, 1), jnp.float32),
            pltpu.VMEM((Hkv * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, nw=W, ps=ps, n_pages=n_pages,
                          scale=scale, Hkv=Hkv, G=G, D=D),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(bt, lengths.astype(jnp.int32), q, k_pages, v_pages)
    return out


# ---------------------------------------------------------------------------
# packed-int4 paged variant (two nibbles per byte, per-token scales)
# ---------------------------------------------------------------------------


def _unpack_q4(b, scale_tok):
    """In-register nibble unpack + dequant: b uint8 [ps, Hkv, D//2] with
    per-(token, kv-head) f32 scales [ps, Hkv] -> f32 [ps, Hkv, D].  Element
    2i rides the low nibble, 2i+1 the high nibble (quantized_cache.pack_int4);
    nibbles >= 8 are negative (explicit sign extension — uint8->int8 casts
    of high values are not portable across backends)."""
    lo = (b & 0xF).astype(jnp.int32)
    hi = (b >> 4).astype(jnp.int32)
    lo = jnp.where(lo >= 8, lo - 16, lo).astype(jnp.float32)
    hi = jnp.where(hi >= 8, hi - 16, hi).astype(jnp.float32)
    x = jnp.stack([lo, hi], axis=-1).reshape(b.shape[:-1] + (2 * b.shape[-1],))
    return x * scale_tok[..., None].astype(jnp.float32)


def _paged_decode_q4_kernel(bt_ref, len_ref, q_ref, k_ref, ks_ref, v_ref,
                            vs_ref, o_ref, m_ref, l_ref, acc_ref,
                            *, nw: int, ps: int, n_pages: int, scale: float,
                            Hkv: int, G: int, D: int):
    """``_paged_decode_kernel`` for packed-int4 pages: K/V arrive as uint8
    nibble pairs at HALF the head width plus per-token scale pages riding
    the same block table — the HBM bytes per step are ~quarter of f32 —
    and are unpacked + dequantized in-register before the identical
    online-softmax sweep."""
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    s_start = i * ps
    allocated = bt_ref[b, i] < n_pages

    @pl.when((s_start < length) & allocated)
    def _compute():
        q = q_ref[0].reshape(Hkv, G, D)                      # [Hkv,G,D]
        k = _unpack_q4(k_ref[0], ks_ref[0])                  # [ps,Hkv,D] f32
        v = _unpack_q4(v_ref[0], vs_ref[0])
        row = s_start + jax.lax.broadcasted_iota(jnp.int32, (ps, 1, 1), 0)
        v = jnp.where(row < length, v, 0.0)
        s = jax.lax.dot_general(
            q.astype(jnp.float32), k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)              # [Hkv,G,ps]
        s = s * scale
        idx = s_start + jax.lax.broadcasted_iota(jnp.int32, (Hkv, G, ps), 2)
        s = jnp.where(idx < length, s, NEG_INF)

        m_prev = m_ref[...].reshape(Hkv, G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        p = jnp.exp(s - m_new)                               # [Hkv,G,ps]
        corr = jnp.exp(m_prev - m_new)                       # [Hkv,G,1]
        l_new = l_ref[...].reshape(Hkv, G, 1) * corr + jnp.sum(
            p, axis=2, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)              # [Hkv,G,D]
        acc = acc_ref[...].reshape(Hkv, G, D) * corr + pv
        acc_ref[...] = acc.reshape(Hkv * G, D)
        m_ref[...] = m_new.reshape(Hkv * G, 1)
        l_ref[...] = l_new.reshape(Hkv * G, 1)

    @pl.when(i == nw - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)                   # [Hkv*G,1]
        o_ref[0] = (acc_ref[...].reshape(Hkv * G, D) / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_q4(q, k_pages, k_scales, v_pages, v_scales,
                              block_tables, lengths, *,
                              interpret: bool = False):
    """Flash-decode over a packed-int4 paged KV pool.

    q: [B, H, D]; k_pages/v_pages: uint8 [n_pages, ps, Hkv, D//2] (nibble
    pairs, see quantized_cache.pack_int4); k_scales/v_scales: f32
    [n_pages, ps, Hkv] per-token scales riding the SAME block table;
    block_tables: [B, W] int32; lengths: [B].  Returns [B, H, D].
    """
    B, H, D = q.shape
    n_pages, ps, Hkv = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    assert k_pages.shape[3] * 2 == D, \
        f"packed page width {k_pages.shape[3]} != D/2 = {D // 2}"
    W = block_tables.shape[1]
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    bt = block_tables.astype(jnp.int32)
    D2 = D // 2

    def page_map(b, i, bt_ref, len_ref):
        return (jnp.minimum(bt_ref[b, i], n_pages - 1), 0, 0, 0)

    def scale_map(b, i, bt_ref, len_ref):
        return (jnp.minimum(bt_ref[b, i], n_pages - 1), 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, W),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, i, bt_ref, len_ref: (b, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, D2), page_map),
            pl.BlockSpec((1, ps, Hkv), scale_map),
            pl.BlockSpec((1, ps, Hkv, D2), page_map),
            pl.BlockSpec((1, ps, Hkv), scale_map),
        ],
        out_specs=pl.BlockSpec((1, H, D),
                               lambda b, i, bt_ref, len_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv * G, 1), jnp.float32),
            pltpu.VMEM((Hkv * G, 1), jnp.float32),
            pltpu.VMEM((Hkv * G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_q4_kernel, nw=W, ps=ps,
                          n_pages=n_pages, scale=scale, Hkv=Hkv, G=G, D=D),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(bt, lengths.astype(jnp.int32), q, k_pages, k_scales, v_pages, v_scales)
    return out
