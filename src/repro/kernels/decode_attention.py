"""Flash-decode Pallas kernel: one query token vs a long KV cache.

The decode GEMV sweep HALO maps to CiD.  Grid: (B, S/bs) — the cache is
tiled along the sequence axis and each tile is read from HBM exactly once;
the per-(head) online-softmax state rides in VMEM scratch across tiles.
Entries beyond ``length`` (unwritten slots / padding) are masked out, so the
kernel works with ring buffers and right-padded serving batches alike.

Per-tile working set (bs=1024, Hkv=8, D=128, bf16): k/v 2x1024x8x128x2 = 4 MB.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, ns: int, bs: int, scale: float, Hkv: int, G: int, D: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    s_start = si * bs

    @pl.when(s_start < length)
    def _compute():
        q = q_ref[0].reshape(Hkv, G, D)                      # [Hkv,G,D]
        k = k_ref[0]                                         # [bs,Hkv,D]
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)              # [Hkv,G,bs]
        s = s * scale
        idx = s_start + jax.lax.broadcasted_iota(jnp.int32, (Hkv, G, bs), 2)
        s = jnp.where(idx < length, s, NEG_INF)

        m_prev = m_ref[...].reshape(Hkv, G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2, keepdims=True))
        p = jnp.exp(s - m_new)                               # [Hkv,G,bs]
        corr = jnp.exp(m_prev - m_new)                       # [Hkv,G,1]
        l_new = l_ref[...].reshape(Hkv, G, 1) * corr + jnp.sum(
            p, axis=2, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)              # [Hkv,G,D]
        acc = acc_ref[...].reshape(Hkv, G, D) * corr + pv
        acc_ref[...] = acc.reshape(Hkv * G, D)
        m_ref[...] = m_new.reshape(Hkv * G, 1)
        l_ref[...] = l_new.reshape(Hkv * G, 1)

    @pl.when(si == ns - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)                   # [Hkv*G,1]
        o_ref[0] = (acc_ref[...].reshape(Hkv * G, D) / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *, bs: int = 1024,
                     interpret: bool = False):
    """q: [B,H,D]; caches: [B,S,Hkv,D]; lengths: [B].  Returns [B,H,D]."""
    B, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    bs = min(bs, S)
    assert S % bs == 0
    ns = S // bs
    scale = 1.0 / math.sqrt(D)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, ns=ns, bs=bs, scale=scale,
                          Hkv=Hkv, G=G, D=D),
        grid=(B, ns),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hkv * G, 1), jnp.float32),
            pltpu.VMEM((Hkv * G, 1), jnp.float32),
            pltpu.VMEM((Hkv * G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, lengths.astype(jnp.int32))
    return out
