"""Mamba-2 SSD intra-chunk Pallas kernel.

Computes, for one (sequence-chunk, head-block), the chunk-local SSD output
and the end-of-chunk state contribution:

    L[i,j]  = exp(cumsum(dA)[i] - cumsum(dA)[j]),  j <= i
    y_diag  = ((C B^T) * L) @ (dt*x)
    state   = B^T @ (decay_to_end * dt*x)

The inter-chunk recurrence (combining the per-chunk states) is a tiny
O(T/Q) ``lax.scan`` outside the kernel.  Grid: (BH/bh, nc).  The chunk never
leaves VMEM between the three matmuls — this is the fusion the pure-jnp SSD
cannot get (XLA materializes L and CB in HBM at [B,nc,H,Q,Q]).

Working set (Q=256, bh=4, P=64, N=128, f32):
x 4x256x64 + B/C 256x128x2 + L 4x256x256 + y 4x256x64 ~ 2.3 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref,
                *, Q: int, bh: int, P: int, N: int):
    # refs (leading singleton = grid block):
    # x [1,bh,Q,P]  dt [1,bh,Q]  a [bh]  b/c [1,Q,N]
    dt = dt_ref[0].astype(jnp.float32)                        # [bh,Q]
    A = a_ref[...].astype(jnp.float32)                        # [bh]
    dA = dt * A[:, None]                                      # [bh,Q]
    cs = jnp.cumsum(dA, axis=1)                               # [bh,Q]
    seg = cs[:, :, None] - cs[:, None, :]                     # [bh,Q,Q]
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(tri[None], jnp.exp(seg), 0.0)               # [bh,Q,Q]

    xb = x_ref[0].astype(jnp.float32) * dt[:, :, None]        # [bh,Q,P]
    Bm = b_ref[0].astype(jnp.float32)                         # [Q,N]
    Cm = c_ref[0].astype(jnp.float32)                         # [Q,N]
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    G = CB[None] * L                                          # [bh,Q,Q]
    y = jax.lax.dot_general(G, xb, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)   # [bh,Q,P]
    y_ref[0] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(cs[:, -1:] - cs)                      # [bh,Q]
    xw = xb * decay_end[:, :, None]                           # [bh,Q,P]
    st = jax.lax.dot_general(
        jnp.broadcast_to(Bm[None], (bh, Q, N)), xw,
        (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                   # [bh,N,P]
    st_ref[0] = st.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bh", "interpret"))
def ssd_chunk(x, dt, A, Bm, Cm, *, bh: int = 4, interpret: bool = False):
    """Intra-chunk SSD over stacked chunks.

    x: [nc, H, Q, P]; dt: [nc, H, Q]; A: [H]; Bm/Cm: [nc, Q, N] (1 group).
    Returns (y [nc,H,Q,P] f32, states [nc,H,N,P] f32) — per-chunk local
    output and end-state, before the inter-chunk recurrence.
    """
    nc, H, Q, P = x.shape
    N = Bm.shape[2]
    bh = min(bh, H)
    assert H % bh == 0
    y, st = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q, bh=bh, P=P, N=N),
        grid=(nc, H // bh),
        in_specs=[
            pl.BlockSpec((1, bh, Q, P), lambda c, h: (c, h, 0, 0)),
            pl.BlockSpec((1, bh, Q), lambda c, h: (c, h, 0)),
            pl.BlockSpec((bh,), lambda c, h: (h,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, Q, N), lambda c, h: (c, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda c, h: (c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bh, Q, P), lambda c, h: (c, h, 0, 0)),
            pl.BlockSpec((1, bh, N, P), lambda c, h: (c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nc, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((nc, H, N, P), jnp.float32),
        ],
        scratch_shapes=[],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, st
