"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) every kernel executes in interpret mode, which runs
the kernel body as JAX ops — bit-exact algorithm, no Mosaic.  On TPU the
same call sites compile to Mosaic with the documented VMEM tilings.
"""

from __future__ import annotations

import jax

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import gemm_cim as _gemm
from repro.kernels import gemv_cid as _gemv
from repro.kernels import ssd_scan as _ssd
from repro.kernels.gemv_cid import quantize_int8  # noqa: F401  (re-export)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def matmul(x, w, **kw):
    """Prefill GEMM (CiM path): [M,K] @ [K,N]."""
    kw.setdefault("interpret", _interpret())
    return _gemm.matmul(x, w, **kw)


def gemv(x, w, scale=None, **kw):
    """Decode GEMV (CiD path): [B,K] @ [K,N], optional fused int8 dequant."""
    kw.setdefault("interpret", _interpret())
    return _gemv.gemv(x, w, scale, **kw)


def flash_attention(q, k, v, **kw):
    """Prefill attention: q [B,H,T,D], kv [B,Hkv,T,D]."""
    kw.setdefault("interpret", _interpret())
    return _fa.flash_attention(q, k, v, **kw)


def packed_prefill_attention(q, k_new, v_new, k_pages, v_pages, block_tables,
                             seg_starts, seg_offsets, seg_lengths, **kw):
    """Packed multi-request prefill attention: flat stream q/k_new/v_new
    [T,H|Hkv,D] of bq-aligned segments, each attending over its own arena
    history (pool [n_pages,P,Hkv,D] via per-segment block_tables [N,W])."""
    kw.setdefault("interpret", _interpret())
    return _fa.packed_prefill_attention(q, k_new, v_new, k_pages, v_pages,
                                        block_tables, seg_starts,
                                        seg_offsets, seg_lengths, **kw)


def decode_attention(q, k_cache, v_cache, lengths, **kw):
    """Decode attention: q [B,H,D] vs cache [B,S,Hkv,D]."""
    kw.setdefault("interpret", _interpret())
    return _da.decode_attention(q, k_cache, v_cache, lengths, **kw)


def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, **kw):
    """Paged decode attention: q [B,H,D] vs pool [n_pages,ps,Hkv,D] gathered
    through block_tables [B,W] (entries >= n_pages: unallocated)."""
    kw.setdefault("interpret", _interpret())
    return _da.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                      lengths, **kw)


def paged_decode_attention_q4(q, k_pages, k_scales, v_pages, v_scales,
                              block_tables, lengths, **kw):
    """Paged decode attention over packed-int4 pages: uint8 nibble pairs
    [n_pages,ps,Hkv,D//2] + per-token f32 scales [n_pages,ps,Hkv], unpacked
    and dequantized in-register (see kernels/decode_attention.py)."""
    kw.setdefault("interpret", _interpret())
    return _da.paged_decode_attention_q4(q, k_pages, k_scales, v_pages,
                                         v_scales, block_tables, lengths,
                                         **kw)


def ssd_chunk(x, dt, A, Bm, Cm, **kw):
    """Mamba-2 intra-chunk SSD: see kernels/ssd_scan.py."""
    kw.setdefault("interpret", _interpret())
    return _ssd.ssd_chunk(x, dt, A, Bm, Cm, **kw)
