"""MXU-tiled matmul kernel — the TPU adaptation of HALO's CiM prefill path.

HALO maps prefill GEMMs onto weight-stationary analog crossbars; the TPU
analogue is a weight-stationary MXU schedule: the kernel walks the K
dimension in the innermost grid axis so each (bm, bn) output tile keeps its
f32 accumulator resident in VMEM scratch while weight tiles stream HBM->VMEM
exactly once per (m, n) tile — the same "load weights once, stream many
activations through them" dataflow the crossbar provides.

Block shapes default to 256x256x512 (bf16): working set
256*512 + 512*256 + 256*256*4 bytes = 0.75 MB << VMEM, and every matmul dim
is a multiple of the 128x128 MXU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x, w, *, bm: int = 256, bn: int = 256, bk: int = 512,
           interpret: bool = False):
    """x: [M, K] @ w: [K, N] -> [M, N] (dtype of x, f32 accumulation)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
