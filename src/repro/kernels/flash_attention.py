"""Flash attention (prefill) Pallas kernel with causal + sliding-window masks.

Grid: (B*Hkv*G, Tq/bq, Tk/bk) — the KV axis is innermost so the online-
softmax state (m, l, acc) for one query tile lives in VMEM scratch across KV
steps.  Causal upper-triangle KV tiles are skipped with pl.when (zero MXU
work), which is the triangular schedule the pure-JAX blockwise version can't
express (EXPERIMENTS.md §Perf).

VMEM working set per step (bq=bk=512, D=128, bf16):
q 512x128x2 + k/v 2x512x128x2 + acc 512x128x4 + p 512x512x4 ~ 1.7 MB.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, nk: int, bq: int, bk: int, scale: float,
                  causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # does this KV tile intersect the (causal, windowed) mask at all?
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window > 0:
        run = jnp.logical_and(run, (q_start - (k_start + bk - 1)) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                                         # [bq, D]
        k = k_ref[0]                                         # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [bq, bk]
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot_general(
                            p.astype(v_ref.dtype), v_ref[0],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512, interpret: bool = False):
    """q: [B,H,T,D]; k,v: [B,Hkv,T,D] (GQA).  Returns [B,H,T,D]."""
    B, H, T, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    bq, bk = min(bq, T), min(bk, T)
    assert T % bq == 0 and T % bk == 0
    nq, nk = T // bq, T // bk
    scale = 1.0 / math.sqrt(D)

    # flatten (B, Hkv, G) into one grid axis; the kv index map drops G
    qf = q.reshape(B * Hkv * G, T, D)
    kf = k.reshape(B * Hkv, T, D)
    vf = v.reshape(B * Hkv, T, D)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, nk=nk, bq=bq, bk=bk, scale=scale,
                          causal=causal, window=window),
        grid=(B * Hkv * G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, G=G: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv * G, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, T, D)
