"""Flash attention (prefill) Pallas kernel with causal + sliding-window masks.

Grid: (B*Hkv*G, Tq/bq, Tk/bk) — the KV axis is innermost so the online-
softmax state (m, l, acc) for one query tile lives in VMEM scratch across KV
steps.  Causal upper-triangle KV tiles are skipped with pl.when (zero MXU
work), which is the triangular schedule the pure-JAX blockwise version can't
express (EXPERIMENTS.md §Perf).

VMEM working set per step (bq=bk=512, D=128, bf16):
q 512x128x2 + k/v 2x512x128x2 + acc 512x128x4 + p 512x512x4 ~ 1.7 MB.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, nk: int, bq: int, bk: int, scale: float,
                  causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk
    # does this KV tile intersect the (causal, windowed) mask at all?
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window > 0:
        run = jnp.logical_and(run, (q_start - (k_start + bk - 1)) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0]                                         # [bq, D]
        k = k_ref[0]                                         # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [bq, bk]
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot_general(
                            p.astype(v_ref.dtype), v_ref[0],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512, interpret: bool = False):
    """q: [B,H,T,D]; k,v: [B,Hkv,T,D] (GQA).  Returns [B,H,T,D]."""
    B, H, T, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    bq, bk = min(bq, T), min(bk, T)
    assert T % bq == 0 and T % bk == 0
    nq, nk = T // bq, T // bk
    scale = 1.0 / math.sqrt(D)

    # flatten (B, Hkv, G) into one grid axis; the kv index map drops G
    qf = q.reshape(B * Hkv * G, T, D)
    kf = k.reshape(B * Hkv, T, D)
    vf = v.reshape(B * Hkv, T, D)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, nk=nk, bq=bq, bk=bk, scale=scale,
                          causal=causal, window=window),
        grid=(B * Hkv * G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, G=G: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv * G, T, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, T, D)


# ---------------------------------------------------------------------------
# packed multi-request prefill (flat stream + per-segment arena history)
# ---------------------------------------------------------------------------


def _packed_prefill_kernel(sot_ref, st_ref, off_ref, len_ref, bt_ref,
                           q_ref, kn_ref, vn_ref, kp_ref, vp_ref, o_ref,
                           m_ref, l_ref, acc_ref, *,
                           nw: int, nq: int, bq: int, P: int, n_pages: int,
                           ring: int, scale: float, window: int, G: int):
    i = pl.program_id(1)                   # query tile (one segment each)
    j = pl.program_id(2)                   # KV step: [0,nw) history pages,
    #                                        [nw,nw+nq) stream tiles

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seg = sot_ref[i]                       # segment owning this query tile
    start = st_ref[seg]
    off = off_ref[seg]
    ln = len_ref[seg]
    t0 = i * bq

    def accum(s, v):
        m_prev = m_ref[...]                                  # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot_general(
                            p.astype(v.dtype), v,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(j < nw)
    def _history():
        # one logical page of THIS segment's arena history; sentinel pages
        # (unallocated / pad segment), pages beyond the ring span, and
        # zero-history segments are skipped whole
        jh = jnp.minimum(j, nw - 1)
        page = bt_ref[seg, jh]
        s0 = jh * P

        @pl.when((page < n_pages) & (s0 < ring) & (off > 0))
        def _run():
            q = q_ref[0]                                     # [bq, D]
            k = kp_ref[0, :, 0, :]                           # [P, D]
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale  # [bq, P]
            rows = jax.lax.broadcasted_iota(jnp.int32, (bq, P), 0)
            cols = jax.lax.broadcasted_iota(jnp.int32, (bq, P), 1)
            jj_q = t0 + rows - start       # query index within segment
            q_pos = off + jj_q
            s_idx = s0 + cols              # logical ring slot
            # ring slot s holds the largest position p < off, p % ring == s
            prev_pos = off - 1 - jnp.remainder(off - 1 - s_idx, ring)
            mask = ((jj_q < ln) & (s_idx < ring)
                    & (prev_pos >= 0) & (prev_pos <= q_pos))
            if window > 0:
                mask &= (q_pos - prev_pos) < window
            accum(jnp.where(mask, s, NEG_INF), vp_ref[0, :, 0, :])

    @pl.when(j >= nw)
    def _stream():
        # one stream tile: only causally-visible tiles of the SAME segment
        jj = jnp.maximum(j - nw, 0)

        @pl.when((sot_ref[jj] == seg) & (jj <= i))
        def _run():
            q = q_ref[0]                                     # [bq, D]
            k = kn_ref[0]                                    # [bq, D]
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale  # [bq, bq]
            rows = jax.lax.broadcasted_iota(jnp.int32, (bq, bq), 0)
            cols = jax.lax.broadcasted_iota(jnp.int32, (bq, bq), 1)
            jj_q = t0 + rows - start
            jj_k = jj * bq + cols - start
            mask = (jj_q < ln) & (jj_k < ln) & (jj_k <= jj_q)
            if window > 0:
                mask &= (jj_q - jj_k) < window
            accum(jnp.where(mask, s, NEG_INF), vn_ref[0])

    @pl.when(j == nw + nq - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("ring", "window", "bq", "interpret"))
def packed_prefill_attention(q, k_new, v_new, k_pages, v_pages, block_tables,
                             seg_starts, seg_offsets, seg_lengths, *,
                             ring: int, window: int = 0, bq: int = 128,
                             interpret: bool = False):
    """Segment-masked online-softmax attention over a PACKED prefill stream.

    One tick's prefill chunks, laid out back to back in a flat stream of T
    tokens (rope already applied):

      q:            [T, H, D]    packed queries
      k_new/v_new:  [T, Hkv, D]  the stream's own keys/values
      k_pages/v_pages: [n_pages, P, Hkv, D] arena history pool
      block_tables: [N, W] int32 per-SEGMENT page rows (>= n_pages =
                    unallocated sentinel; pad segments are all-sentinel)
      seg_starts/seg_offsets/seg_lengths: [N] segment start in the stream
                    (multiple of ``bq`` — a tile never straddles two
                    segments), arena history length, and token count

    Grid: (Hkv*G, T/bq, W + T/bq) — for each query tile the KV axis first
    walks the owning segment's history pages (scalar-prefetched block-table
    rows gather physical pages in the BlockSpec index_map, exactly like the
    paged decode kernel) and then the stream tiles, skipping other
    segments' tiles and causal-future tiles whole via pl.when.  ``ring`` is
    the arena's logical ring span R (positions live at ``pos % R``); the
    dense [B, R, ...] arena is served by the same kernel as a 1-page-per-
    segment pool view (P = R, block table = the segment's slot).

    Returns ctx [T, H, D] (pre-``wo``); rows of pad tokens are garbage the
    caller discards, exactly like padded rows in the pure-JAX path.
    """
    T, H, D = q.shape
    Hkv = k_new.shape[1]
    G = H // Hkv
    n_pages, P = k_pages.shape[0], k_pages.shape[1]
    W = block_tables.shape[1]
    assert T % bq == 0, (T, bq)
    nq = T // bq
    scale = 1.0 / math.sqrt(D)
    starts = jnp.asarray(seg_starts, jnp.int32)
    bt = jnp.asarray(block_tables, jnp.int32)
    # owning segment of each query tile (pad segments carry start == T, so
    # tail tiles resolve to the last real segment and mask out row-wise)
    tile0 = jnp.arange(nq, dtype=jnp.int32) * bq
    seg_of_tile = jnp.maximum(
        jnp.sum(tile0[:, None] >= starts[None, :], axis=1) - 1,
        0).astype(jnp.int32)
    qh = q.swapaxes(0, 1)                  # [H, T, D]
    kh = k_new.swapaxes(0, 1)              # [Hkv, T, D]
    vh = v_new.swapaxes(0, 1)

    def page_map(h, i, j, sot, st, off, ln, btr):
        # clamp the sentinel: the fetched page is ignored (pl.when masks
        # the whole step) but the DMA address must stay in bounds
        pg = btr[sot[i], jnp.minimum(j, W - 1)]
        return (jnp.minimum(pg, n_pages - 1), 0, h // G, 0)

    def stream_map(h, i, j, sot, st, off, ln, btr):
        return (h // G, jnp.maximum(j - W, 0), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(Hkv * G, nq, W + nq),
        in_specs=[
            pl.BlockSpec((1, bq, D),
                         lambda h, i, j, sot, st, off, ln, btr: (h, i, 0)),
            pl.BlockSpec((1, bq, D), stream_map),
            pl.BlockSpec((1, bq, D), stream_map),
            pl.BlockSpec((1, P, 1, D), page_map),
            pl.BlockSpec((1, P, 1, D), page_map),
        ],
        out_specs=pl.BlockSpec(
            (1, bq, D), lambda h, i, j, sot, st, off, ln, btr: (h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_packed_prefill_kernel, nw=W, nq=nq, bq=bq, P=P,
                          n_pages=n_pages, ring=ring, scale=scale,
                          window=window, G=G),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv * G, T, D), q.dtype),
        interpret=interpret,
    )(seg_of_tile, starts, jnp.asarray(seg_offsets, jnp.int32),
      jnp.asarray(seg_lengths, jnp.int32), bt, qh, kh, vh, k_pages, v_pages)
    return out.swapaxes(0, 1)              # [T, H, D]
