"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(x, w):
    """[M,K] @ [K,N] -> [M,N], f32 accumulation, output in x.dtype."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def gemv_ref(x, w, scale=None):
    """Batched GEMV: x [B,K] @ w [K,N] (w possibly int8 with per-col scale)."""
    wf = w.astype(jnp.float32)
    if scale is not None:
        wf = wf * scale[None, :].astype(jnp.float32)
    out = jnp.dot(x.astype(jnp.float32), wf)
    return out.astype(x.dtype)


def flash_attention_ref(q, k, v, causal=True, window=0):
    """q,k,v: [B,H,T,D] (kv may have fewer heads -> GQA broadcast)."""
    B, H, T, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, T, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) / math.sqrt(D)
    idx = jnp.arange(T)
    mask = jnp.ones((T, T), bool)
    if causal:
        mask &= idx[None, :] <= idx[:, None]
    if window and window > 0:
        mask &= (idx[:, None] - idx[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return o.reshape(B, H, T, D).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: [B,H,D]; caches: [B,S,Hkv,D]; lengths: [B] #valid entries.

    Returns [B,H,D].
    """
    B, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache.astype(jnp.float32))
    s = s / math.sqrt(D)
    valid = jnp.arange(S)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def ssd_chunk_ref(x, dt, A, Bm, Cm):
    """Single-chunk SSD (no inter-chunk state): x [Q,H,P], dt [Q,H],
    A [H], Bm/Cm [Q,N] (1 group).  Returns (y [Q,H,P], state [H,P,N]).
    """
    Q, H, P = x.shape
    N = Bm.shape[1]
    dtf = dt.astype(jnp.float32)
    dA = dtf * A[None, :]                                   # [Q,H]
    xb = x.astype(jnp.float32) * dtf[..., None]
    cs = jnp.cumsum(dA, axis=0)                             # [Q,H]
    seg = cs[:, None, :] - cs[None, :, :]                   # [i,j,H]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[..., None], jnp.exp(seg), 0.0)       # [Q,Q,H]
    CB = Cm.astype(jnp.float32) @ Bm.astype(jnp.float32).T  # [Q,Q]
    y = jnp.einsum("ij,ijh,jhp->ihp", CB, L, xb)
    decay_end = jnp.exp(cs[-1:, :] - cs)                    # [Q,H]
    state = jnp.einsum("qn,qh,qhp->hpn", Bm.astype(jnp.float32), decay_end, xb)
    return y, state
