"""Operator-graph extraction: ModelConfig x (phase, context, batch) -> ops.

This is the workload half of the HALO analytical model (Section IV-B /
Fig. 4 of the paper profile exactly these operators).  Every transformer /
SSD / MoE / MLA sub-operation becomes an :class:`Op` with its matmul
dimensions, the bytes it must stream from memory (weights, or KV cache —
whatever is resident in DRAM), and elementwise/special-function op counts
for the non-GEMM units.

The paper evaluates dense models (LLaMA-2 7B, Qwen3 8B); the extraction
below also covers the assigned MoE / MLA / SSM / hybrid architectures so the
phase-aware mapping can be studied beyond the paper (EXPERIMENTS.md §Beyond).

Conventions:
  * weights and KV are 8-bit (HALO computes int8 end-to-end): 1 byte/elem.
  * ``m`` is the GEMM M dimension (tokens in flight).  Decode ops therefore
    have m == batch — the engines decide memory- vs compute-bound from that.
  * ``count`` replicates an op (e.g. once per layer).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.configs.base import ModelConfig

BYTES = 1  # int8


@dataclass(frozen=True)
class Op:
    name: str
    kind: str                 # "matmul" | "attn" | "ew" | "softmax" | "norm"
    m: int = 0                # matmul dims (per instance)
    k: int = 0
    n: int = 0
    batch: int = 1            # independent matmul instances (e.g. B*H)
    stream_bytes: int = 0     # bytes streamed from DRAM (weights / KV cache)
    ew_ops: int = 0           # elementwise ops (vector unit)
    sfu_ops: int = 0          # exp/rsqrt ops (SFU)
    count: int = 1            # replication across layers
    is_attention: bool = False

    @property
    def flops(self) -> int:
        mm = 2 * self.m * self.k * self.n * self.batch
        return (mm + self.ew_ops + self.sfu_ops) * self.count

    @property
    def total_stream(self) -> int:
        return self.stream_bytes * self.count


def _norm_op(name, tokens, d, count=1) -> Op:
    return Op(name, "norm", ew_ops=4 * tokens * d, sfu_ops=tokens,
              stream_bytes=tokens * d * BYTES, count=count)


def _softmax_op(name, rows, width, count=1) -> Op:
    return Op(name, "softmax", ew_ops=3 * rows * width, sfu_ops=rows * width,
              stream_bytes=0, count=count)


def _attn_ctx(cfg: ModelConfig, layer_window: int, ctx: int) -> int:
    """Effective attended context for a layer (sliding window bounds it)."""
    return min(ctx, layer_window) if layer_window > 0 else ctx


def _layer_windows(cfg: ModelConfig) -> List[int]:
    out = []
    for kind in cfg.layer_kinds():
        if kind == "attn_local":
            out.append(cfg.attn.sliding_window)
        elif kind.startswith("attn"):
            out.append(0)
    return out


# ---------------------------------------------------------------------------
# per-phase extraction
# ---------------------------------------------------------------------------

def prefill_ops(cfg: ModelConfig, l_in: int, batch: int) -> List[Op]:
    """Operator list for one full prefill pass."""
    d = cfg.d_model
    T = batch * l_in
    ops: List[Op] = []
    ops.append(Op("embed", "ew", ew_ops=T * d, stream_bytes=T * d * BYTES))

    for i in range(cfg.n_layers):
        kind = cfg.layer_kinds()[i]
        suffix = f"@L{i}"
        if kind == "ssm":
            ops += _ssm_ops(cfg, l_in, batch, phase="prefill", idx=i)
        else:
            window = (cfg.attn.sliding_window
                      if kind == "attn_local" else 0)
            ops += _attn_block_ops(cfg, l_in, batch, window, phase="prefill",
                                   ctx=l_in, idx=i)
        ops += _ffn_ops(cfg, i, T, batch, phase="prefill")
    if cfg.hybrid.enabled:
        ops += _shared_attn_ops(cfg, l_in, batch, phase="prefill", ctx=l_in)

    ops.append(_norm_op("final_norm", T, d))
    # only the last position feeds the LM head during prefill
    V = cfg.vocab_size
    ops.append(Op("lm_head", "matmul", m=batch, k=d, n=V,
                  stream_bytes=d * V * BYTES))
    return ops


def decode_ops(cfg: ModelConfig, ctx: int, batch: int) -> List[Op]:
    """Operator list for generating ONE token at context length ``ctx``."""
    d = cfg.d_model
    ops: List[Op] = []
    ops.append(Op("embed", "ew", ew_ops=batch * d,
                  stream_bytes=batch * d * BYTES))
    for i in range(cfg.n_layers):
        kind = cfg.layer_kinds()[i]
        if kind == "ssm":
            ops += _ssm_ops(cfg, 1, batch, phase="decode", idx=i)
        else:
            window = (cfg.attn.sliding_window
                      if kind == "attn_local" else 0)
            ops += _attn_block_ops(cfg, 1, batch, window, phase="decode",
                                   ctx=ctx, idx=i)
        ops += _ffn_ops(cfg, i, batch, batch, phase="decode")
    if cfg.hybrid.enabled:
        ops += _shared_attn_ops(cfg, 1, batch, phase="decode", ctx=ctx)
    ops.append(_norm_op("final_norm", batch, d))
    V = cfg.vocab_size
    ops.append(Op("lm_head", "matmul", m=batch, k=d, n=V,
                  stream_bytes=d * V * BYTES))
    return ops


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _attn_block_ops(cfg, l_q: int, batch: int, window: int, phase: str,
                    ctx: int, idx: int) -> List[Op]:
    d = cfg.d_model
    H, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    T = batch * l_q
    eff_ctx = _attn_ctx(cfg, window, ctx)
    ops: List[Op] = [_norm_op(f"ln1@L{idx}", T, d)]

    if cfg.mla.enabled:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        rank = m.kv_lora_rank
        q_in = m.q_lora_rank if m.q_lora_rank else d
        if m.q_lora_rank:
            ops.append(Op(f"q_down@L{idx}", "matmul", m=T, k=d, n=m.q_lora_rank,
                          stream_bytes=d * m.q_lora_rank))
        ops.append(Op(f"q_up@L{idx}", "matmul", m=T, k=q_in, n=H * qk,
                      stream_bytes=q_in * H * qk))
        ops.append(Op(f"kv_down@L{idx}", "matmul", m=T, k=d,
                      n=rank + m.qk_rope_head_dim,
                      stream_bytes=d * (rank + m.qk_rope_head_dim)))
        if phase == "prefill":
            # materialize K/V from latent: GEMM over T tokens
            ops.append(Op(f"kv_up@L{idx}", "matmul", m=T, k=rank,
                          n=H * (m.qk_nope_head_dim + m.v_head_dim),
                          stream_bytes=rank * H * (m.qk_nope_head_dim + m.v_head_dim)))
            score_ctx, v_dim = eff_ctx, m.v_head_dim
            ops.append(Op(f"scores@L{idx}", "attn", m=l_q, k=qk, n=score_ctx,
                          batch=batch * H, stream_bytes=0, is_attention=True))
            ops.append(_softmax_op(f"softmax@L{idx}", batch * H * l_q, score_ctx))
            ops.append(Op(f"attn_v@L{idx}", "attn", m=l_q, k=score_ctx, n=v_dim,
                          batch=batch * H, stream_bytes=0, is_attention=True))
        else:
            # absorbed decode: GEMV over the latent cache
            cache_bytes = batch * eff_ctx * (rank + m.qk_rope_head_dim) * BYTES
            ops.append(Op(f"q_absorb@L{idx}", "matmul", m=batch, k=m.qk_nope_head_dim,
                          n=rank, batch=H, stream_bytes=H * m.qk_nope_head_dim * rank))
            ops.append(Op(f"scores@L{idx}", "attn", m=1, k=rank + m.qk_rope_head_dim,
                          n=eff_ctx, batch=batch * H, stream_bytes=cache_bytes,
                          is_attention=True))
            ops.append(_softmax_op(f"softmax@L{idx}", batch * H, eff_ctx))
            ops.append(Op(f"attn_v@L{idx}", "attn", m=1, k=eff_ctx, n=rank,
                          batch=batch * H, stream_bytes=cache_bytes,
                          is_attention=True))
            ops.append(Op(f"v_absorb@L{idx}", "matmul", m=batch, k=rank,
                          n=m.v_head_dim, batch=H,
                          stream_bytes=H * rank * m.v_head_dim))
        ops.append(Op(f"o_proj@L{idx}", "matmul", m=T, k=H * m.v_head_dim, n=d,
                      stream_bytes=H * m.v_head_dim * d))
        return ops

    # standard GQA
    ops.append(Op(f"qkv@L{idx}", "matmul", m=T, k=d, n=(H + 2 * Hkv) * dh,
                  stream_bytes=d * (H + 2 * Hkv) * dh))
    ops.append(Op(f"rope@L{idx}", "ew", ew_ops=4 * T * (H + Hkv) * dh))
    kv_bytes = batch * eff_ctx * Hkv * dh * BYTES
    if phase == "prefill":
        # causal: average attended length ~ eff_ctx/2 for full attention
        avg_ctx = (eff_ctx + 1) // 2 if window == 0 else eff_ctx
        ops.append(Op(f"scores@L{idx}", "attn", m=l_q, k=dh, n=avg_ctx,
                      batch=batch * H, stream_bytes=0, is_attention=True))
        ops.append(_softmax_op(f"softmax@L{idx}", batch * H * l_q, avg_ctx))
        ops.append(Op(f"attn_v@L{idx}", "attn", m=l_q, k=avg_ctx, n=dh,
                      batch=batch * H, stream_bytes=0, is_attention=True))
    else:
        ops.append(Op(f"scores@L{idx}", "attn", m=1, k=dh, n=eff_ctx,
                      batch=batch * H, stream_bytes=kv_bytes, is_attention=True))
        ops.append(_softmax_op(f"softmax@L{idx}", batch * H, eff_ctx))
        ops.append(Op(f"attn_v@L{idx}", "attn", m=1, k=eff_ctx, n=dh,
                      batch=batch * H, stream_bytes=kv_bytes, is_attention=True))
    ops.append(Op(f"o_proj@L{idx}", "matmul", m=T, k=H * dh, n=d,
                  stream_bytes=H * dh * d))
    return ops


def _ffn_ops(cfg, idx: int, T: int, batch: int, phase: str) -> List[Op]:
    d = cfg.d_model
    ops: List[Op] = []
    if cfg.layer_kinds()[idx] == "ssm" and (cfg.d_ff == 0
                                            or cfg.family == "hybrid"):
        return ops                      # hybrid: FFN lives in the shared block
    if cfg.ffn_kind(idx) == "moe":
        m = cfg.moe
        ops.append(_norm_op(f"ln2@L{idx}", T, d))
        ops.append(Op(f"router@L{idx}", "matmul", m=T, k=d, n=m.n_experts,
                      stream_bytes=d * m.n_experts))
        # routed experts: tokens*top_k rows; streamed weights depend on phase
        if phase == "decode" and batch * m.top_k < m.n_experts:
            active = batch * m.top_k            # distinct experts touched (<=)
        else:
            active = m.n_experts
        w_bytes = active * 3 * d * m.d_ff_expert * BYTES
        ops.append(Op(f"moe_ffn@L{idx}", "matmul", m=T * m.top_k, k=d,
                      n=m.d_ff_expert, batch=3, stream_bytes=w_bytes))
        ops.append(Op(f"moe_act@L{idx}", "ew", ew_ops=4 * T * m.top_k * m.d_ff_expert))
        if m.n_shared_experts:
            ff = m.n_shared_experts * m.d_ff_expert
            ops.append(Op(f"shared_ffn@L{idx}", "matmul", m=T, k=d, n=ff,
                          batch=3, stream_bytes=3 * d * ff))
        if m.dense_residual:
            ops.append(Op(f"dense_res@L{idx}", "matmul", m=T, k=d, n=m.d_ff_dense,
                          batch=3, stream_bytes=3 * d * m.d_ff_dense))
    else:
        ff = cfg.d_ff
        ops.append(_norm_op(f"ln2@L{idx}", T, d))
        ops.append(Op(f"ffn@L{idx}", "matmul", m=T, k=d, n=ff, batch=3,
                      stream_bytes=3 * d * ff))
        ops.append(Op(f"ffn_act@L{idx}", "ew", ew_ops=4 * T * ff))
    return ops


def _ssm_ops(cfg, l_q: int, batch: int, phase: str, idx: int) -> List[Op]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gn = s.n_groups * s.d_state
    T = batch * l_q
    in_dim = 2 * di + 2 * gn + nh
    ops: List[Op] = [_norm_op(f"ln1@L{idx}", T, d)]
    ops.append(Op(f"ssm_in@L{idx}", "matmul", m=T, k=d, n=in_dim,
                  stream_bytes=d * in_dim))
    ops.append(Op(f"conv@L{idx}", "ew", ew_ops=2 * T * (di + 2 * gn) * s.d_conv))
    if phase == "prefill":
        # chunked SSD: intra-chunk GEMMs dominate
        Q = min(s.chunk_size, l_q)
        nc = max(l_q // Q, 1)
        ops.append(Op(f"ssd_cb@L{idx}", "attn", m=Q, k=s.d_state, n=Q,
                      batch=batch * nc * s.n_groups, is_attention=True))
        ops.append(Op(f"ssd_diag@L{idx}", "attn", m=Q, k=Q, n=s.head_dim,
                      batch=batch * nc * nh, is_attention=True))
        ops.append(Op(f"ssd_state@L{idx}", "attn", m=s.d_state, k=Q, n=s.head_dim,
                      batch=batch * nc * nh, is_attention=True))
        ops.append(Op(f"ssd_off@L{idx}", "attn", m=Q, k=s.d_state, n=s.head_dim,
                      batch=batch * nc * nh, is_attention=True))
        ops.append(Op(f"ssd_decay@L{idx}", "ew",
                      ew_ops=6 * batch * nc * nh * Q, sfu_ops=batch * nc * nh * Q))
    else:
        state_bytes = batch * nh * s.head_dim * s.d_state * BYTES
        # state update + output: elementwise + tiny GEMVs over the state
        ops.append(Op(f"ssm_step@L{idx}", "ew",
                      ew_ops=6 * batch * nh * s.head_dim * s.d_state,
                      sfu_ops=2 * batch * nh,
                      stream_bytes=2 * state_bytes))
    ops.append(Op(f"ssm_gate@L{idx}", "ew", ew_ops=6 * T * di, sfu_ops=T))
    ops.append(Op(f"ssm_out@L{idx}", "matmul", m=T, k=di, n=d,
                  stream_bytes=di * d))
    return ops


def _shared_attn_ops(cfg, l_q: int, batch: int, phase: str, ctx: int) -> List[Op]:
    """Zamba2 shared block, invoked n_layers // every times."""
    h = cfg.hybrid
    n_inv = cfg.n_layers // h.shared_attn_every
    d_in = cfg.d_model * (2 if h.concat_embedding else 1)
    nh = h.shared_attn_n_heads
    dh = d_in // nh
    T = batch * l_q
    ops: List[Op] = []
    ops.append(Op("shared_qkvo", "matmul", m=T, k=d_in, n=4 * d_in,
                  stream_bytes=4 * d_in * d_in, count=n_inv))
    if phase == "prefill":
        avg = (ctx + 1) // 2
        ops.append(Op("shared_scores", "attn", m=l_q, k=dh, n=avg,
                      batch=batch * nh, count=n_inv, is_attention=True))
        ops.append(_softmax_op("shared_softmax", batch * nh * l_q, avg, count=n_inv))
        ops.append(Op("shared_av", "attn", m=l_q, k=avg, n=dh,
                      batch=batch * nh, count=n_inv, is_attention=True))
    else:
        kv = batch * ctx * nh * dh * BYTES
        ops.append(Op("shared_scores", "attn", m=1, k=dh, n=ctx,
                      batch=batch * nh, stream_bytes=kv, count=n_inv,
                      is_attention=True))
        ops.append(_softmax_op("shared_softmax", batch * nh, ctx, count=n_inv))
        ops.append(Op("shared_av", "attn", m=1, k=ctx, n=dh,
                      batch=batch * nh, stream_bytes=kv, count=n_inv,
                      is_attention=True))
    ops.append(Op("shared_ffn", "matmul", m=T, k=d_in, n=cfg.d_ff, batch=3,
                  stream_bytes=3 * d_in * cfg.d_ff, count=n_inv))
    ops.append(Op("shared_down", "matmul", m=T, k=d_in, n=cfg.d_model,
                  stream_bytes=d_in * cfg.d_model, count=n_inv))
    return ops


# ---------------------------------------------------------------------------
# summaries
# ---------------------------------------------------------------------------

def total_flops(ops: List[Op]) -> int:
    return sum(o.flops for o in ops)


def total_stream(ops: List[Op]) -> int:
    return sum(o.total_stream for o in ops)
