"""Phase-aware mapping strategies (paper Table II).

A mapping assigns every Op to an engine, per phase.  Non-GEMM ops (norms,
softmax, rope, activations) always run on the logic-die vector units in every
strategy — the strategies differ only in where the GEMM/GEMV work goes.

  halo1    prefill GEMMs -> CiM (128 wordlines), ALL decode GEMVs -> CiD.
  halo2    same with 64 wordlines (non-ideality mitigation; 2x ADC energy).
  cent     everything -> CiD in both phases (CENT / fully-CiD).
  attacc1  prefill -> CiM(128wl); decode: ONLY attention -> CiD, the rest
           (QKV/proj/FFN/LM-head GEMVs) stays on CiM.
  attacc2  same with 64 wordlines.
  full_cim everything -> CiM (the Section V-B extreme).
  halo_sa  phase-aware like halo1 but CiM replaced by an iso-area digital
           systolic array (Section V-D, i.e. a NeuPIM-like design).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict

from repro.core.opgraph import Op

NON_GEMM = ("ew", "softmax", "norm")


@dataclass(frozen=True)
class Mapping:
    name: str
    wordlines: int                              # CiM wordlines (latency/energy)
    prefill_engine: Callable[[Op], str]
    decode_engine: Callable[[Op], str]

    def engine_for(self, op: Op, phase: str) -> str:
        if op.kind in NON_GEMM:
            return "vu"
        sel = self.prefill_engine if phase == "prefill" else self.decode_engine
        return sel(op)


def _const(engine: str) -> Callable[[Op], str]:
    return lambda op: engine


def _attacc_decode(op: Op) -> str:
    return "cid" if op.is_attention else "cim"


MAPPINGS: Dict[str, Mapping] = {
    "halo1": Mapping("halo1", 128, _const("cim"), _const("cid")),
    "halo2": Mapping("halo2", 64, _const("cim"), _const("cid")),
    "cent": Mapping("cent", 128, _const("cid"), _const("cid")),
    "full_cid": Mapping("full_cid", 128, _const("cid"), _const("cid")),
    "full_cim": Mapping("full_cim", 128, _const("cim"), _const("cim")),
    "attacc1": Mapping("attacc1", 128, _const("cim"), _attacc_decode),
    "attacc2": Mapping("attacc2", 64, _const("cim"), _attacc_decode),
    "halo_sa": Mapping("halo_sa", 128, _const("sa"), _const("cid")),
}


def get_mapping(name: str) -> Mapping:
    return MAPPINGS[name]
