"""Execution engines: latency + energy of one Op on one HALO compute unit.

Latency model (per engine):

  CiD   t = max(flops / peak_ops, stream_bytes / internal_bw)
        GEMV (m==batch small) is stream-bound: the 41 TB/s aggregate in-bank
        bandwidth is the service rate.  GEMM is capped at the 41 Tops the
        bank-level MACs provide (weights are register-held and reused across
        the input vectors resident in the 4 KB SRAM buffer).

  CiM   t = max(flops / peak_ops, stream_bytes / fill_bw)
        GEMM is compute-bound at the analog-array rate (ADC-pipelined);
        GEMV is fill-bound: every weight byte must cross the 1 TB/s GB path.
        64-wordline mode halves peak_ops and doubles ADC energy.

  SA    same shape as CiM with digital-systolic constants (HALO-SA).

  VU    elementwise/softmax/norm ops on the logic-die vector units;
        exp/rsqrt on the SFU at 1/4 rate.

The max() encodes the double-buffered overlap of fills with compute that the
paper inherits from COMET: whichever pipeline stage is slower hides the
other.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.hardware import HaloHardware
from repro.core.opgraph import Op


@dataclass(frozen=True)
class Cost:
    seconds: float
    joules: float

    def __add__(self, other: "Cost") -> "Cost":
        return Cost(self.seconds + other.seconds, self.joules + other.joules)


ZERO = Cost(0.0, 0.0)


class Engine:
    name = "abstract"

    def cost(self, op: Op) -> Cost:
        raise NotImplementedError


class CiDEngine(Engine):
    name = "cid"

    def __init__(self, hw: HaloHardware):
        self.c = hw.cid

    def cost(self, op: Op) -> Cost:
        t_compute = op.flops / self.c.peak_ops
        t_stream = op.total_stream / self.c.internal_bw
        t = max(t_compute, t_stream)
        e = (op.flops * self.c.e_mac
             + op.total_stream * self.c.e_bank_read
             + op.total_stream * self.c.e_buffer)
        return Cost(t, e)


class CiMEngine(Engine):
    name = "cim"

    def __init__(self, hw: HaloHardware):
        self.c = hw.cim

    def cost(self, op: Op) -> Cost:
        t_compute = op.flops / self.c.peak_ops
        t_fill = op.total_stream / self.c.fill_bw
        t = max(t_compute, t_fill)
        e = (op.flops * self.c.e_per_op()
             + op.total_stream * self.c.e_fill
             + op.total_stream * self.c.e_buffer)
        return Cost(t, e)


class SystolicEngine(Engine):
    name = "sa"

    def __init__(self, hw: HaloHardware):
        self.c = hw.sa

    def cost(self, op: Op) -> Cost:
        t = max(op.flops / self.c.peak_ops, op.total_stream / self.c.fill_bw)
        e = op.flops * self.c.e_mac + op.total_stream * self.c.e_fill
        return Cost(t, e)


class VectorEngine(Engine):
    name = "vu"

    def __init__(self, hw: HaloHardware):
        self.c = hw.vu
        self.hw = hw

    def cost(self, op: Op) -> Cost:
        t = (op.ew_ops * op.count / self.c.peak_ops
             + op.sfu_ops * op.count / self.c.peak_sfu_ops)
        t = max(t, op.total_stream / self.hw.cim.gb_bw)
        e = ((op.ew_ops + op.sfu_ops) * op.count * self.c.e_op
             + op.total_stream * self.c.e_sram)
        return Cost(t, e)


def make_engines(hw: HaloHardware) -> Dict[str, Engine]:
    return {
        "cid": CiDEngine(hw),
        "cim": CiMEngine(hw),
        "sa": SystolicEngine(hw),
        "vu": VectorEngine(hw),
    }
