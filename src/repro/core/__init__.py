from repro.core.hardware import DEFAULT_HW, TPU_V5E, HaloHardware, TPUv5e
from repro.core.mapping import MAPPINGS, Mapping, get_mapping
from repro.core.opgraph import Op, decode_ops, prefill_ops, total_flops, total_stream
from repro.core.scheduler import (
    DEFAULT_GRID,
    RunResult,
    evaluate,
    geomean,
    gmean_speedup,
)

__all__ = [
    "DEFAULT_HW", "TPU_V5E", "HaloHardware", "TPUv5e",
    "MAPPINGS", "Mapping", "get_mapping",
    "Op", "decode_ops", "prefill_ops", "total_flops", "total_stream",
    "DEFAULT_GRID", "RunResult", "evaluate", "geomean", "gmean_speedup",
]
