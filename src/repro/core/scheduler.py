"""HALO phase scheduler: ops x mapping x hardware -> TTFT / TPOT / E2E / energy.

Decode cost grows affinely with context length t (KV-cache streaming and
softmax width are linear in t, everything else constant), so the total decode
time over L_out tokens is computed EXACTLY from the two endpoints:

    sum_{t=L_in..L_in+L_out-1} cost(t) = L_out * (cost(t0) + cost(t1)) / 2

This is the paper's evaluation loop (Figs. 5-10) in closed form.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.engines import make_engines
from repro.core.hardware import DEFAULT_HW, HaloHardware
from repro.core.mapping import Mapping, get_mapping
from repro.core.opgraph import Op, decode_ops, prefill_ops


@dataclass
class PhaseResult:
    seconds: float = 0.0
    joules: float = 0.0
    by_engine_s: Dict[str, float] = field(default_factory=dict)
    by_op_kind_s: Dict[str, float] = field(default_factory=dict)


@dataclass
class RunResult:
    """One (model, mapping, L_in, L_out, batch) evaluation."""

    model: str
    mapping: str
    l_in: int
    l_out: int
    batch: int
    ttft: float                    # prefill seconds
    tpot: float                    # mean seconds per output token
    decode_total: float
    prefill_energy: float
    decode_energy: float
    prefill_detail: PhaseResult = None
    decode_detail: PhaseResult = None

    @property
    def e2e(self) -> float:
        return self.ttft + self.decode_total

    @property
    def energy(self) -> float:
        return self.prefill_energy + self.decode_energy


def _phase_cost(ops: List[Op], mapping: Mapping, engines, phase: str
                ) -> PhaseResult:
    res = PhaseResult()
    for op in ops:
        eng = mapping.engine_for(op, phase)
        c = engines[eng].cost(op)
        res.seconds += c.seconds
        res.joules += c.joules
        res.by_engine_s[eng] = res.by_engine_s.get(eng, 0.0) + c.seconds
        base = op.name.split("@")[0]
        res.by_op_kind_s[base] = res.by_op_kind_s.get(base, 0.0) + c.seconds
    return res


def evaluate(cfg: ModelConfig, mapping_name: str, l_in: int, l_out: int,
             batch: int = 1, hw: Optional[HaloHardware] = None) -> RunResult:
    mapping = get_mapping(mapping_name)
    hw = (hw or DEFAULT_HW).with_wordlines(mapping.wordlines)
    engines = make_engines(hw)

    pre = _phase_cost(prefill_ops(cfg, l_in, batch), mapping, engines, "prefill")

    # decode: affine in context -> exact trapezoid over [t0, t1]
    t0 = max(l_in, 1)
    t1 = l_in + max(l_out, 1) - 1
    d0 = _phase_cost(decode_ops(cfg, t0, batch), mapping, engines, "decode")
    d1 = _phase_cost(decode_ops(cfg, t1, batch), mapping, engines, "decode")
    tpot = (d0.seconds + d1.seconds) / 2.0
    decode_total = tpot * l_out
    decode_energy = (d0.joules + d1.joules) / 2.0 * l_out

    mid = PhaseResult(
        seconds=tpot, joules=(d0.joules + d1.joules) / 2.0,
        by_engine_s={k: (d0.by_engine_s.get(k, 0) + d1.by_engine_s.get(k, 0)) / 2
                     for k in set(d0.by_engine_s) | set(d1.by_engine_s)},
        by_op_kind_s={k: (d0.by_op_kind_s.get(k, 0) + d1.by_op_kind_s.get(k, 0)) / 2
                      for k in set(d0.by_op_kind_s) | set(d1.by_op_kind_s)})

    return RunResult(
        model=cfg.name, mapping=mapping_name, l_in=l_in, l_out=l_out,
        batch=batch, ttft=pre.seconds, tpot=tpot, decode_total=decode_total,
        prefill_energy=pre.joules, decode_energy=decode_energy,
        prefill_detail=pre, decode_detail=mid)


# ---------------------------------------------------------------------------
# sweeps + geometric means (the paper's headline numbers)
# ---------------------------------------------------------------------------

# (L_in, L_out) grid used for the Fig. 7/8 style end-to-end comparisons;
# the paper spans 128..10K for both axes.
DEFAULT_GRID = [
    (512, 128), (2048, 128), (8192, 128),
    (512, 2048), (2048, 2048), (8192, 2048),
]

PREFILL_LENGTHS = [512, 2048, 8192]             # Fig. 5 TTFT sweep (paper: 512-8192)
DECODE_GRID = [(512, 512), (2048, 512), (2048, 2048), (8192, 512)]  # Fig. 6


def geomean(xs: List[float]) -> float:
    import math
    xs = [max(x, 1e-30) for x in xs]
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


def gmean_speedup(cfg: ModelConfig, base: str, ours: str,
                  grid=None, metric: str = "e2e", batch: int = 1,
                  hw: Optional[HaloHardware] = None) -> float:
    """Geometric-mean ratio base/ours over the (L_in, L_out) grid."""
    grid = grid or DEFAULT_GRID
    ratios = []
    for l_in, l_out in grid:
        a = evaluate(cfg, base, l_in, l_out, batch, hw)
        b = evaluate(cfg, ours, l_in, l_out, batch, hw)
        get = {
            "e2e": lambda r: r.e2e,
            "ttft": lambda r: r.ttft,
            "tpot": lambda r: r.tpot,
            "energy": lambda r: r.energy,
            "prefill_energy": lambda r: r.prefill_energy,
            "decode_energy": lambda r: r.decode_energy / max(r.l_out, 1),
        }[metric]
        ratios.append(get(a) / get(b))
    return geomean(ratios)
