"""Hardware descriptions for the HALO analytical performance/energy model.

Derivation of the headline rates (paper Table I + Section V-A):

CiD (compute-in-DRAM, HBM3, 5 stacks / 80 GB)
  banks        = 5 stacks x 16 channels x 2 pseudo-ch x 4 BG x 4 banks = 2560
  column rate  = one 32 B column / tCCD_L (2 ns)  ->  16 GB/s per bank
  internal BW  = 2560 banks x 16 GB/s             ->  ~41 TB/s
  MACs         = 32 8-bit MAC/bank @ 500 MHz      ->  41 Tops int8 aggregate
                 (32 MAC consume 32 weight B/cycle: compute and streaming are
                  balanced at 2 ops/byte by construction — a GEMV never stalls)
  GEMM support = the 4 KB double-buffered SRAM holds ONE 4096-entry int8 input
                 vector; weights are held in the MAC registers for B_in cycles
                 to be reused across inputs, so GEMM throughput is CAPPED at
                 the 41 Tops compute rate (this is why prefill-on-CiD loses).

CiM (analog 8T-SRAM, 2.5D co-packaged)
  units        = 4x4 tiles x 2x2 cores x 1 unit   ->  64 units
  unit         = 8 crossbars of 128x128 (8 bit-slices) = one 128x128 int8 tile
  unit op      = 8 input bit-planes x ceil(128 col / 48 ADC) conversions
                 @ 1 GS/s SAR  ->  ~24 ns per 16384-MAC tile op (128 wordlines)
  peak         = 64 x 16384 / 24 ns ~ 43 TMAC/s; with input/weight double
                 buffering across the IB/WB/OB hierarchy (COMET-modeled) the
                 sustained GEMM rate used here is 250 TMAC/s = 500 Tops
                 (2b/cell slicing -> 4 int8 tiles/unit + ADC interleaving;
                 cross-checked against the paper's 6x TTFT gmean, Fig. 5).
  64-wordline mode (HALO2/AttAcc2): 2 passes -> half rate, 2x ADC energy.
  weight fill  = HBM -> 4 MB global buffer @ 2 TB/s, half-duplex -> 1 TB/s
                 effective fill bandwidth (this caps CiM GEMV: decode-on-CiM
                 re-streams every weight through the GB -> 41x slower than
                 CiD's internal bandwidth, Fig. 6).

Systolic array option (HALO-SA, Section V-D): two 128x128 8b MAC arrays per
core at iso-area, 1 GHz -> 64 cores... (2 arrays x 16384 MACs x 1 GHz x 64) is
area-capped to ~0.77x the CiM rate (paper: CiM1 is 1.3x faster than SA).

Energy constants are per-byte / per-op and calibrated against the paper's
gmean ratios (2.6x prefill CiM/CiD, 3.9x decode CiD/CiM, 2x vs AttAcc1,
1.8x vs CENT) — the paper does not publish absolute Joules, so the absolute
scale is from CACTI-class literature values and the RATIOS are what we
reproduce (see scripts/validate_paper.py and tests/test_paper_claims.py).

The TPU v5e description at the bottom is used by the roofline layer
(launch/roofline.py), not by the paper model.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class CiDConfig:
    """HBM3-embedded bank-level compute (decode engine)."""

    n_stacks: int = 5
    capacity_gb: float = 80.0
    banks: int = 2560                       # 5 x 16ch x 2pc x 4bg x 4banks
    bank_stream_gbps: float = 16.0          # 32B / 2ns tCCD_L
    macs_per_bank: int = 32
    freq_ghz: float = 0.5
    # derived
    @property
    def internal_bw(self) -> float:         # bytes/s
        return self.banks * self.bank_stream_gbps * 1e9

    @property
    def peak_ops(self) -> float:            # int8 ops/s (1 MAC = 2 ops)
        return self.banks * self.macs_per_bank * self.freq_ghz * 1e9 * 2

    # energy (J/byte, J/op) — 1z-nm DRAM process, bank-level access
    e_bank_read: float = 0.5e-12            # J/byte, in-bank row stream
    e_mac: float = 0.43e-12                 # J/op, 8-bit MAC @7nm-scaled
    e_buffer: float = 0.08e-12              # J/byte, local SRAM buffer


@dataclass(frozen=True)
class CiMConfig:
    """On-chip analog CiM accelerator (prefill engine)."""

    tiles: int = 16                         # 4x4 mesh
    cores_per_tile: int = 4                 # 2x2 mesh
    crossbars_per_unit: int = 8             # 8 bit-slices -> 1 int8 tile/unit
    xbar_rows: int = 128
    xbar_cols: int = 128
    adc_per_xbar: int = 48
    adc_gsps: float = 1.0                   # SAR 7-bit, 1 GS/s
    input_bits: int = 8
    wordlines_on: int = 128                 # 128 (HALO1) or 64 (HALO2)
    sustained_tops: float = 500e12          # int8 ops/s, COMET-calibrated
    gb_bw: float = 2e12                     # global buffer, bytes/s
    gb_bytes: int = 4 * 2**20
    ib_bw: float = 4e12
    wb_bw: float = 4e12
    ob_bw: float = 4e12

    @property
    def n_units(self) -> int:
        return self.tiles * self.cores_per_tile

    # 64-wl mode needs 2 passes, but the second pass overlaps with the
    # parent-buffer (GB->WB) fills of the next tile and the narrower
    # accumulation relaxes the SAR conversion depth — the paper reports only
    # a ~10% end-to-end penalty ("amortized by improved overlap with parent
    # memory fills", Sec. V-C).  Calibrated pipeline-overlap gain:
    wl_overlap_gain: float = 1.7

    @property
    def peak_ops(self) -> float:
        if self.wordlines_on >= 128:
            return self.sustained_tops
        scale = (self.wordlines_on / 128.0) * self.wl_overlap_gain
        return self.sustained_tops * min(scale, 1.0)

    @property
    def fill_bw(self) -> float:
        """Effective HBM->GB->WB weight streaming bandwidth (half-duplex GB)."""
        return self.gb_bw / 2.0

    # energy
    e_mac_analog: float = 0.04e-12          # J/op, crossbar MAC (pre-ADC)
    e_adc: float = 4.0e-12                  # J/conversion (7b SAR)
    e_fill: float = 5.0e-12                 # J/byte, HBM ext + interposer + GB
    e_buffer: float = 0.15e-12              # J/byte IB/WB/OB traffic

    def e_per_op(self) -> float:
        """Energy per int8 op including amortized ADC cost."""
        # per unit-op: 16384 MACs, 8 bit-planes x 128 conversions
        convs = self.input_bits * self.xbar_cols * (128 // self.wordlines_on)
        macs = self.xbar_rows * self.xbar_cols
        return self.e_mac_analog + (convs * self.e_adc) / (2 * macs)


@dataclass(frozen=True)
class SystolicConfig:
    """Digital systolic array replacement for CiM (HALO-SA, iso-area)."""

    sustained_tops: float = 260e12          # iso-area with CiM1 -> ~1.3x slower e2e
    fill_bw: float = 1e12                   # same GB path
    e_mac: float = 0.50e-12                 # J/op digital 8b MAC + reg traffic
    e_fill: float = 5.0e-12

    @property
    def peak_ops(self) -> float:
        return self.sustained_tops


@dataclass(frozen=True)
class VectorUnitConfig:
    """Logic-die vector/scalar units (non-GEMM ops)."""

    width: int = 512                        # lanes
    n_units: int = 16                       # one per channel pair
    freq_ghz: float = 1.0
    e_op: float = 0.4e-12                   # J/elementwise-op
    e_sram: float = 0.2e-12                 # J/byte logic-die SRAM

    @property
    def peak_ops(self) -> float:
        return self.width * self.n_units * self.freq_ghz * 1e9

    # special-function throughput (exp for softmax, rsqrt for norms)
    @property
    def peak_sfu_ops(self) -> float:
        return self.peak_ops / 4.0


@dataclass(frozen=True)
class HBMConfig:
    """External (off-stack) HBM path — used when data crosses the interposer."""

    ext_bw: float = 4.1e12                  # 5 stacks x 819 GB/s
    e_ext: float = 5.5e-12                  # J/byte external access


@dataclass(frozen=True)
class HaloHardware:
    cid: CiDConfig = field(default_factory=CiDConfig)
    cim: CiMConfig = field(default_factory=CiMConfig)
    sa: SystolicConfig = field(default_factory=SystolicConfig)
    vu: VectorUnitConfig = field(default_factory=VectorUnitConfig)
    hbm: HBMConfig = field(default_factory=HBMConfig)

    def with_wordlines(self, wl: int) -> "HaloHardware":
        from dataclasses import replace
        return replace(self, cim=replace(self.cim, wordlines_on=wl))


DEFAULT_HW = HaloHardware()


# ---------------------------------------------------------------------------
# TPU v5e — the roofline target for the JAX/Pallas implementation
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TPUv5e:
    peak_flops_bf16: float = 197e12         # per chip
    hbm_bw: float = 819e9                   # bytes/s per chip
    hbm_bytes: float = 16e9                 # 16 GB per chip
    ici_bw: float = 50e9                    # bytes/s per link (~per direction)
    ici_links: int = 4                      # 2D torus (v5e: 4 links/chip)
    vmem_bytes: float = 128 * 2**20


TPU_V5E = TPUv5e()
