"""Checkpointing: atomic, async-capable, reshard-on-restore.

Layout per step:  <dir>/step_<N>/
    manifest.json   — tree structure, leaf dtypes/shapes, step, metadata
    arrays.npz      — flattened leaves keyed by index ("a0", "a1", ...)

Properties needed at scale and how they are met here:
  * atomicity — writes go to ``step_<N>.tmp`` and are renamed only after
    fsync; a crash mid-write never corrupts the latest checkpoint;
  * async — ``save(..., blocking=False)`` snapshots device arrays to host
    (jax.device_get is the only synchronous part) and writes in a
    background thread, overlapping I/O with the next train steps;
  * elastic reshard — restore() takes the CURRENT mesh/shardings and uses
    ``jax.device_put`` per leaf, so a checkpoint written on one mesh shape
    restores onto any other (the arrays are saved unsharded; on a real
    multi-host deployment each host would write its shard set, the
    ocdbt-style extension);
  * retention — keep the last ``keep`` checkpoints, delete older ones.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

Pytree = Any


def _flatten_with_paths(tree: Pytree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _to_savable(h: np.ndarray):
    """np.savez can't serialize ml_dtypes (bfloat16, f8): store the raw bits
    as uint8/16 and record the logical dtype in the manifest."""
    if h.dtype.kind == "V" or str(h.dtype) in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        bits = {2: np.uint16, 1: np.uint8}[h.dtype.itemsize]
        return h.view(bits), str(h.dtype)
    return h, str(h.dtype)


def save_pytree(path: str, tree: Pytree, *, step: int = 0,
                extra: Optional[Dict[str, Any]] = None) -> None:
    """Atomic synchronous save of a pytree of arrays."""
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten_with_paths(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    savable = [_to_savable(h) for h in host]
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{f"a{i}": h for i, (h, _) in enumerate(savable)})
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(host),
        "shapes": [list(h.shape) for h in host],
        "dtypes": [dt for _, dt in savable],
        "extra": extra or {},
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_pytree(path: str, like: Pytree, *, shardings: Optional[Pytree] = None
                ) -> Pytree:
    """Restore into the structure of ``like``; optionally device_put with
    per-leaf shardings (elastic reshard onto the current mesh)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten_with_paths(like)
    assert manifest["n_leaves"] == len(leaves), (
        f"checkpoint has {manifest['n_leaves']} leaves, "
        f"model expects {len(leaves)}")
    restored: List[Any] = []
    shard_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda x: hasattr(x, "device_set"))
        if shardings is not None else [None] * len(leaves))
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = data[f"a{i}"]
        saved_dt = manifest["dtypes"][i]
        if str(arr.dtype) != saved_dt:      # bit-stored ml_dtype: view back
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, saved_dt)))
        want = np.dtype(getattr(ref, "dtype", arr.dtype))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != model {ref.shape}")
        arr = arr.astype(want, copy=False)
        if sh is not None:
            restored.append(jax.device_put(arr, sh))
        else:
            restored.append(jax.device_put(arr))
    return jax.tree.unflatten(treedef, restored)


class CheckpointManager:
    """Step-indexed manager with retention + async writes."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def save(self, step: int, tree: Pytree, *, blocking: bool = True,
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        # snapshot to host synchronously (cheap vs a train step), write async
        leaves, treedef = _flatten_with_paths(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        host_tree = jax.tree.unflatten(treedef, host)

        def _write():
            save_pytree(self._step_dir(step), host_tree, step=step,
                        extra=extra)
            self._gc()

        if blocking:
            _write()
        else:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()

    def restore(self, like: Pytree, *, step: Optional[int] = None,
                shardings: Optional[Pytree] = None) -> Pytree:
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        return load_pytree(self._step_dir(step), like, shardings=shardings)

    def restore_extra(self, step: Optional[int] = None) -> Dict[str, Any]:
        if step is None:
            step = self.latest_step()
        with open(os.path.join(self._step_dir(step), "manifest.json")) as f:
            return json.load(f).get("extra", {})

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
