"""Learning-rate schedules (pure functions of an int32 step).

``wsd_schedule`` is the MiniCPM Warmup-Stable-Decay schedule — one of the
assigned architectures' own training recipes (arXiv:2404.06395): linear
warmup, a long constant plateau, then a short exponential-ish decay tail.
All schedules are jit-safe (branchless ``jnp.where`` selection).
"""

from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def f(step):
        return jnp.full((), lr, jnp.float32)
    return f


def cosine_schedule(lr: float, total_steps: int, warmup_steps: int = 0,
                    final_frac: float = 0.1):
    """Linear warmup then cosine decay to ``final_frac * lr``."""
    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = lr * s / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) /
                     jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, cos).astype(jnp.float32)
    return f


def wsd_schedule(lr: float, total_steps: int, warmup_steps: int = 0,
                 decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup-Stable-Decay (MiniCPM).  Stable at ``lr`` until the last
    ``decay_frac`` of training, then exponential decay to ``final_frac*lr``."""
    decay_steps = max(int(total_steps * decay_frac), 1)
    decay_start = total_steps - decay_steps

    def f(step):
        s = jnp.asarray(step, jnp.float32)
        warm = lr * s / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((s - decay_start) / decay_steps, 0.0, 1.0)
        decay = lr * jnp.power(final_frac, t)      # exp interp lr -> final
        out = jnp.where(s < warmup_steps, warm,
                        jnp.where(s < decay_start, lr, decay))
        return out.astype(jnp.float32)
    return f


def make_schedule(name: str, lr: float, total_steps: int,
                  warmup_steps: int = 0):
    if name == "constant":
        return constant_schedule(lr)
    if name == "cosine":
        return cosine_schedule(lr, total_steps, warmup_steps)
    if name == "wsd":
        return wsd_schedule(lr, total_steps, warmup_steps)
    raise ValueError(f"unknown schedule {name!r}")
