"""Gradient utilities: global-norm clipping and int8 gradient compression.

Compression is the distributed-optimization trick used by the cross-pod
data-parallel axis: gradients are quantized to int8 blocks with per-block f32
scales before the "pod" all-reduce (4x fewer inter-pod bytes), with an
error-feedback buffer so the quantization error is re-injected next step
(1-bit-Adam-style convergence guarantee).  Intra-pod reduce-scatters stay in
full precision — only the slow pod axis pays the quantization.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

BLOCK = 2048  # quantization block (elements) — per-block scale


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: Pytree, max_norm: float
                        ) -> Tuple[Pytree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm


def _pad_to_block(flat: jnp.ndarray) -> jnp.ndarray:
    n = flat.shape[0]
    pad = (-n) % BLOCK
    return jnp.pad(flat, (0, pad)) if pad else flat


def compress_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (any shape) -> (int8 codes [Nb, BLOCK], f32 scales [Nb]).

    Symmetric per-block quantization; exactly invertible metadata.
    """
    flat = _pad_to_block(x.astype(jnp.float32).reshape(-1))
    blocks = flat.reshape(-1, BLOCK)
    amax = jnp.max(jnp.abs(blocks), axis=1)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray, shape, dtype
                    ) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compress_with_feedback(grad: jnp.ndarray, error: jnp.ndarray
                           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Error-feedback compression: returns (codes, scales, new_error).

    new_error = (grad + error) - dequant(quant(grad + error)); callers carry
    it to the next step so the bias introduced by quantization is corrected.
    """
    corrected = grad.astype(jnp.float32) + error
    q, s = compress_int8(corrected)
    deq = decompress_int8(q, s, grad.shape, jnp.float32)
    return q, s, corrected - deq
