from repro.optim.optimizers import (
    Optimizer,
    adamw,
    adafactor,
    sgd_momentum,
    make_optimizer,
)
from repro.optim.schedules import (
    constant_schedule,
    cosine_schedule,
    wsd_schedule,
    make_schedule,
)
from repro.optim.grad_utils import (
    clip_by_global_norm,
    global_norm,
    compress_int8,
    decompress_int8,
)

__all__ = [
    "Optimizer", "adamw", "adafactor", "sgd_momentum", "make_optimizer",
    "constant_schedule", "cosine_schedule", "wsd_schedule", "make_schedule",
    "clip_by_global_norm", "global_norm", "compress_int8", "decompress_int8",
]
