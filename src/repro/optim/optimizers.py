"""Optimizers as (init, update) pairs over arbitrary param pytrees.

State dtype is configurable: ``state_dtype="bfloat16"`` halves optimizer
memory (used by the largest assigned MoE configs, where f32 Adam state would
not fit the 16 GB/chip budget at 256 chips — see launch/training_config.py).
``adafactor`` factors the second moment into row/col statistics for >=2D
params (Shazeer & Stern, 2018), cutting state to ~1 byte/param — the default
for arctic-480b.

Update rules are pure pytree maps, so the optimizer state inherits the
parameter sharding (FSDP x TP) with no extra code.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree, jnp.ndarray], Tuple[Pytree, Pytree]]
    # update(grads, opt_state, params, step) -> (new_params, new_opt_state)


def _cast(x, dtype):
    return x.astype(dtype) if dtype is not None else x


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(schedule: Schedule, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          state_dtype: str = "float32") -> Optimizer:
    sdt = jnp.dtype(state_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, sdt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        lr = schedule(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - jnp.power(b1, t)
        c2 = 1.0 - jnp.power(b2, t)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = mf / c1
            vhat = vf / c2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            if p.ndim >= 2:                      # decay matrices only
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step_
            return new_p.astype(p.dtype), mf.astype(sdt), vf.astype(sdt)

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda t3: t3[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t3: t3[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t3: t3[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer("adamw", init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; beta1 optional)
# ---------------------------------------------------------------------------

def adafactor(schedule: Schedule, *, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0,
              momentum_dtype: Optional[str] = None) -> Optimizer:
    """Factored Adam: for >=2D params the second moment is stored as row/col
    means (O(n+m) instead of O(nm)); <2D params keep a full ``v``.
    ``momentum_dtype`` enables optional first-moment accumulation."""
    mdt = jnp.dtype(momentum_dtype) if momentum_dtype else None

    def init(params):
        def v_init(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        st = {"v": jax.tree.map(v_init, params)}
        if mdt is not None:
            st["m"] = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
        return st

    def update(grads, state, params, step):
        lr = schedule(step)
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - jnp.power(t, -decay)

        def upd(g, v, p, m=None):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if p.ndim >= 2:
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of v
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                vhat = (vr[..., None] * vc[..., None, :]
                        / jnp.maximum(denom[..., None], eps))
                new_v = {"vr": vr, "vc": vc}
            else:
                vhat = beta2 * v["v"] + (1 - beta2) * g2
                new_v = {"v": vhat}
            u = gf / jnp.sqrt(jnp.maximum(vhat, eps))
            # relative update clipping (adafactor's d=1.0 rule)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if m is not None:
                mf = 0.9 * m.astype(jnp.float32) + 0.1 * u
                u, new_m = mf, mf.astype(mdt)
            else:
                new_m = None
            if p.ndim >= 2 and weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return new_p, new_v, new_m

        # tree.map over multiple trees with dict-leaves needs explicit zip:
        g_leaves, treedef = jax.tree.flatten(grads)
        v_leaves = treedef.flatten_up_to(state["v"])
        p_leaves = jax.tree.leaves(params)
        m_leaves = (jax.tree.leaves(state["m"]) if mdt is not None
                    else [None] * len(g_leaves))
        trip = [upd(g, v, p, m) for g, v, p, m
                in zip(g_leaves, v_leaves, p_leaves, m_leaves)]
        new_p = jax.tree.unflatten(treedef, [t3[0] for t3 in trip])
        new_v = jax.tree.unflatten(treedef, [t3[1] for t3 in trip])
        new_state = {"v": new_v}
        if mdt is not None:
            new_state["m"] = jax.tree.unflatten(treedef, [t3[2] for t3 in trip])
        return new_p, new_state

    return Optimizer("adafactor", init, update)


# ---------------------------------------------------------------------------
# SGD + momentum (baseline / tests)
# ---------------------------------------------------------------------------

def sgd_momentum(schedule: Schedule, *, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)}

    def update(grads, state, params, step):
        lr = schedule(step)

        def upd(g, m, p):
            mf = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * mf).astype(p.dtype), mf

        pairs = jax.tree.map(upd, grads, state["m"], params)
        new_p = jax.tree.map(lambda t2: t2[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t2: t2[1], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m}

    return Optimizer("sgd", init, update)


def make_optimizer(name: str, schedule: Schedule, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(schedule, **kw)
    if name == "adafactor":
        return adafactor(schedule, **kw)
    if name == "sgd":
        return sgd_momentum(schedule, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
