"""Activation-sharding policy: explicit with_sharding_constraint points.

GSPMD propagates parameter shardings to activations, but propagation alone
picks pathological layouts at scale (observed: the embedding gather output
left the batch axis replicated, turning every FFN activation into a
[B_global, T, ff/16] tensor — 1.6 GB/chip per instance).  Production JAX
frameworks (MaxText et al.) pin activation layouts at module boundaries;
we do the same through a process-global policy object so model code stays
mesh-agnostic and single-device smoke tests pay zero overhead (policy None
-> constraints are identity).

Constraint names used by the model code:
  "act_btd"   [B, T, d]        batch over (pod,data); d replicated
  "act_btf"   [B, T, ff]       batch over (pod,data); ff over model (TP)
  "act_bthd"  [B, T, H, dh]    batch over (pod,data); heads over model
  "logits"    [B, T, V]        batch over (pod,data); vocab over model
  "moe_ecd"   [E, C, d]        experts over model (EP), capacity over data
  "kv_cache"  [L, B, S, ...]   batch over data, S over model (decode)
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_POLICY: Optional["ShardingPolicy"] = None


@dataclass(frozen=True)
class ShardingPolicy:
    mesh: Mesh
    batch_axes: Tuple[str, ...] = ("data",)     # ("pod","data") multi-pod
    model_axis: str = "model"
    # decode-time override: shard the cache sequence axis over these axes
    seq_axes: Tuple[str, ...] = ("model",)
    # batch too small to shard (long_500k): batch axes become None
    shard_batch: bool = True
    # SEQUENCE-PARALLEL mode (§Perf prefill iteration): activations are
    # sharded over the token axis on ``model`` instead of TP on heads/ff;
    # weights are gathered per layer (FSDP-style) and attention all-gathers
    # K/V — replaces the per-layer [B,T,d] activation all-reduces of
    # Megatron TP (the dominant prefill collective) with far smaller
    # weight/KV all-gathers.
    seq_parallel: bool = False

    def _b(self):
        return self.batch_axes if self.shard_batch else None

    def spec(self, name: str) -> P:
        b = self._b()
        m = self.model_axis
        s = self.seq_axes if len(self.seq_axes) > 1 else self.seq_axes[0]
        if self.seq_parallel:
            table = {
                "act_btd": P(b, m, None),
                "act_btf": P(b, m, None),
                "act_bthd": P(b, m, None, None),
                "act_bd": P(b, None),
                "logits": P(b, m, None),
                "logits4": P(b, m, None, None),
                "kv_full": P(b, None, None, None),   # gathered K/V
                "moe_ecd": P(m, None, None),
                "moe_dsd": P(b, None, None),
                "kv_cache": P(None, b, s, None, None),
                "kv_cache_latent": P(None, b, s, None),
                "kv_bshd": P(b, s, None, None),
                "latent_bsr": P(b, s, None),
                "decode_scores": P(b, m, None, None),
            }
            return table[name]
        table = {
            "act_btd": P(b, None, None),
            "act_btf": P(b, None, m),
            "act_bthd": P(b, None, m, None),
            "act_bd": P(b, None),
            "logits": P(b, None, m),
            "moe_ecd": P(m, None, None),
            "moe_dsd": P(b, None, None),       # [D_shards, S_loc, d]
            "kv_cache": P(None, b, s, None, None),
            "kv_cache_latent": P(None, b, s, None),
            "kv_bshd": P(b, s, None, None),
            "latent_bsr": P(b, s, None),
            "logits4": P(b, None, None, m),
            "kv_full": P(b, None, None, None),
            "decode_scores": P(b, m, None, None),
        }
        return table[name]

    @property
    def sp_enabled(self) -> bool:
        return self.seq_parallel

    def _axis_size(self, axes) -> int:
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        if axes is None:
            return 1
        if isinstance(axes, str):
            return sizes[axes]
        n = 1
        for a in axes:
            n *= sizes[a]
        return n

    def constrain(self, x, name: str):
        spec = self.spec(name)
        # drop axes that do not divide the dim (e.g. 4 heads on a 16-way
        # model axis) — GSPMD would pad; replication is cheaper and exact.
        fixed = []
        for i, axes in enumerate(spec):
            if i >= x.ndim:
                break
            fixed.append(axes if x.shape[i] % self._axis_size(axes) == 0
                         else None)
        fixed += [None] * (x.ndim - len(fixed))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*fixed)))


def set_policy(p: Optional[ShardingPolicy]) -> None:
    global _POLICY
    _POLICY = p


def get_policy() -> Optional[ShardingPolicy]:
    return _POLICY


@contextlib.contextmanager
def sharding_policy(p: Optional[ShardingPolicy]):
    prev = get_policy()
    set_policy(p)
    try:
        yield p
    finally:
        set_policy(prev)


def constrain(x, name: str):
    """Pin activation ``x`` to the named layout (no-op without a policy)."""
    p = get_policy()
    if p is None:
        return x
    return p.constrain(x, name)


def replicate(x):
    """Force ``x`` fully replicated (no-op without a policy).  Used to pin
    weight all-gathers to the STORED dtype: without it GSPMD hoists the
    int8->f32 dequant (or bf16->f32 convert) above the gather and moves f32
    over the network (observed 2-4x collective inflation, §Perf B3)."""
    p = get_policy()
    if p is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(p.mesh, P(*([None] * x.ndim))))
