from repro.distributed.sharding import (
    param_pspecs,
    batch_pspec,
    cache_pspecs,
    train_state_pspecs,
    shardings_from_pspecs,
)

__all__ = [
    "param_pspecs", "batch_pspec", "cache_pspecs", "train_state_pspecs",
    "shardings_from_pspecs",
]
