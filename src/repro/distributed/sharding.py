"""Sharding rules: params / batches / caches -> PartitionSpec trees.

Strategy (the TPU mapping of HALO's two engines — see also
docs/serving.md §Strategy groups):

* Parameters use 2D sharding: the TP dimension (heads / d_ff / experts /
  d_inner) over the ``model`` axis and the other matrix dimension over the
  ``data`` axis (FSDP-style).  GSPMD all-gathers the ``data``-sharded factor
  just-in-time per layer, which keeps per-chip parameter state O(P/256) —
  required to fit arctic-480b's Adam state in 16 GB chips.

* Prefill activations: batch over (pod, data), heads/ff over ``model`` —
  the compute-bound GEMM phase (HALO's CiM side).

* Decode KV caches: the SEQUENCE axis of every cache is sharded over
  ``model`` (and the batch axis over ``data``) so each chip scans only its
  local cache slice — flash-decode semantics; this is the TPU analogue of
  HALO's in-bank CiD GEMV (each DRAM bank serves its own slice, partial
  softmax reduced across banks).  When the batch is too small to fill the
  data axis (long_500k: batch=1), the sequence is sharded over BOTH axes.

Rules are applied by leaf path, so they work for any config family without
model-specific code.  ``None`` in a spec means replicated on that dim.
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

Pytree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

# Each rule: (regex on leaf path, spec builder taking (ndim, fsdp_axis)).
# Specs are written for the UNSTACKED 2D weight; _apply pads leading None
# dims for scan-stacked / per-expert leading axes automatically by matching
# from the TRAILING dims.

def _w_in(nd, fsdp):          # [d_in, d_out_tp]: input FSDP, output TP
    return (fsdp, "model")


def _w_out(nd, fsdp):         # [d_in_tp, d_out]: input TP, output FSDP
    return ("model", fsdp)


def _moe_in(nd, fsdp):        # [E, d, ff]: experts EP, d FSDP, ff TP... but
    # E already consumes "model"; shard d over data only.
    return ("model", fsdp, None)


def _moe_out(nd, fsdp):       # [E, ff, d]
    return ("model", None, fsdp)


def _embed(nd, fsdp):         # [V, d]: vocab TP; d replicated — sharding d
    # over "data" would make the gather output d-sharded/batch-replicated
    # and GSPMD then drops batch parallelism everywhere downstream.
    return ("model", None)


def _lm_head(nd, fsdp):       # [d, V]: vocab TP (matches "logits" layout)
    return (None, "model")


def _vec_tp(nd, fsdp):        # [d_tp] vectors living in the TP'd dim
    return ("model",)


def _replicated(nd, fsdp):
    return ()


PARAM_RULES: List[Tuple[str, Any]] = [
    # --- MoE experts (before generic matchers; path contains 'moe/') -------
    (r"moe/wi_gate$", _moe_in),
    (r"moe/wi_up$", _moe_in),
    (r"moe/wo$", _moe_out),
    (r"moe/router$", lambda nd, f: (f, None)),       # [d, E] — E tiny
    (r"moe/(shared|dense)/wi_(gate|up)$", _w_in),
    (r"moe/(shared|dense)/wo$", _w_out),
    # --- attention ----------------------------------------------------------
    (r"attn/w(q|k|v)$", _w_in),
    (r"attn/wq_(a|b)$", _w_in),
    (r"attn/wkv_a$", lambda nd, f: (f, None)),       # latent r+dr is small
    (r"attn/w_u(k|v)$", lambda nd, f: ("model", None, None)),  # [H, r, n]
    (r"attn/wo$", _w_out),
    (r"attn/(q|k)_norm$", _replicated),
    # --- FFN ------------------------------------------------------------
    (r"ffn/wi_(gate|up)$", _w_in),
    (r"ffn/wo$", _w_out),
    # --- SSM --------------------------------------------------------------
    (r"ssm/in_proj$", _w_in),
    (r"ssm/out_proj$", _w_out),
    (r"ssm/conv_w$", lambda nd, f: (None, "model")),
    (r"ssm/conv_b$", _vec_tp),
    (r"ssm/(A_log|D|dt_bias)$", _replicated),
    (r"ssm/norm_scale$", _vec_tp),
    # --- shared attention block (zamba2) ------------------------------------
    (r"shared_attn/attn/w(q|k|v)$", _w_in),
    (r"shared_attn/attn/wo$", _w_out),
    (r"shared_attn/ffn/wi_(gate|up)$", _w_in),
    (r"shared_attn/ffn/wo$", _w_out),
    (r"shared_attn/down$", lambda nd, f: (f, None)),
    # --- embeddings / head ---------------------------------------------------
    (r"^embed$", _embed),
    (r"^lm_head$", _lm_head),
    # --- norms (catch-all 1D) -------------------------------------------------
    (r"(ln1|ln2|final_norm|q_norm|kv_norm)(/scale)?$", _replicated),
    (r"scale$", _replicated),
]


def _spec_for_leaf(path: str, ndim: int, fsdp: Optional[str]) -> P:
    # int8 weight-only-quantized leaves: ".../<w>/q" shards like the weight,
    # ".../<w>/scale" (one fewer dim) keeps only the output-dim sharding
    is_scale = False
    if path.endswith("/q"):
        path = path[:-2]
    elif path.endswith("/scale") and "norm" not in path:
        path, is_scale = path[:-6], True
    for pat, builder in PARAM_RULES:
        if re.search(pat, path):
            if is_scale:
                tail = builder(ndim + 1, fsdp)
                tail = tuple(tail[:-2]) + tuple(tail[-1:])  # drop K-dim axis
            else:
                tail = builder(ndim, fsdp)
            lead = (None,) * (ndim - len(tail))
            assert len(tail) <= ndim, (path, ndim, tail)
            return P(*(lead + tuple(tail)))
    return P()  # replicate anything unmatched (norms, scalars)


def param_pspecs(cfg: ModelConfig, *, fsdp_axis: Optional[str] = "data",
                 params_tree: Optional[Pytree] = None) -> Pytree:
    """PartitionSpec tree matching init_params(cfg) structure.

    ``fsdp_axis=None`` disables FSDP (params only TP-sharded over 'model') —
    used by the decode/serving path where weights are read-only and the
    ``data`` axis carries the request batch.
    """
    if params_tree is None:
        from repro.models.transformer import init_params
        params_tree = jax.eval_shape(
            lambda: init_params(jax.random.PRNGKey(0), cfg))

    def leaf_spec(path, leaf):
        return _spec_for_leaf(_path_str(path), len(leaf.shape), fsdp_axis)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_tree)


def phase_fsdp_axis(phase: str) -> Optional[str]:
    """FSDP axis for a disaggregated phase worker group's parameters.

    Prefill workers (CiM analogue) shard parameters over ``data`` too —
    their batched GEMMs amortize the just-in-time all-gather.  Decode
    workers (CiD analogue) keep weights TP-only/replicated on ``data``:
    the GEMV phase is latency-bound and re-gathering weights every
    one-token step would dominate it (the same convention the serving
    path has always used — see ``param_pspecs``)."""
    if phase not in ("prefill", "decode"):
        raise ValueError(f"phase={phase!r} (expected 'prefill' or 'decode')")
    return "data" if phase == "prefill" else None


def phase_param_pspecs(cfg: ModelConfig, phase: str, *,
                       params_tree: Optional[Pytree] = None) -> Pytree:
    """Parameter specs for one phase worker group of a disaggregated
    deployment (serving/executor.DisaggregatedExecutor)."""
    return param_pspecs(cfg, fsdp_axis=phase_fsdp_axis(phase),
                        params_tree=params_tree)


# ---------------------------------------------------------------------------
# batch / activation rules
# ---------------------------------------------------------------------------

def batch_pspec(mesh_axes: Tuple[str, ...], *, batch_size: int,
                mesh_shape: Dict[str, int]) -> Tuple:
    """Axes tuple for the batch dim: as many of (pod, data) as divide it."""
    axes = []
    div = 1
    for a in ("pod", "data"):
        if a in mesh_axes and batch_size % (div * mesh_shape[a]) == 0:
            axes.append(a)
            div *= mesh_shape[a]
    return tuple(axes) if axes else (None,)


def token_pspec(cfg: ModelConfig, mesh: Mesh, batch_size: int) -> P:
    """Spec for the tokens array [B, T] (or [B, K, T])."""
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    b = batch_pspec(mesh.axis_names, batch_size=batch_size, mesh_shape=shape)
    spec_b = tuple(b) if b != (None,) else None
    trailing = (None, None) if cfg.n_codebooks > 1 else (None,)
    return P(spec_b, *trailing)


# ---------------------------------------------------------------------------
# cache rules (decode)
# ---------------------------------------------------------------------------

def cache_pspecs(cfg: ModelConfig, mesh: Mesh, batch_size: int,
                 seq_shard_axes: Optional[Tuple[str, ...]] = None,
                 cache_tree: Optional[List[Any]] = None) -> List[Any]:
    """Spec tree matching init_cache(cfg, B, S).

    Cache layouts (leading L = scan-stacked layer axis):
      attn   k/v    [L, B, S, Hkv, Dh]   -> S over seq axes, B over data
             (+ int8 variant's k_scale/v_scale [L, B, S, Hkv])
      mla    latent [L, B, S, r+dr]
      ssm    conv   [L, B, K-1, C]       -> C (d_inner) over model
             state  [L, B, H, P, N]      -> H over model
      shared k/v    [B, S, H, Dh]

    ``cache_tree``: optional ShapeDtypeStruct tree (e.g. the quantized
    arena) — specs are generated per leaf by rank for attn runs.
    """
    from repro.models.transformer import build_plan

    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in data_ax:
        n_data *= shape[a]
    batch_sharded = batch_size % n_data == 0 and batch_size >= n_data
    if seq_shard_axes is None:
        if batch_sharded:
            seq_shard_axes = ("model",)
        else:
            # small batch (long_500k): sequence takes every axis
            seq_shard_axes = data_ax + ("model",)
    b_ax = data_ax if batch_sharded else None
    seq = seq_shard_axes if len(seq_shard_axes) > 1 else seq_shard_axes[0]

    specs: List[Any] = []
    for ri, run in enumerate(build_plan(cfg)):
        if run.kind == "attn" and cache_tree is not None:
            # rank-based: [L, B, S, ...] for any attn-cache leaf (covers the
            # int8 arena's value + scale tensors uniformly)
            piece = cache_tree[ri]
            specs.append(jax.tree.map(
                lambda leaf: P(*((None, b_ax, seq)
                                 + (None,) * (len(leaf.shape) - 3))),
                piece))
        elif run.kind == "attn" and cfg.mla.enabled:
            specs.append({"latent": P(None, b_ax, seq, None)})
        elif run.kind == "attn":
            kv = P(None, b_ax, seq, None, None)
            specs.append({"k": kv, "v": kv})
        elif run.kind == "ssm":
            specs.append({
                "conv": P(None, b_ax, None, "model"),
                "state": P(None, b_ax, "model", None, None),
            })
        else:  # shared_attn: [B, S, H, Dh]
            specs.append({"k": P(b_ax, seq, None, None),
                          "v": P(b_ax, seq, None, None)})
    return specs


# ---------------------------------------------------------------------------
# train state
# ---------------------------------------------------------------------------

def _map_like(spec_tree: Pytree, state_tree: Pytree) -> Pytree:
    """Broadcast param specs onto an optimizer-state tree that nests one
    extra level (e.g. adafactor's {"vr","vc"} per param)."""

    def expand(spec, sub):
        if isinstance(sub, dict):
            out = {}
            for k, v in sub.items():
                if k == "vr":      # row stats: drop last dim of the spec
                    out[k] = P(*spec[:-1]) if len(spec) else P()
                elif k == "vc":    # col stats: drop second-to-last dim
                    out[k] = (P(*(spec[:-2] + spec[-1:]))
                              if len(spec) >= 2 else spec)
                else:
                    out[k] = spec
            return out
        return spec

    return jax.tree.map(expand, spec_tree, state_tree,
                        is_leaf=lambda x: isinstance(x, P))


def train_state_pspecs(cfg: ModelConfig, *, fsdp_axis="data",
                       opt_state_tree: Optional[Pytree] = None,
                       params_tree: Optional[Pytree] = None) -> Dict[str, Any]:
    """Specs for {params, opt_state, step}: optimizer moments inherit the
    parameter sharding (m/v same shape; adafactor factored stats mapped)."""
    pspec = param_pspecs(cfg, fsdp_axis=fsdp_axis, params_tree=params_tree)
    out: Dict[str, Any] = {"params": pspec, "step": P()}
    if opt_state_tree is not None:
        opt_spec = {}
        for k, sub in opt_state_tree.items():
            if k in ("m",):
                opt_spec[k] = pspec
            else:  # "v": may be full (adamw) or factored dicts (adafactor)
                dictish = jax.tree.leaves(
                    sub, is_leaf=lambda y: isinstance(y, dict))
                factored = any(isinstance(x, dict) for x in dictish)
                opt_spec[k] = _map_like(pspec, sub) if factored else pspec
        out["opt_state"] = opt_spec
    return out


def shardings_from_pspecs(mesh: Mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
