"""Mixture-of-Experts FFN (sort-based dispatch with static capacity).

Dispatch is MaxText-style sparse routing rather than GShard one-hot einsum:
token->expert assignments are sorted, packed into a static [E, C, d] buffer
(gather/scatter, NO S x E x C dispatch tensor), run through a batched expert
GEMM, and unsorted.  FLOP cost is therefore ~top_k * capacity_factor * active
FLOPs, which keeps the roofline's MODEL_FLOPS/HLO_FLOPS ratio honest.

Supports: shared (always-on) experts fused into one wide FFN (DeepSeek-V2),
a parallel dense-residual FFN (Arctic), and a switch-style load-balance aux
loss.  The expert axis E is sharded over the 'model' mesh axis (EP); GSPMD
inserts the all-to-all around the pack/unpack gathers.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import _act, dense_init, ffn, ffn_init


def moe_init(key, d_model: int, m: MoEConfig, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    E, ff = m.n_experts, m.d_ff_expert
    scale = 1.0 / math.sqrt(d_model)
    p: Dict[str, Any] = {
        "router": dense_init(ks[0], d_model, E, jnp.float32, scale=0.02),
        "wi_gate": (jax.random.truncated_normal(ks[1], -3, 3, (E, d_model, ff),
                                                jnp.float32) * scale).astype(dtype),
        "wi_up": (jax.random.truncated_normal(ks[2], -3, 3, (E, d_model, ff),
                                              jnp.float32) * scale).astype(dtype),
        "wo": (jax.random.truncated_normal(ks[3], -3, 3, (E, ff, d_model),
                                           jnp.float32)
               / math.sqrt(ff)).astype(dtype),
    }
    if m.n_shared_experts > 0:
        p["shared"] = ffn_init(ks[4], d_model, m.n_shared_experts * ff, dtype)
    if m.dense_residual:
        p["dense"] = ffn_init(ks[5], d_model, m.d_ff_dense, dtype)
    return p


def _route(params, x2d, m: MoEConfig):
    """Router: softmax over experts then top-k (DeepSeek-V2 convention)."""
    logits = jnp.einsum("sd,de->se", x2d.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                     # [S,E]
    weights, idx = jax.lax.top_k(probs, m.top_k)                # [S,K]
    return probs, weights, idx


def _aux_loss(probs, idx, E: int):
    """Switch-transformer load-balance loss (f32 scalar)."""
    S = probs.shape[0]
    counts = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(counts.sum(), 1.0)
    frac_probs = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac_tokens * frac_probs)


def _capacity(S: int, K: int, E: int, cf: float) -> int:
    """Static per-expert capacity.  A single expert can receive at most S
    tokens (each token lists an expert once), so C = S is DROPLESS; small
    token counts (decode steps, tiny smoke batches) use it outright —
    dropping a decode token would silently corrupt generation."""
    if S <= 256:
        return S
    C = int(math.ceil(S * K / E * cf))
    C = max(8, -(-C // 8) * 8)                                  # round up to 8
    return min(C, S)


def _dispatch_ffn(params, x2d, weights, idx, m: MoEConfig, act: str, C: int):
    """Sort-based pack -> expert GEMM -> unpack, over ONE token shard.

    x2d [S, d]; weights/idx [S, K].  Returns out2d [S, d].  When vmapped
    over a leading data-shard axis, every gather/sort/scatter here is
    shard-LOCAL — the global version lowered to 120 GB cross-shard gathers
    and all-reduces under GSPMD (EXPERIMENTS.md §Perf, deepseek train).
    """
    S, d = x2d.shape
    K, E = m.top_k, m.n_experts
    flat_e = idx.reshape(S * K)                                 # [SK]
    order = jnp.argsort(flat_e)                                 # stable
    sorted_e = flat_e[order]
    sorted_tok = order // K                                     # source token
    # position within expert = rank - first_rank_of_expert
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    pos_in_e = (jnp.arange(S * K, dtype=jnp.int32)
                - starts[sorted_e].astype(jnp.int32))
    keep = pos_in_e < C

    # ---- pack into [E, C, d] ------------------------------------------------
    from repro.distributed.policy import constrain
    buf = jnp.zeros((E, C, d), x2d.dtype)
    safe_pos = jnp.where(keep, pos_in_e, C - 1)
    src = x2d[sorted_tok]                                       # gather [SK, d]
    src = jnp.where(keep[:, None], src, 0)
    buf = buf.at[sorted_e, safe_pos].add(src, mode="drop")
    # EP layout pin — works under vmap (the data-shard batch dim is inserted
    # unconstrained); without it multi-pod propagation re-replicates the
    # buffer across the pod axis (observed 3.3x collective inflation)
    buf = constrain(buf, "moe_ecd")

    # ---- batched expert FFN (the EP GEMM) -----------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"],
                   preferred_element_type=jnp.float32)
    h = (_act(g, act) * u).astype(x2d.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo"],
                         preferred_element_type=jnp.float32).astype(x2d.dtype)

    # ---- unpack + weighted combine ------------------------------------------
    gathered = out_buf[sorted_e, safe_pos]                      # [SK, d]
    gathered = jnp.where(keep[:, None], gathered, 0)
    w_sorted = weights.reshape(S * K)[order].astype(x2d.dtype)
    contrib = gathered * w_sorted[:, None]
    return jnp.zeros((S, d), x2d.dtype).at[sorted_tok].add(contrib)


def _dispatch_shards(x2d) -> int:
    """Number of token shards for the local-dispatch path: the data-axis
    size of the active sharding policy (1 = global dispatch)."""
    from repro.distributed.policy import get_policy
    p = get_policy()
    if p is None or not p.shard_batch:
        return 1
    n = p._axis_size(p.batch_axes)
    S = x2d.shape[0]
    if n > 1 and S % n == 0 and S // n >= 8:
        return n
    return 1


def moe_apply(params, x, m: MoEConfig, act: str = "silu",
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, T, d] -> (out [B, T, d], aux_loss scalar).

    Under a sharding policy, dispatch runs PER DATA SHARD (vmapped): the
    sort/pack/unpack stays local to each shard, the packed buffer is laid
    out [D, E, C_loc, d] -> P(data, model EP, -, -), and only the expert
    GEMMs touch the network (FSDP weight all-gathers).  Local capacity is
    C_loc = capacity(S/D), i.e. standard local-capacity MoE semantics.
    """
    from repro.distributed.policy import constrain

    Bsz, T, d = x.shape
    S = Bsz * T
    K, E = m.top_k, m.n_experts
    x2d = x.reshape(S, d)
    probs, weights, idx = _route(params, x2d, m)
    aux = _aux_loss(probs, idx, E)

    D = _dispatch_shards(x2d)
    if D == 1:
        C = _capacity(S, K, E, m.capacity_factor)
        buf_fn = lambda xs, ws, ix: _dispatch_ffn(params, xs, ws, ix, m,
                                                  act, C)
        out2d = buf_fn(x2d, weights, idx)
    else:
        S_loc = S // D
        C = _capacity(S_loc, K, E, m.capacity_factor)
        xs = constrain(x2d.reshape(D, S_loc, d), "moe_dsd")
        ws = weights.reshape(D, S_loc, K)
        ix = idx.reshape(D, S_loc, K)
        out2d = jax.vmap(
            lambda a, b, c: _dispatch_ffn(params, a, b, c, m, act, C)
        )(xs, ws, ix)
        out2d = constrain(out2d, "moe_dsd").reshape(S, d)

    # ---- always-on paths -----------------------------------------------------
    if "shared" in params:
        out2d = out2d + ffn(params["shared"], x2d, act)
    if "dense" in params:
        out2d = out2d + ffn(params["dense"], x2d, act)
    return out2d.reshape(Bsz, T, d), aux


def moe_apply_reference(params, x, m: MoEConfig, act: str = "silu"):
    """Dense oracle: loop over experts, no capacity drops.  Test-only."""
    Bsz, T, d = x.shape
    x2d = x.reshape(Bsz * T, d)
    probs, weights, idx = _route(params, x2d, m)
    out = jnp.zeros_like(x2d, dtype=jnp.float32)
    for e in range(m.n_experts):
        sel = (idx == e).astype(jnp.float32) * weights          # [S,K]
        w_e = sel.sum(-1)                                       # [S]
        g = x2d @ params["wi_gate"][e]
        u = x2d @ params["wi_up"][e]
        h = (_act(g.astype(jnp.float32), act) * u.astype(jnp.float32))
        y = h.astype(x.dtype) @ params["wo"][e]
        out = out + y.astype(jnp.float32) * w_e[:, None]
    out = out.astype(x.dtype)
    if "shared" in params:
        out = out + ffn(params["shared"], x2d, act)
    if "dense" in params:
        out = out + ffn(params["dense"], x2d, act)
    return out.reshape(Bsz, T, d), _aux_loss(probs, idx, m.n_experts)
