from repro.models.transformer import (
    build_plan,
    cache_specs,
    decode_step,
    forward,
    forward_train,
    init_cache,
    init_params,
    lm_logits,
    padded_vocab,
    prefill,
)

__all__ = [
    "build_plan", "cache_specs", "decode_step", "forward", "forward_train",
    "init_cache", "init_params", "lm_logits", "padded_vocab", "prefill",
]
