"""Mamba-2 (SSD — state-space duality) block.

Training / prefill uses the chunked SSD algorithm (matmul-dominated — the
compute-bound phase HALO maps to CiM); decode uses the O(1)-per-token
recurrent state update (pure elementwise/GEMV — HALO's CiD phase).  The
recurrent state [B, H, P, N] replaces the KV cache and is constant in
sequence length, which is why the SSM archs run the long_500k shape.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init, matmul

Params = Dict[str, Any]


def ssm_init(key, d_model: int, s: SSMConfig, dtype) -> Params:
    di = s.d_inner(d_model)
    nh = s.n_heads(d_model)
    conv_dim = di + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 6)
    in_dim = 2 * di + 2 * s.n_groups * s.d_state + nh          # z, x, B, C, dt
    p: Params = {
        "in_proj": dense_init(ks[0], d_model, in_dim, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        # A in (-exp) parameterization, init in [1, 16] like the reference
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[3], di, d_model, dtype),
    }
    return p


def _split_proj(proj, d_model: int, s: SSMConfig):
    di = s.d_inner(d_model)
    gn = s.n_groups * s.d_state
    z = proj[..., :di]
    x = proj[..., di: 2 * di]
    Bm = proj[..., 2 * di: 2 * di + gn]
    Cm = proj[..., 2 * di + gn: 2 * di + 2 * gn]
    dt = proj[..., 2 * di + 2 * gn:]
    return z, x, Bm, Cm, dt


def _gated_out(params, y, z, eps=1e-5):
    dt = y.dtype
    g = y * jax.nn.silu(z.astype(jnp.float32)).astype(dt)
    gf = g.astype(jnp.float32)
    var = jnp.mean(gf * gf, axis=-1, keepdims=True)
    gf = gf * jax.lax.rsqrt(var + eps) * params["norm_scale"].astype(jnp.float32)
    return matmul(gf.astype(dt), params["out_proj"])


def _causal_conv(xbc, conv_w, conv_b, d_conv: int):
    """Depthwise causal conv along T.  xbc: [B,T,C]; conv_w: [K,C]."""
    pad = jnp.pad(xbc, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    T = xbc.shape[1]
    for k in range(d_conv):                                     # K=4: unrolled
        out = out + pad[:, k: k + T].astype(jnp.float32) * conv_w[k].astype(jnp.float32)
    out = out + conv_b.astype(jnp.float32)
    return jax.nn.silu(out).astype(xbc.dtype)


# ---------------------------------------------------------------------------
# chunked SSD (train / prefill)
# ---------------------------------------------------------------------------

def _segsum(dA):
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} dA[..., k] (j<i)."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]                  # [..., i, j]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int, initial_state=None):
    """Chunked SSD.  x:[B,T,H,P] dt:[B,T,H] A:[H] Bm/Cm:[B,T,G,N] D:[H].

    Returns (y [B,T,H,P], final_state [B,H,P,N]).
    """
    Bsz, T, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert T % chunk == 0, (T, chunk)
    nc = T // chunk
    rep = H // G

    dtf = dt.astype(jnp.float32)
    dA = dtf * A[None, None, :]                                 # [B,T,H]
    xb = (x.astype(jnp.float32) * dtf[..., None])               # dt-weighted input

    # chunked views
    xc = xb.reshape(Bsz, nc, chunk, H, P)
    dAc = dA.reshape(Bsz, nc, chunk, H)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, chunk, G, N)

    dA_cs = jnp.cumsum(dAc, axis=2)                             # [B,nc,Q,H]
    seg = _segsum(dAc.transpose(0, 1, 3, 2))                    # [B,nc,H,Q,Q]
    L = jnp.exp(seg)

    # intra-chunk (diagonal blocks): GEMMs
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)               # [B,nc,G,Q,Q]
    CB = jnp.repeat(CB, rep, axis=2)                            # [B,nc,H,Q,Q]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", CB * L, xc)

    # per-chunk end states
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)         # [B,nc,Q,H]
    Brep = jnp.repeat(Bc, rep, axis=3)                          # [B,nc,Q,H,N]
    S_chunk = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Brep, decay_to_end, xc)

    # inter-chunk recurrence over nc states
    chunk_decay = jnp.exp(jnp.sum(dAc, axis=2))                 # [B,nc,H]

    def step(state, inp):
        s_c, dec = inp                                          # [B,H,P,N], [B,H]
        prev = state
        state = state * dec[..., None, None] + s_c
        return state, prev

    init = (jnp.zeros((Bsz, H, P, N), jnp.float32)
            if initial_state is None else initial_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        step, init, (S_chunk.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                    # [B,nc,H,P,N]

    # inter-chunk contribution
    state_decay = jnp.exp(dA_cs)                                # [B,nc,Q,H]
    Crep = jnp.repeat(Cc, rep, axis=3)                          # [B,nc,Q,H,N]
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Crep, prev_states, state_decay)

    y = (y_diag + y_off).reshape(Bsz, T, H, P)
    y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y, final_state


# ---------------------------------------------------------------------------
# block-level apply
# ---------------------------------------------------------------------------

def ssm_prefill(params, h, d_model: int, s: SSMConfig, pad_mask=None):
    """Full-sequence SSD block.  h: [B,T,d_model] -> (out, (conv_state, ssm_state)).

    ``pad_mask`` [B,T] (True = real token): pad positions contribute no state
    update (their dt and x are zeroed, so exp(dt*A)=1 passes state through).
    """
    Bsz, T, _ = h.shape
    di = s.d_inner(d_model)
    nh = s.n_heads(d_model)
    proj = matmul(h, params["in_proj"])
    z, x, Bm, Cm, dt = _split_proj(proj, d_model, s)
    if pad_mask is not None:
        pm = pad_mask[..., None].astype(x.dtype)
        x = x * pm
        dt = dt * pm
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)
    conv_state = xbc[:, -(s.d_conv - 1):, :] if T >= s.d_conv - 1 else \
        jnp.pad(xbc, ((0, 0), (s.d_conv - 1 - T, 0), (0, 0)))
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"], s.d_conv)
    gn = s.n_groups * s.d_state
    from repro.distributed.policy import constrain
    xbc = constrain(xbc, "act_btf")
    x = xbc[..., :di].reshape(Bsz, T, nh, s.head_dim)
    x = constrain(x, "act_bthd")
    Bm = xbc[..., di: di + gn].reshape(Bsz, T, s.n_groups, s.d_state)
    Cm = xbc[..., di + gn:].reshape(Bsz, T, s.n_groups, s.d_state)
    A = -jnp.exp(params["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    y, state = ssd_chunked(x, dt, A, Bm, Cm, params["D"],
                           min(s.chunk_size, T))
    y = y.reshape(Bsz, T, di).astype(h.dtype)
    out = _gated_out(params, y, z)
    return out, (conv_state, state.astype(jnp.float32))


def ssm_decode(params, h, conv_state, ssm_state, d_model: int, s: SSMConfig):
    """Single-token recurrent update.

    h: [B,1,d_model]; conv_state: [B, d_conv-1, conv_dim];
    ssm_state: [B,H,P,N].  Returns (out, new_conv_state, new_ssm_state).
    """
    Bsz = h.shape[0]
    di = s.d_inner(d_model)
    nh = s.n_heads(d_model)
    gn = s.n_groups * s.d_state
    proj = matmul(h, params["in_proj"])[:, 0]                   # [B, in_dim]
    z, x, Bm, Cm, dt = _split_proj(proj, d_model, s)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)                 # [B, conv_dim]
    # causal conv via the rolling state
    window = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # [B,K,C]
    new_conv_state = window[:, 1:, :]
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    x = conv_out[:, :di].reshape(Bsz, nh, s.head_dim)
    Bv = conv_out[:, di: di + gn].reshape(Bsz, s.n_groups, s.d_state)
    Cv = conv_out[:, di + gn:].reshape(Bsz, s.n_groups, s.d_state)
    rep = nh // s.n_groups
    Bv = jnp.repeat(Bv, rep, axis=1)                            # [B,H,N]
    Cv = jnp.repeat(Cv, rep, axis=1)
    A = -jnp.exp(params["A_log"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # [B,H]
    dA = jnp.exp(dt * A[None, :])                               # [B,H]
    # state update: s = s*dA + dt * x ⊗ B   (elementwise + outer product)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, x, Bv)
    new_state = ssm_state * dA[..., None, None] + upd
    # y = C · s + D * x     (GEMV over N)
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Cv)
    y = y + x * params["D"][None, :, None]
    y = y.reshape(Bsz, 1, di).astype(h.dtype)
    out = _gated_out(params, y, z[:, None, :])
    return out, new_conv_state, new_state
