"""Attention: GQA with qk-norm / sliding-window / local-global variants.

Two execution paths, mirroring HALO's phase split:

* ``attn_prefill`` — compute-bound GEMM path.  For long sequences it uses a
  blockwise (flash-style, online-softmax) pure-JAX implementation so the
  lowered HLO has O(T * block) live memory instead of O(T^2).  On TPU the
  Pallas flash kernel (kernels/flash_attention.py) implements the same
  algorithm with explicit VMEM tiling.

* ``attn_decode`` — memory-bound GEMV path.  One new token attends to a KV
  cache of S entries.  The cache is laid out [B, S, Hkv, Dh] so that S can be
  sequence-sharded across the ``model`` mesh axis (the TPU analogue of HALO's
  bank-level CiD GEMV: every shard scans its local slice of the cache and the
  softmax is reconstructed with tiny cross-shard reductions).

Sliding-window layers use a ring-buffer cache of length min(W, S): keys are
stored with RoPE already applied at their absolute position, so the ring
order does not matter; validity masking only needs the current position.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import (
    apply_rope,
    dense_init,
    head_rmsnorm,
    matmul,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int, d_head: int,
              dtype, qk_norm: bool = False, d_model_out: Optional[int] = None):
    d_out = d_model_out or d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * d_head, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * d_head, dtype),
        "wo": dense_init(ks[3], n_heads * d_head, d_out, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((d_head,), dtype)
        p["k_norm"] = jnp.ones((d_head,), dtype)
    return p


# ---------------------------------------------------------------------------
# qkv projection (shared between phases)
# ---------------------------------------------------------------------------

def _project_qkv(params, x, n_heads, n_kv_heads, d_head, positions, theta,
                 qk_norm: bool):
    from repro.distributed.policy import constrain
    B = x.shape[0]
    T = x.shape[1]
    q = matmul(x, params["wq"]).reshape(B, T, n_heads, d_head)
    k = matmul(x, params["wk"]).reshape(B, T, n_kv_heads, d_head)
    v = matmul(x, params["wv"]).reshape(B, T, n_kv_heads, d_head)
    from repro.distributed.policy import get_policy
    pol = get_policy()
    q = constrain(q, "act_bthd")
    if pol is not None and pol.sp_enabled and T > 1:
        # sequence-parallel prefill: gather K/V across the token shards
        # (0.27 GB/layer vs the 4.3 GB/layer of f32 activation all-reduces
        # that Megatron-style TP costs at 32k context — §Perf iteration 2)
        k = constrain(k, "kv_full")
        v = constrain(v, "kv_full")
    elif n_kv_heads > 1:      # kv=1 (gemma3) cannot shard the head axis
        k = constrain(k, "act_bthd")
        v = constrain(v, "act_bthd")
    if qk_norm:
        q = head_rmsnorm(params["q_norm"], q)
        k = head_rmsnorm(params["k_norm"], k)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def _maybe_softcap(scores, softcap: float):
    if softcap and softcap > 0.0:
        return softcap * jnp.tanh(scores / softcap)
    return scores


# ---------------------------------------------------------------------------
# prefill / train
# ---------------------------------------------------------------------------

def _dense_attention(q, k, v, positions, kv_positions, window, softcap,
                     pad_mask=None):
    """Reference masked attention.  q:[B,Tq,H,D] k,v:[B,Tk,Hkv,D]."""
    B, Tq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Tq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(D)
    scores = _maybe_softcap(scores, softcap)
    # causal + window mask.  window is traced (per-layer); 0 means "full".
    pq = positions[:, :, None]                       # [B,Tq,1]
    pk = kv_positions[:, None, :]                    # [B,1,Tk]
    causal = pk <= pq
    w = jnp.where(window > 0, window, jnp.iinfo(jnp.int32).max)
    in_window = (pq - pk) < w
    valid = causal & in_window
    if pad_mask is not None:
        valid = valid & pad_mask[:, None, :]
    mask = valid[:, None, None]                      # [B,1,1,Tq,Tk]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Tq, H, D).astype(q.dtype)


def _blockwise_attention(q, k, v, positions, window, softcap,
                         block_q: int = 512, block_kv: int = 1024,
                         pad_mask=None):
    """Flash-style online-softmax attention; O(T*block) live memory.

    Causal masking is applied at block granularity through the score mask;
    the FLOP count still includes upper-triangle blocks (see EXPERIMENTS.md
    §Perf for the triangular-schedule optimization that removes them).
    """
    B, T, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    nq = T // block_q
    nk = T // block_kv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, nq, block_q, Hkv, G, D)
    kb = k.reshape(B, nk, block_kv, Hkv, D)
    vb = v.reshape(B, nk, block_kv, Hkv, D)
    pos_q = positions.reshape(B, nq, block_q)
    pos_k = positions.reshape(B, nk, block_kv)
    if pad_mask is None:
        pad_mask = jnp.ones((B, T), jnp.bool_)
    pm_k = pad_mask.reshape(B, nk, block_kv)
    w = jnp.where(window > 0, window, jnp.iinfo(jnp.int32).max)

    @partial(jax.checkpoint,
             policy=jax.checkpoint_policies.nothing_saveable)
    def q_block_inner(qblk, pq):
        """One query block vs all kv blocks.  Rematerialized in backward so
        the per-(q,kv)-block probabilities are never stacked across blocks
        (flash-attention memory discipline, pure-JAX edition)."""
        m0 = jnp.full((B, Hkv, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, block_q, D), jnp.float32)

        def kv_block(acc, ki):
            m, l, a = acc
            kblk, vblk, pk, pmk = ki
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = _maybe_softcap(s, softcap)
            causal = pk[:, None, None, None, :] <= pq[:, None, None, :, None]
            in_w = (pq[:, None, None, :, None] - pk[:, None, None, None, :]) < w
            ok = causal & in_w & pmk[:, None, None, None, :]
            s = jnp.where(ok, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            a_new = a * corr[..., None] + pv
            return (m_new, l_new, a_new), None

        (m, l, a), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), pos_k.swapaxes(0, 1),
             pm_k.swapaxes(0, 1)))
        out = a / jnp.maximum(l[..., None], 1e-30)           # [B,Hkv,G,bq,D]
        out = out.transpose(0, 3, 1, 2, 4)                   # [B,bq,Hkv,G,D]
        return out.astype(qblk.dtype)

    from repro.distributed.policy import get_policy
    pol = get_policy()
    if pol is not None and pol.sp_enabled:
        # SEQUENCE-PARALLEL: q blocks are sharded over 'model'; a scan would
        # serialize them globally (GSPMD slices the scan axis), so the block
        # loop becomes a vmap — batched ops with a sharded leading dim stay
        # shard-local.  K/V were all-gathered by the caller.
        outs = jax.vmap(q_block_inner)(
            qg.swapaxes(0, 1), pos_q.swapaxes(0, 1))         # [nq,B,bq,...]
    else:
        def q_block(carry, qi):
            qblk, pq = qi                                    # [B,bq,Hkv,G,D]
            return carry, q_block_inner(qblk, pq)

        _, outs = jax.lax.scan(
            q_block, None,
            (qg.swapaxes(0, 1), pos_q.swapaxes(0, 1)))       # [nq,B,bq,...]
    out = outs.swapaxes(0, 1).reshape(B, T, H, D)
    return out


def _can_use_pallas_flash(q, softcap, pad_mask, positions) -> bool:
    """The Pallas kernel path: TPU backend, no softcap/padding, contiguous
    positions (the kernel masks by absolute block indices)."""
    import jax as _jax
    if _jax.default_backend() != "tpu":
        return False
    if softcap or pad_mask is not None:
        return False
    T = q.shape[1]
    return T % 512 == 0


def attn_prefill(params, x, positions, *, n_heads, n_kv_heads, d_head,
                 theta, window, softcap=0.0, qk_norm=False,
                 dense_threshold: int = 2048, pad_mask=None):
    """Full-sequence attention.  Returns [B, T, d_model_out] and (k, v) for
    cache initialization.  ``pad_mask`` [B,T] marks valid (non-pad) keys.

    Dispatch: small T -> dense reference; long T on TPU -> the Pallas flash
    kernel (kernels/flash_attention.py: triangular tile schedule, VMEM-
    resident probs); otherwise the pure-JAX blockwise path (same online-
    softmax algorithm — the CPU/dry-run stand-in the §Roofline kernel-region
    discount maps back onto the kernel).
    """
    B, T, _ = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           positions, theta, qk_norm)
    if T <= dense_threshold:
        out = _dense_attention(q, k, v, positions, positions, window, softcap,
                               pad_mask=pad_mask)
    else:
        use_kernel = _can_use_pallas_flash(q, softcap, pad_mask, positions)
        w = None
        if use_kernel:
            try:
                w = int(window)        # concrete at trace time (per-run)
            except Exception:
                use_kernel = False
        if use_kernel:
            from repro.kernels import ops as _kops
            out = _kops.flash_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=True,
                window=w).transpose(0, 2, 1, 3)
        else:
            out = _blockwise_attention(q, k, v, positions, window, softcap,
                                       pad_mask=pad_mask)
    out = matmul(out.reshape(B, T, n_heads * d_head), params["wo"])
    return out, (k, v)


# ---------------------------------------------------------------------------
# chunked prefill (arena-direct)
# ---------------------------------------------------------------------------

def attn_chunk(params, x, offsets, lengths, slots, cache_k, cache_v, *,
               n_heads, n_kv_heads, d_head, theta, window, softcap=0.0,
               qk_norm=False):
    """Chunked prefill against the decode arena (HALO's CiM -> CiD handoff).

    x: [N, C, d] — N packed chunk rows of up to C tokens; row ``n`` carries
    tokens ``[offsets[n], offsets[n]+lengths[n])`` of the request living in
    arena slot ``slots[n]``.  cache_k/v: [B, R, Hkv, Dh] decode arena
    (R = min(window, S) ring for sliding-window runs, R = S otherwise).

    Entries at positions < offsets[n] were written by earlier chunks of the
    same request; the chunk attends over that history + itself (causal,
    windowed) and then writes its own K/V into the arena.  History is
    gathered BEFORE the write: a ring entry the chunk is about to overwrite
    is still needed by the chunk's early queries.  Padded rows
    (slots[n] >= B) and padded positions (j >= lengths[n]) scatter out of
    bounds and are dropped.

    Returns (out [N, C, d_model], new_cache_k, new_cache_v).
    """
    N, C, _ = x.shape
    B, R = cache_k.shape[0], cache_k.shape[1]
    offs = jnp.asarray(offsets, jnp.int32)
    lens = jnp.asarray(lengths, jnp.int32)
    slot = jnp.asarray(slots, jnp.int32)
    j = jnp.arange(C, dtype=jnp.int32)
    positions = offs[:, None] + j[None, :]                       # [N, C]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           positions, theta, qk_norm)

    row = jnp.clip(slot, 0, B - 1)
    prev_k = cache_k[row]                                        # [N, R, ...]
    prev_v = cache_v[row]
    s_idx = jnp.arange(R, dtype=jnp.int32)
    # ring slot s holds the largest position p < off with p % R == s
    # (for full-attention runs R == S, so this reduces to p == s when
    # s < off and "not yet written" otherwise — one formula for both)
    prev_pos = offs[:, None] - 1 - ((offs[:, None] - 1 - s_idx[None, :]) % R)
    chunk_pos = jnp.where(j[None, :] < lens[:, None], positions, -1)
    kv_k = jnp.concatenate([prev_k, k], axis=1)                  # [N, R+C, ...]
    kv_v = jnp.concatenate([prev_v, v], axis=1)
    kv_pos = jnp.concatenate([prev_pos, chunk_pos], axis=1)      # [N, R+C]

    Hkv = n_kv_heads
    G = n_heads // Hkv
    qg = q.reshape(N, C, Hkv, G, d_head)
    scores = jnp.einsum("nqhgd,nkhd->nhgqk", qg, kv_k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(d_head)
    scores = _maybe_softcap(scores, softcap)
    pq = positions[:, :, None]                                   # [N, C, 1]
    pk = kv_pos[:, None, :]                                      # [N, 1, R+C]
    w = jnp.where(window > 0, window, jnp.iinfo(jnp.int32).max)
    valid = (pk >= 0) & (pk <= pq) & ((pq - pk) < w)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("nhgqk,nkhd->nqhgd", probs.astype(kv_v.dtype), kv_v,
                     preferred_element_type=jnp.float32)
    ctx = ctx.reshape(N, C, n_heads * d_head).astype(x.dtype)
    out = matmul(ctx, params["wo"])

    # arena write: ring discipline keeps only the row's last R positions
    # (earlier chunk positions a later same-chunk token wraps onto must
    # not be scattered — duplicate scatter indices are order-undefined)
    keep = (j[None, :] < lens[:, None]) & (j[None, :] >= lens[:, None] - R)
    w_slot = jnp.where(keep, jnp.broadcast_to(slot[:, None], (N, C)), B)
    w_idx = jnp.where(keep, positions % R, R)
    new_k = cache_k.at[w_slot, w_idx].set(k, mode="drop")
    new_v = cache_v.at[w_slot, w_idx].set(v, mode="drop")
    return out, new_k, new_v


# ---------------------------------------------------------------------------
# chunked prefill (paged pool)
# ---------------------------------------------------------------------------

def attn_chunk_paged(params, x, offsets, lengths, slots, cache, block_table,
                     *, n_heads, n_kv_heads, d_head, theta, window,
                     softcap=0.0, qk_norm=False):
    """Chunked prefill writing K/V into the paged block pool.

    Same packing contract as ``attn_chunk`` (x: [N, C, d], row ``n`` holds
    tokens [offsets[n], offsets[n]+lengths[n]) of the request in slot
    ``slots[n]``) but the arena is a pool ``cache = {"k","v"[, scales]}``
    of shape [n_pages, P, Hkv, Dh] addressed via ``block_table`` [B, W]:
    position ``pos`` of a slot lives at page ``bt[slot, (pos % R) // P]``
    offset ``(pos % R) % P`` where R is the run's logical ring span
    (min(window, W*P-ish) — derived from the pool the same way the engine's
    KVPool derives it).  History is gathered through the block table BEFORE
    the chunk's own K/V are scattered (ring overwrite discipline), and
    quantized pools ("k_scale" present; int8 pages, or packed-int4 uint8
    pages at half the head width) dequantize history / quantize writes —
    the attention math itself stays full precision (CiM prefill).

    Returns (out [N, C, d_model], new_cache dict).
    """
    from repro.serving.quantized_cache import (
        dequantize, pack_int4, quantize_token, quantize_token_int4,
        unpack_int4)

    n_rows, C, _ = x.shape
    n_pages, P = cache["k"].shape[0], cache["k"].shape[1]
    B, W = block_table.shape[0], block_table.shape[1]
    capacity = n_pages * P
    try:
        w_static = int(window)
    except Exception as e:          # pragma: no cover - window is per-run static
        raise ValueError("paged attention needs a trace-time window") from e
    R = min(w_static, capacity) if w_static > 0 else capacity
    S = W * P                                     # gathered logical span
    quant = "k_scale" in cache
    q4 = quant and cache["k"].dtype == jnp.uint8  # packed nibble pages

    offs = jnp.asarray(offsets, jnp.int32)
    lens = jnp.asarray(lengths, jnp.int32)
    slot = jnp.asarray(slots, jnp.int32)
    j = jnp.arange(C, dtype=jnp.int32)
    positions = offs[:, None] + j[None, :]                       # [N, C]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           positions, theta, qk_norm)

    # gather the rows' history pages BEFORE writing (a ring entry the chunk
    # overwrites is still needed by the chunk's early queries)
    row = jnp.clip(slot, 0, B - 1)
    bt_rows = jnp.asarray(block_table, jnp.int32)[row]           # [N, W]
    pages = jnp.clip(bt_rows, 0, n_pages - 1)
    if quant:
        raw_k, raw_v = cache["k"][pages], cache["v"][pages]
        if q4:
            raw_k, raw_v = unpack_int4(raw_k), unpack_int4(raw_v)
        prev_k = dequantize(raw_k, cache["k_scale"][pages])
        prev_v = dequantize(raw_v, cache["v_scale"][pages])
        prev_k = prev_k.astype(x.dtype)
        prev_v = prev_v.astype(x.dtype)
    else:
        prev_k = cache["k"][pages]                # [N, W, P, Hkv, Dh]
        prev_v = cache["v"][pages]
    prev_k = prev_k.reshape(n_rows, S, n_kv_heads, d_head)
    prev_v = prev_v.reshape(n_rows, S, n_kv_heads, d_head)
    s_idx = jnp.arange(S, dtype=jnp.int32)
    # ring slot s holds the largest position p < off with p % R == s
    prev_pos = offs[:, None] - 1 - ((offs[:, None] - 1 - s_idx[None, :]) % R)
    prev_pos = jnp.where(s_idx[None, :] < R, prev_pos, -1)       # page tail pad
    unalloc = jnp.repeat(bt_rows >= n_pages, P, axis=1)          # [N, S]
    prev_pos = jnp.where(unalloc, -1, prev_pos)
    chunk_pos = jnp.where(j[None, :] < lens[:, None], positions, -1)
    kv_k = jnp.concatenate([prev_k, k], axis=1)                  # [N, S+C, ...]
    kv_v = jnp.concatenate([prev_v, v], axis=1)
    kv_pos = jnp.concatenate([prev_pos, chunk_pos], axis=1)      # [N, S+C]

    Hkv = n_kv_heads
    G = n_heads // Hkv
    qg = q.reshape(n_rows, C, Hkv, G, d_head)
    scores = jnp.einsum("nqhgd,nkhd->nhgqk", qg, kv_k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(d_head)
    scores = _maybe_softcap(scores, softcap)
    pq = positions[:, :, None]                                   # [N, C, 1]
    pk = kv_pos[:, None, :]                                      # [N, 1, S+C]
    wmask = jnp.where(window > 0, window, jnp.iinfo(jnp.int32).max)
    valid = (pk >= 0) & (pk <= pq) & ((pq - pk) < wmask)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("nhgqk,nkhd->nqhgd", probs.astype(kv_v.dtype), kv_v,
                     preferred_element_type=jnp.float32)
    ctx = ctx.reshape(n_rows, C, n_heads * d_head).astype(x.dtype)
    out = matmul(ctx, params["wo"])

    # pool write: only the row's last R positions (ring discipline — see
    # attn_chunk), through the block table, with padded rows / positions /
    # unallocated pages all dropping out of bounds
    keep = (j[None, :] < lens[:, None]) & (j[None, :] >= lens[:, None] - R)
    valid_row = (slot >= 0) & (slot < B)
    ridx = positions % R
    w_page = jnp.take_along_axis(bt_rows, ridx // P, axis=1)     # [N, C]
    w_page = jnp.where(keep & valid_row[:, None], w_page, n_pages)
    w_off = jnp.where(keep, ridx % P, P)
    new_cache = dict(cache)
    if quant:
        if q4:
            k_q, k_s = quantize_token_int4(k)       # [N,C,Hkv,Dh],[N,C,Hkv]
            v_q, v_s = quantize_token_int4(v)
            k_q, v_q = pack_int4(k_q), pack_int4(v_q)
        else:
            k_q, k_s = quantize_token(k)
            v_q, v_s = quantize_token(v)
        new_cache["k"] = cache["k"].at[w_page, w_off].set(k_q, mode="drop")
        new_cache["k_scale"] = cache["k_scale"].at[w_page, w_off].set(
            k_s, mode="drop")
        new_cache["v"] = cache["v"].at[w_page, w_off].set(v_q, mode="drop")
        new_cache["v_scale"] = cache["v_scale"].at[w_page, w_off].set(
            v_s, mode="drop")
    else:
        new_cache["k"] = cache["k"].at[w_page, w_off].set(k, mode="drop")
        new_cache["v"] = cache["v"].at[w_page, w_off].set(v, mode="drop")
    return out, new_cache


# ---------------------------------------------------------------------------
# packed chunked prefill (flat token stream, per-token segment metadata)
# ---------------------------------------------------------------------------

class PackedSegs(NamedTuple):
    """Per-token segment metadata for a packed prefill stream of T tokens
    holding N segments (one per request chunk; pad segments carry
    start == T so no token maps onto them).

    Per-token ([T]): seg_id, positions (absolute), valid (non-pad),
    jj (index within segment), lens_tok (segment length broadcast),
    tok_slot (arena slot broadcast).  Per-segment ([N]): starts, offsets,
    lengths, slots.
    """
    seg_id: Any
    positions: Any
    valid: Any
    jj: Any
    lens_tok: Any
    tok_slot: Any
    starts: Any
    offsets: Any
    lengths: Any
    slots: Any


def make_packed_segs(starts, offsets, lengths, slots, T: int) -> PackedSegs:
    """Expand per-segment (starts/offsets/lengths/slots, all [N]) into the
    per-token view over a T-token stream.  ``starts`` must be non-decreasing
    with starts[0] == 0; pad segments use start == T (stream length) so the
    running count assigns tail tokens to the last real segment."""
    starts = jnp.asarray(starts, jnp.int32)
    offsets = jnp.asarray(offsets, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    slots = jnp.asarray(slots, jnp.int32)
    t = jnp.arange(T, dtype=jnp.int32)
    seg_id = jnp.maximum(
        jnp.sum(t[:, None] >= starts[None, :], axis=1) - 1, 0
    ).astype(jnp.int32)
    jj = t - starts[seg_id]
    lens_tok = lengths[seg_id]
    valid = jj < lens_tok
    positions = offsets[seg_id] + jj
    tok_slot = slots[seg_id]
    return PackedSegs(seg_id, positions, valid, jj, lens_tok, tok_slot,
                      starts, offsets, lengths, slots)


def _use_packed_kernel(pack_align: int, T: int, softcap, window) -> bool:
    """Pallas packed-prefill kernel dispatch: TPU backend, no softcap, a
    tile-aligned stream (segment starts aligned to pack_align >= 128 so a
    bq-tile never straddles two segments), and a trace-time window."""
    import jax as _jax
    if _jax.default_backend() != "tpu":
        return False
    if softcap and softcap > 0.0:
        return False
    if pack_align < 128 or T % pack_align != 0:
        return False
    try:
        int(window)
    except Exception:
        return False
    return True


def _packed_attention_jax(q, k, v, prev_k, prev_v, prev_pos, seg, *,
                          n_heads, n_kv_heads, d_head, window, softcap):
    """Pure-JAX segment-masked attention over a packed stream.

    q: [T, H, D]; k/v: [T, Hkv, D] (the stream's own projected keys/values);
    prev_k/prev_v: [N, S, Hkv, D] per-SEGMENT arena history with logical
    positions prev_pos [N, S] (-1 = invalid).  Token t attends over its
    segment's history plus the causally-visible same-segment stream tokens.
    Returns ctx [T, H*D] float-accumulated then cast to q.dtype.

    The history and self halves run as separate einsums (summing the two
    softmax partials) so the [T, T] self block never broadcasts to
    [T, S+T, ...] — same online-softmax algebra as a concat, and the
    masked entries contribute exact zeros either way.
    """
    T = q.shape[0]
    S = prev_k.shape[1]
    Hkv = n_kv_heads
    G = n_heads // Hkv
    qg = q.reshape(T, Hkv, G, d_head)
    # per-token history view (gather by segment id)
    ph_k = prev_k[seg.seg_id]                                    # [T, S, ...]
    ph_v = prev_v[seg.seg_id]
    pk_h = prev_pos[seg.seg_id]                                  # [T, S]
    s_hist = jnp.einsum("thgd,tshd->thgs", qg, ph_k,
                        preferred_element_type=jnp.float32)
    s_self = jnp.einsum("thgd,uhd->thgu", qg, k,
                        preferred_element_type=jnp.float32)
    scores = jnp.concatenate([s_hist, s_self], axis=-1) / math.sqrt(d_head)
    scores = _maybe_softcap(scores, softcap)
    pq = seg.positions[:, None]                                  # [T, 1]
    kv_pos_self = jnp.where(seg.valid, seg.positions, -1)        # [T]
    pk = jnp.concatenate(
        [pk_h, jnp.broadcast_to(kv_pos_self[None, :], (T, T))], axis=1)
    w = jnp.where(window > 0, window, jnp.iinfo(jnp.int32).max)
    ok = (pk >= 0) & (pk <= pq) & ((pq - pk) < w)
    same = jnp.concatenate(
        [jnp.ones((T, S), jnp.bool_),
         seg.seg_id[None, :] == seg.seg_id[:, None]], axis=1)
    scores = jnp.where((ok & same)[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("thgs,tshd->thgd", probs[..., :S].astype(ph_v.dtype),
                     ph_v, preferred_element_type=jnp.float32)
    ctx = ctx + jnp.einsum("thgu,uhd->thgd", probs[..., S:].astype(v.dtype),
                           v, preferred_element_type=jnp.float32)
    return ctx.reshape(T, n_heads * d_head)


def attn_chunk_packed(params, x, seg: PackedSegs, cache_k, cache_v, *,
                      n_heads, n_kv_heads, d_head, theta, window,
                      softcap=0.0, qk_norm=False, pack_align: int = 0):
    """Packed-stream chunked prefill against the dense decode arena.

    x: [1, T, d] — one flat stream of N segments described by ``seg``
    (see ``PackedSegs``); cache_k/v: [B, R, Hkv, Dh].  Same arena-direct
    contract as ``attn_chunk`` but without padded rows: each token attends
    over its OWN segment's ring history plus the causally-visible tokens of
    the same segment inside the stream.  On TPU with a tile-aligned stream
    the attention sweep runs in the Pallas ``packed_prefill_attention``
    kernel (the dense arena is presented as a 1-page-per-segment pool view).

    Returns (out [1, T, d_model], new_cache_k, new_cache_v).
    """
    _, T, _ = x.shape
    B, R = cache_k.shape[0], cache_k.shape[1]
    positions = seg.positions[None]                              # [1, T]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           positions, theta, qk_norm)
    q, k, v = q[0], k[0], v[0]                                   # [T, ...]

    if _use_packed_kernel(pack_align, T, softcap, window):
        from repro.kernels import ops as _kops
        # dense arena as a page view: one R-sized page per slot, block
        # table = the segment's slot (sentinel B for pad segments drops
        # to a clamped page whose entries prev_pos-mask out anyway)
        ctx = _kops.packed_prefill_attention(
            q, k, v, cache_k, cache_v, seg.slots[:, None],
            seg.starts, seg.offsets, seg.lengths,
            ring=R, window=int(window), bq=pack_align)
        ctx = ctx.reshape(T, n_heads * d_head)
    else:
        row = jnp.clip(seg.slots, 0, B - 1)
        prev_k = cache_k[row]                                    # [N, R, ...]
        prev_v = cache_v[row]
        s_idx = jnp.arange(R, dtype=jnp.int32)
        offs = seg.offsets
        prev_pos = (offs[:, None] - 1
                    - ((offs[:, None] - 1 - s_idx[None, :]) % R))
        ctx = _packed_attention_jax(
            q, k, v, prev_k, prev_v, prev_pos, seg,
            n_heads=n_heads, n_kv_heads=n_kv_heads, d_head=d_head,
            window=window, softcap=softcap)
    out = matmul(ctx[None].astype(x.dtype), params["wo"])

    # arena write: identical ring discipline to attn_chunk, per token
    keep = seg.valid & (seg.jj >= seg.lens_tok - R)
    w_slot = jnp.where(keep, seg.tok_slot, B)
    w_idx = jnp.where(keep, seg.positions % R, R)
    new_k = cache_k.at[w_slot, w_idx].set(k, mode="drop")
    new_v = cache_v.at[w_slot, w_idx].set(v, mode="drop")
    return out, new_k, new_v


def attn_chunk_packed_paged(params, x, seg: PackedSegs, cache, block_table,
                            *, n_heads, n_kv_heads, d_head, theta, window,
                            softcap=0.0, qk_norm=False, pack_align: int = 0):
    """Packed-stream chunked prefill writing K/V into the paged block pool.

    Same stream contract as ``attn_chunk_packed``; the arena is the pool
    ``cache`` ([n_pages, P, ...]) addressed via ``block_table`` [B, W]
    exactly as in ``attn_chunk_paged`` (ring span R, sentinel pages drop,
    quantized — int8 or packed-int4 — pools dequantize history / quantize
    writes).  On TPU the float pool path runs the Pallas kernel with the
    segments' block-table rows scalar-prefetched.

    Returns (out [1, T, d_model], new_cache dict).
    """
    from repro.serving.quantized_cache import (
        dequantize, pack_int4, quantize_token, quantize_token_int4,
        unpack_int4)

    _, T, _ = x.shape
    n_pages, P = cache["k"].shape[0], cache["k"].shape[1]
    B, W = block_table.shape[0], block_table.shape[1]
    capacity = n_pages * P
    try:
        w_static = int(window)
    except Exception as e:          # pragma: no cover - window is per-run static
        raise ValueError("paged attention needs a trace-time window") from e
    R = min(w_static, capacity) if w_static > 0 else capacity
    S = W * P
    quant = "k_scale" in cache
    q4 = quant and cache["k"].dtype == jnp.uint8  # packed nibble pages

    positions = seg.positions[None]                              # [1, T]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           positions, theta, qk_norm)
    q, k, v = q[0], k[0], v[0]                                   # [T, ...]
    bt_rows = jnp.asarray(block_table, jnp.int32)[
        jnp.clip(seg.slots, 0, B - 1)]                           # [N, W]

    if not quant and _use_packed_kernel(pack_align, T, softcap, window):
        from repro.kernels import ops as _kops
        ctx = _kops.packed_prefill_attention(
            q, k, v, cache["k"], cache["v"], bt_rows,
            seg.starts, seg.offsets, seg.lengths,
            ring=R, window=w_static, bq=pack_align)
        ctx = ctx.reshape(T, n_heads * d_head)
    else:
        pages = jnp.clip(bt_rows, 0, n_pages - 1)
        if quant:
            raw_k, raw_v = cache["k"][pages], cache["v"][pages]
            if q4:
                raw_k, raw_v = unpack_int4(raw_k), unpack_int4(raw_v)
            prev_k = dequantize(raw_k, cache["k_scale"][pages])
            prev_v = dequantize(raw_v, cache["v_scale"][pages])
            prev_k = prev_k.astype(x.dtype)
            prev_v = prev_v.astype(x.dtype)
        else:
            prev_k = cache["k"][pages]            # [N, W, P, Hkv, Dh]
            prev_v = cache["v"][pages]
        N = bt_rows.shape[0]
        prev_k = prev_k.reshape(N, S, n_kv_heads, d_head)
        prev_v = prev_v.reshape(N, S, n_kv_heads, d_head)
        s_idx = jnp.arange(S, dtype=jnp.int32)
        offs = seg.offsets
        prev_pos = (offs[:, None] - 1
                    - ((offs[:, None] - 1 - s_idx[None, :]) % R))
        prev_pos = jnp.where(s_idx[None, :] < R, prev_pos, -1)
        unalloc = jnp.repeat(bt_rows >= n_pages, P, axis=1)      # [N, S]
        prev_pos = jnp.where(unalloc, -1, prev_pos)
        ctx = _packed_attention_jax(
            q, k, v, prev_k, prev_v, prev_pos, seg,
            n_heads=n_heads, n_kv_heads=n_kv_heads, d_head=d_head,
            window=window, softcap=softcap)
    out = matmul(ctx[None].astype(x.dtype), params["wo"])

    # pool write: ring discipline through the block table, per token
    keep = seg.valid & (seg.jj >= seg.lens_tok - R)
    valid_row = (seg.tok_slot >= 0) & (seg.tok_slot < B)
    ridx = seg.positions % R
    bt_tok = bt_rows[seg.seg_id]                                 # [T, W]
    w_page = jnp.take_along_axis(bt_tok, (ridx // P)[:, None], axis=1)[:, 0]
    w_page = jnp.where(keep & valid_row, w_page, n_pages)
    w_off = jnp.where(keep, ridx % P, P)
    new_cache = dict(cache)
    if quant:
        if q4:
            k_q, k_s = quantize_token_int4(k)     # [T,Hkv,Dh],[T,Hkv]
            v_q, v_s = quantize_token_int4(v)
            k_q, v_q = pack_int4(k_q), pack_int4(v_q)
        else:
            k_q, k_s = quantize_token(k)
            v_q, v_s = quantize_token(v)
        new_cache["k"] = cache["k"].at[w_page, w_off].set(k_q, mode="drop")
        new_cache["k_scale"] = cache["k_scale"].at[w_page, w_off].set(
            k_s, mode="drop")
        new_cache["v"] = cache["v"].at[w_page, w_off].set(v_q, mode="drop")
        new_cache["v_scale"] = cache["v_scale"].at[w_page, w_off].set(
            v_s, mode="drop")
    else:
        new_cache["k"] = cache["k"].at[w_page, w_off].set(k, mode="drop")
        new_cache["v"] = cache["v"].at[w_page, w_off].set(v, mode="drop")
    return out, new_cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _q8_sweep(q, ck, cks, cv, cvs, valid, *, n_heads, n_kv_heads, d_head,
              softcap):
    """The s8 x s8 decode attention sweep shared by the dense and paged
    int8 paths (see ``attn_decode_q8`` for the math / HALO reading).

    q: [B, 1, H, Dh] float; ck/cv: int8 [B, S, Hkv, Dh] (dense arena or a
    block-table gather of the page pool); cks/cvs: f32 [B, S, Hkv] scales;
    valid: [B, S] entry mask.  Returns ctx f32 [B, Hkv, G, Dh].
    """
    from repro.serving.quantized_cache import quantize_token

    B = q.shape[0]
    Hkv = n_kv_heads
    G = n_heads // Hkv
    # quantize q per head; s8 x s8 scores [B,Hkv,G,Dh].[B,S,Hkv,Dh]
    q_q, q_s = quantize_token(q.reshape(B, Hkv, G, d_head))
    s_i32 = jax.lax.dot_general(
        q_q, ck, (((3,), (3,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.int32)                      # [B,Hkv,G,S]
    scores = (s_i32.astype(jnp.float32)
              * q_s[..., None]
              * cks.transpose(0, 2, 1)[:, :, None, :])
    scores = scores / math.sqrt(d_head)
    scores = _maybe_softcap(scores, softcap)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)                    # [B,Hkv,G,S]
    # fold v_scale into p, re-quantize, s8 x s8 attn_v
    p_scaled = probs * cvs.transpose(0, 2, 1)[:, :, None, :]
    p_q, p_s = quantize_token(p_scaled)                        # scale [B,Hkv,G]
    ctx_i32 = jax.lax.dot_general(
        p_q, cv, (((3,), (1,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.int32)                      # [B,Hkv,G,Dh]
    return ctx_i32.astype(jnp.float32) * p_s[..., None]


def attn_decode_q8(params, x, cache, pos, *, n_heads, n_kv_heads,
                   d_head, theta, window, softcap=0.0, qk_norm=False,
                   slot=None, extra_mask=None):
    """int8-KV decode — the HALO-faithful memory format (CiD computes int8
    end to end; Section IV-A).  The cache stores int8 values with one f32
    scale per (token, kv-head); BOTH attention contractions run as s8 x s8
    ``dot_general`` (the MXU's native int8 path, = CiD's 8-bit bank MACs):

      scores[s] = (q_q . k_q[s]) * q_scale * k_scale[s]
      out       = (p'_q . v_q)   * p'_scale          with p' = p * v_scale[s]

    folding the per-token v_scale into p BEFORE quantizing keeps the second
    contraction exact up to int8 rounding.  HBM traffic per token: S*(Hkv*Dh
    + 4) bytes per cache side — 2x less than bf16, 4x less than f32.

    cache: {"k": int8 [B,S,Hkv,Dh], "k_scale": f32 [B,S,Hkv], "v", "v_scale"}
    """
    from repro.distributed.policy import constrain
    from repro.serving.quantized_cache import quantize_token

    B = x.shape[0]
    S = cache["k"].shape[1]
    pos_in = jnp.asarray(pos, jnp.int32)
    pos = jnp.broadcast_to(pos_in, (B,))
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           pos[:, None], theta, qk_norm)
    # quantize the new K/V entry (per kv-head) and splice into the arena
    k_q, k_s = quantize_token(k)                       # [B,1,Hkv,Dh],[B,1,Hkv]
    v_q, v_s = quantize_token(v)
    if slot is None:
        slot = pos_in % S if pos_in.ndim == 0 else pos % S
    slot = jnp.asarray(slot, jnp.int32)
    if slot.ndim == 0:
        ck = jax.lax.dynamic_update_slice(cache["k"], k_q, (0, slot, 0, 0))
        cks = jax.lax.dynamic_update_slice(cache["k_scale"], k_s, (0, slot, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v_q, (0, slot, 0, 0))
        cvs = jax.lax.dynamic_update_slice(cache["v_scale"], v_s, (0, slot, 0))
    else:
        bidx = jnp.arange(B)
        ck = cache["k"].at[bidx, slot].set(k_q[:, 0])
        cks = cache["k_scale"].at[bidx, slot].set(k_s[:, 0])
        cv = cache["v"].at[bidx, slot].set(v_q[:, 0])
        cvs = cache["v_scale"].at[bidx, slot].set(v_s[:, 0])

    slots = jnp.arange(S, dtype=jnp.int32)
    written = slots[None, :] <= pos[:, None]
    wrapped = pos[:, None] >= S
    valid = written | wrapped
    if extra_mask is not None:
        valid = valid & extra_mask
    ctx = _q8_sweep(q, ck, cks, cv, cvs, valid, n_heads=n_heads,
                    n_kv_heads=n_kv_heads, d_head=d_head, softcap=softcap)
    ctx = ctx.reshape(B, 1, n_heads * d_head).astype(x.dtype)
    out = matmul(ctx, params["wo"])
    new_cache = {"k": ck, "k_scale": cks, "v": cv, "v_scale": cvs}
    return out, new_cache


def attn_decode(params, x, cache_k, cache_v, pos, *, n_heads, n_kv_heads,
                d_head, theta, window, softcap=0.0, qk_norm=False,
                slot=None, extra_mask=None):
    """One-token decode against a (possibly ring-buffer) KV cache.

    x: [B, 1, d_model]; cache_k/v: [B, S, Hkv, Dh]; pos: scalar or [B] int32
    (absolute position of the NEW token).  Returns (out [B,1,d], new_k, new_v).

    ``slot`` optionally overrides the physical write index (serving engine
    with right-padded prompts); ``extra_mask`` [B, S] marks additionally
    invalid cache entries (e.g. prompt padding).

    The cache sequence axis S may be sharded across the 'model' mesh axis;
    the softmax over S then lowers to local GEMVs + tiny all-reduces
    (flash-decode semantics via GSPMD).
    """
    B = x.shape[0]
    S = cache_k.shape[1]
    pos_in = jnp.asarray(pos, jnp.int32)
    pos = jnp.broadcast_to(pos_in, (B,))
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           pos[:, None], theta, qk_norm)
    # ring-buffer slot for the new entry: scalar -> dynamic_update_slice
    # (dry-run / aligned batch), per-batch vector -> scatter (serving engine)
    if slot is None:
        slot = pos_in % S if pos_in.ndim == 0 else pos % S
    slot = jnp.asarray(slot, jnp.int32)
    if slot.ndim == 0:
        ck = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    else:
        bidx = jnp.arange(B)
        ck = cache_k.at[bidx, slot].set(k[:, 0])
        cv = cache_v.at[bidx, slot].set(v[:, 0])
    from repro.distributed.policy import constrain
    ck = constrain(ck, "kv_bshd")
    cv = constrain(cv, "kv_bshd")

    Hkv = n_kv_heads
    G = n_heads // Hkv
    qg = q.reshape(B, Hkv, G, d_head)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, ck,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(d_head)
    scores = _maybe_softcap(scores, softcap)
    # validity: slot s was written iff s <= pos (before wrap) else always.
    slots = jnp.arange(S, dtype=jnp.int32)
    written = slots[None, :] <= pos[:, None]
    wrapped = pos[:, None] >= S
    valid = written | wrapped
    if extra_mask is not None:
        valid = valid & extra_mask
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgs,bshd->bhgd", probs.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    ctx = ctx.reshape(B, 1, n_heads * d_head).astype(x.dtype)
    out = matmul(ctx, params["wo"])
    return out, ck, cv


# ---------------------------------------------------------------------------
# decode (paged pool)
# ---------------------------------------------------------------------------

def _paged_ring(window, n_pages: int, page_size: int) -> int:
    """Logical ring span of a paged run: min(window, pool capacity)."""
    capacity = n_pages * page_size
    w = int(window)                 # per-run static (trace-time constant)
    return min(w, capacity) if w > 0 else capacity


def attn_decode_paged(params, x, cache, block_table, pos, *, n_heads,
                      n_kv_heads, d_head, theta, window, softcap=0.0,
                      qk_norm=False):
    """One-token decode against the paged block pool, routed through the
    Pallas paged flash-decode kernel (kernels/decode_attention.py).

    x: [B, 1, d_model]; cache: {"k","v"} of [n_pages, P, Hkv, Dh];
    block_table: [B, W] int32 (sentinel >= n_pages: unallocated — the
    engine hands inactive slots all-sentinel rows, so their writes drop);
    pos: [B] absolute position of the NEW token.  Returns (out, new_cache).
    """
    from repro.kernels import ops as _kops

    B = x.shape[0]
    k_pages, v_pages = cache["k"], cache["v"]
    n_pages, P = k_pages.shape[0], k_pages.shape[1]
    R = _paged_ring(window, n_pages, P)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           pos[:, None], theta, qk_norm)
    bt = jnp.asarray(block_table, jnp.int32)
    # write the new entry through the block table (ring index within R)
    bidx = jnp.arange(B)
    ridx = pos % R
    w_page = bt[bidx, ridx // P]                 # sentinel rows drop
    ck = k_pages.at[w_page, ridx % P].set(k[:, 0], mode="drop")
    cv = v_pages.at[w_page, ridx % P].set(v[:, 0], mode="drop")
    # ring validity: slot s written iff s <= pos (before wrap) else always
    # -> exactly min(pos + 1, R) valid leading logical entries
    lengths = jnp.minimum(pos + 1, R)
    if softcap and softcap > 0.0:
        # the kernel has no softcap path; gather a dense view and reuse the
        # reference math (softcapped GQA decode is not on the paper's path)
        gk = ck[jnp.clip(bt, 0, n_pages - 1)].reshape(
            B, -1, n_kv_heads, d_head)
        gv = cv[jnp.clip(bt, 0, n_pages - 1)].reshape(
            B, -1, n_kv_heads, d_head)
        Hkv, G = n_kv_heads, n_heads // n_kv_heads
        qg = q.reshape(B, Hkv, G, d_head)
        s = jnp.einsum("bhgd,bshd->bhgs", qg, gk,
                       preferred_element_type=jnp.float32) / math.sqrt(d_head)
        s = _maybe_softcap(s, softcap)
        S = gk.shape[1]
        ok = (jnp.arange(S, dtype=jnp.int32)[None, :] < lengths[:, None]) \
            & ~jnp.repeat(bt >= n_pages, P, axis=1)
        s = jnp.where(ok[:, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhgs,bshd->bhgd", p.astype(gv.dtype), gv,
                         preferred_element_type=jnp.float32)
    else:
        ctx = _kops.paged_decode_attention(
            q.reshape(B, n_heads, d_head), ck, cv, bt, lengths)
    ctx = ctx.reshape(B, 1, n_heads * d_head).astype(x.dtype)
    out = matmul(ctx, params["wo"])
    return out, {"k": ck, "v": cv}


def attn_decode_q8_paged(params, x, cache, block_table, pos, *, n_heads,
                         n_kv_heads, d_head, theta, window, softcap=0.0,
                         qk_norm=False):
    """int8 paged decode: the HALO-faithful memory format on the block pool.

    cache: {"k": int8 [n_pages,P,Hkv,Dh], "k_scale": f32 [n_pages,P,Hkv],
    "v", "v_scale"} — scales ride in a parallel page array under the SAME
    block table.  Both contractions run s8 x s8 exactly like the dense
    ``attn_decode_q8``; the pool is gathered into a per-sequence view
    first (the CiD analogue: the bank reads whole rows, the row decoder is
    the block table).
    """
    from repro.serving.quantized_cache import quantize_token

    B = x.shape[0]
    n_pages, P = cache["k"].shape[0], cache["k"].shape[1]
    Hkv = n_kv_heads
    R = _paged_ring(window, n_pages, P)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           pos[:, None], theta, qk_norm)
    k_q, k_s = quantize_token(k)                   # [B,1,Hkv,Dh],[B,1,Hkv]
    v_q, v_s = quantize_token(v)
    bt = jnp.asarray(block_table, jnp.int32)
    bidx = jnp.arange(B)
    ridx = pos % R
    w_page = bt[bidx, ridx // P]
    off = ridx % P
    ck = cache["k"].at[w_page, off].set(k_q[:, 0], mode="drop")
    cks = cache["k_scale"].at[w_page, off].set(k_s[:, 0], mode="drop")
    cv = cache["v"].at[w_page, off].set(v_q[:, 0], mode="drop")
    cvs = cache["v_scale"].at[w_page, off].set(v_s[:, 0], mode="drop")

    # gather the sequence's pages (int8 + scales) through the block table
    rows = jnp.clip(bt, 0, n_pages - 1)
    S = bt.shape[1] * P
    gk = ck[rows].reshape(B, S, Hkv, d_head)       # int8
    gks = cks[rows].reshape(B, S, Hkv)
    gv = cv[rows].reshape(B, S, Hkv, d_head)
    gvs = cvs[rows].reshape(B, S, Hkv)
    lengths = jnp.minimum(pos + 1, R)
    valid = (jnp.arange(S, dtype=jnp.int32)[None, :] < lengths[:, None]) \
        & ~jnp.repeat(bt >= n_pages, P, axis=1)
    ctx = _q8_sweep(q, gk, gks, gv, gvs, valid, n_heads=n_heads,
                    n_kv_heads=n_kv_heads, d_head=d_head, softcap=softcap)
    ctx = ctx.reshape(B, 1, n_heads * d_head).astype(x.dtype)
    out = matmul(ctx, params["wo"])
    return out, {"k": ck, "k_scale": cks, "v": cv, "v_scale": cvs}


def attn_decode_q4_paged(params, x, cache, block_table, pos, *, n_heads,
                         n_kv_heads, d_head, theta, window, softcap=0.0,
                         qk_norm=False):
    """Packed-int4 paged decode: quarter-width KV bytes on the block pool.

    cache: {"k": uint8 [n_pages,P,Hkv,Dh//2] (nibble pairs), "k_scale": f32
    [n_pages,P,Hkv], "v", "v_scale"}.  The new token is quantized to int4
    per kv-head, packed, and scattered through the block table exactly like
    the q8 path; the sweep runs in the Pallas ``paged_decode_attention_q4``
    kernel, which unpacks and dequantizes in-register so the HBM bytes per
    step stay at the packed width (softcap falls back to a gathered dense
    reference view, mirroring ``attn_decode_paged``)."""
    from repro.kernels import ops as _kops
    from repro.serving.quantized_cache import (
        dequantize, pack_int4, quantize_token_int4, unpack_int4)

    B = x.shape[0]
    n_pages, P = cache["k"].shape[0], cache["k"].shape[1]
    Hkv = n_kv_heads
    R = _paged_ring(window, n_pages, P)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           pos[:, None], theta, qk_norm)
    k_q, k_s = quantize_token_int4(k)              # [B,1,Hkv,Dh],[B,1,Hkv]
    v_q, v_s = quantize_token_int4(v)
    k_q, v_q = pack_int4(k_q), pack_int4(v_q)      # [B,1,Hkv,Dh//2] uint8
    bt = jnp.asarray(block_table, jnp.int32)
    bidx = jnp.arange(B)
    ridx = pos % R
    w_page = bt[bidx, ridx // P]
    off = ridx % P
    ck = cache["k"].at[w_page, off].set(k_q[:, 0], mode="drop")
    cks = cache["k_scale"].at[w_page, off].set(k_s[:, 0], mode="drop")
    cv = cache["v"].at[w_page, off].set(v_q[:, 0], mode="drop")
    cvs = cache["v_scale"].at[w_page, off].set(v_s[:, 0], mode="drop")

    lengths = jnp.minimum(pos + 1, R)
    if softcap and softcap > 0.0:
        rows = jnp.clip(bt, 0, n_pages - 1)
        S = bt.shape[1] * P
        gk = dequantize(unpack_int4(ck[rows]), cks[rows]).reshape(
            B, S, Hkv, d_head)
        gv = dequantize(unpack_int4(cv[rows]), cvs[rows]).reshape(
            B, S, Hkv, d_head)
        G = n_heads // Hkv
        qg = q.reshape(B, Hkv, G, d_head)
        s = jnp.einsum("bhgd,bshd->bhgs", qg, gk.astype(q.dtype),
                       preferred_element_type=jnp.float32) / math.sqrt(d_head)
        s = _maybe_softcap(s, softcap)
        ok = (jnp.arange(S, dtype=jnp.int32)[None, :] < lengths[:, None]) \
            & ~jnp.repeat(bt >= n_pages, P, axis=1)
        s = jnp.where(ok[:, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhgs,bshd->bhgd", p, gv,
                         preferred_element_type=jnp.float32)
    else:
        ctx = _kops.paged_decode_attention_q4(
            q.reshape(B, n_heads, d_head), ck, cks, cv, cvs, bt, lengths)
    ctx = ctx.reshape(B, 1, n_heads * d_head).astype(x.dtype)
    out = matmul(ctx, params["wo"])
    return out, {"k": ck, "k_scale": cks, "v": cv, "v_scale": cvs}
