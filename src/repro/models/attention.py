"""Attention: GQA with qk-norm / sliding-window / local-global variants.

Two execution paths, mirroring HALO's phase split:

* ``attn_prefill`` — compute-bound GEMM path.  For long sequences it uses a
  blockwise (flash-style, online-softmax) pure-JAX implementation so the
  lowered HLO has O(T * block) live memory instead of O(T^2).  On TPU the
  Pallas flash kernel (kernels/flash_attention.py) implements the same
  algorithm with explicit VMEM tiling.

* ``attn_decode`` — memory-bound GEMV path.  One new token attends to a KV
  cache of S entries.  The cache is laid out [B, S, Hkv, Dh] so that S can be
  sequence-sharded across the ``model`` mesh axis (the TPU analogue of HALO's
  bank-level CiD GEMV: every shard scans its local slice of the cache and the
  softmax is reconstructed with tiny cross-shard reductions).

Sliding-window layers use a ring-buffer cache of length min(W, S): keys are
stored with RoPE already applied at their absolute position, so the ring
order does not matter; validity masking only needs the current position.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (
    apply_rope,
    dense_init,
    head_rmsnorm,
    matmul,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int, d_head: int,
              dtype, qk_norm: bool = False, d_model_out: Optional[int] = None):
    d_out = d_model_out or d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * d_head, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * d_head, dtype),
        "wo": dense_init(ks[3], n_heads * d_head, d_out, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((d_head,), dtype)
        p["k_norm"] = jnp.ones((d_head,), dtype)
    return p


# ---------------------------------------------------------------------------
# qkv projection (shared between phases)
# ---------------------------------------------------------------------------

def _project_qkv(params, x, n_heads, n_kv_heads, d_head, positions, theta,
                 qk_norm: bool):
    from repro.distributed.policy import constrain
    B = x.shape[0]
    T = x.shape[1]
    q = matmul(x, params["wq"]).reshape(B, T, n_heads, d_head)
    k = matmul(x, params["wk"]).reshape(B, T, n_kv_heads, d_head)
    v = matmul(x, params["wv"]).reshape(B, T, n_kv_heads, d_head)
    from repro.distributed.policy import get_policy
    pol = get_policy()
    q = constrain(q, "act_bthd")
    if pol is not None and pol.sp_enabled and T > 1:
        # sequence-parallel prefill: gather K/V across the token shards
        # (0.27 GB/layer vs the 4.3 GB/layer of f32 activation all-reduces
        # that Megatron-style TP costs at 32k context — §Perf iteration 2)
        k = constrain(k, "kv_full")
        v = constrain(v, "kv_full")
    elif n_kv_heads > 1:      # kv=1 (gemma3) cannot shard the head axis
        k = constrain(k, "act_bthd")
        v = constrain(v, "act_bthd")
    if qk_norm:
        q = head_rmsnorm(params["q_norm"], q)
        k = head_rmsnorm(params["k_norm"], k)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def _maybe_softcap(scores, softcap: float):
    if softcap and softcap > 0.0:
        return softcap * jnp.tanh(scores / softcap)
    return scores


# ---------------------------------------------------------------------------
# prefill / train
# ---------------------------------------------------------------------------

def _dense_attention(q, k, v, positions, kv_positions, window, softcap,
                     pad_mask=None):
    """Reference masked attention.  q:[B,Tq,H,D] k,v:[B,Tk,Hkv,D]."""
    B, Tq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Tq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(D)
    scores = _maybe_softcap(scores, softcap)
    # causal + window mask.  window is traced (per-layer); 0 means "full".
    pq = positions[:, :, None]                       # [B,Tq,1]
    pk = kv_positions[:, None, :]                    # [B,1,Tk]
    causal = pk <= pq
    w = jnp.where(window > 0, window, jnp.iinfo(jnp.int32).max)
    in_window = (pq - pk) < w
    valid = causal & in_window
    if pad_mask is not None:
        valid = valid & pad_mask[:, None, :]
    mask = valid[:, None, None]                      # [B,1,1,Tq,Tk]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Tq, H, D).astype(q.dtype)


def _blockwise_attention(q, k, v, positions, window, softcap,
                         block_q: int = 512, block_kv: int = 1024,
                         pad_mask=None):
    """Flash-style online-softmax attention; O(T*block) live memory.

    Causal masking is applied at block granularity through the score mask;
    the FLOP count still includes upper-triangle blocks (see EXPERIMENTS.md
    §Perf for the triangular-schedule optimization that removes them).
    """
    B, T, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    nq = T // block_q
    nk = T // block_kv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, nq, block_q, Hkv, G, D)
    kb = k.reshape(B, nk, block_kv, Hkv, D)
    vb = v.reshape(B, nk, block_kv, Hkv, D)
    pos_q = positions.reshape(B, nq, block_q)
    pos_k = positions.reshape(B, nk, block_kv)
    if pad_mask is None:
        pad_mask = jnp.ones((B, T), jnp.bool_)
    pm_k = pad_mask.reshape(B, nk, block_kv)
    w = jnp.where(window > 0, window, jnp.iinfo(jnp.int32).max)

    @partial(jax.checkpoint,
             policy=jax.checkpoint_policies.nothing_saveable)
    def q_block_inner(qblk, pq):
        """One query block vs all kv blocks.  Rematerialized in backward so
        the per-(q,kv)-block probabilities are never stacked across blocks
        (flash-attention memory discipline, pure-JAX edition)."""
        m0 = jnp.full((B, Hkv, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, block_q, D), jnp.float32)

        def kv_block(acc, ki):
            m, l, a = acc
            kblk, vblk, pk, pmk = ki
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = _maybe_softcap(s, softcap)
            causal = pk[:, None, None, None, :] <= pq[:, None, None, :, None]
            in_w = (pq[:, None, None, :, None] - pk[:, None, None, None, :]) < w
            ok = causal & in_w & pmk[:, None, None, None, :]
            s = jnp.where(ok, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            a_new = a * corr[..., None] + pv
            return (m_new, l_new, a_new), None

        (m, l, a), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), pos_k.swapaxes(0, 1),
             pm_k.swapaxes(0, 1)))
        out = a / jnp.maximum(l[..., None], 1e-30)           # [B,Hkv,G,bq,D]
        out = out.transpose(0, 3, 1, 2, 4)                   # [B,bq,Hkv,G,D]
        return out.astype(qblk.dtype)

    from repro.distributed.policy import get_policy
    pol = get_policy()
    if pol is not None and pol.sp_enabled:
        # SEQUENCE-PARALLEL: q blocks are sharded over 'model'; a scan would
        # serialize them globally (GSPMD slices the scan axis), so the block
        # loop becomes a vmap — batched ops with a sharded leading dim stay
        # shard-local.  K/V were all-gathered by the caller.
        outs = jax.vmap(q_block_inner)(
            qg.swapaxes(0, 1), pos_q.swapaxes(0, 1))         # [nq,B,bq,...]
    else:
        def q_block(carry, qi):
            qblk, pq = qi                                    # [B,bq,Hkv,G,D]
            return carry, q_block_inner(qblk, pq)

        _, outs = jax.lax.scan(
            q_block, None,
            (qg.swapaxes(0, 1), pos_q.swapaxes(0, 1)))       # [nq,B,bq,...]
    out = outs.swapaxes(0, 1).reshape(B, T, H, D)
    return out


def _can_use_pallas_flash(q, softcap, pad_mask, positions) -> bool:
    """The Pallas kernel path: TPU backend, no softcap/padding, contiguous
    positions (the kernel masks by absolute block indices)."""
    import jax as _jax
    if _jax.default_backend() != "tpu":
        return False
    if softcap or pad_mask is not None:
        return False
    T = q.shape[1]
    return T % 512 == 0


def attn_prefill(params, x, positions, *, n_heads, n_kv_heads, d_head,
                 theta, window, softcap=0.0, qk_norm=False,
                 dense_threshold: int = 2048, pad_mask=None):
    """Full-sequence attention.  Returns [B, T, d_model_out] and (k, v) for
    cache initialization.  ``pad_mask`` [B,T] marks valid (non-pad) keys.

    Dispatch: small T -> dense reference; long T on TPU -> the Pallas flash
    kernel (kernels/flash_attention.py: triangular tile schedule, VMEM-
    resident probs); otherwise the pure-JAX blockwise path (same online-
    softmax algorithm — the CPU/dry-run stand-in the §Roofline kernel-region
    discount maps back onto the kernel).
    """
    B, T, _ = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           positions, theta, qk_norm)
    if T <= dense_threshold:
        out = _dense_attention(q, k, v, positions, positions, window, softcap,
                               pad_mask=pad_mask)
    else:
        use_kernel = _can_use_pallas_flash(q, softcap, pad_mask, positions)
        w = None
        if use_kernel:
            try:
                w = int(window)        # concrete at trace time (per-run)
            except Exception:
                use_kernel = False
        if use_kernel:
            from repro.kernels import ops as _kops
            out = _kops.flash_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=True,
                window=w).transpose(0, 2, 1, 3)
        else:
            out = _blockwise_attention(q, k, v, positions, window, softcap,
                                       pad_mask=pad_mask)
    out = matmul(out.reshape(B, T, n_heads * d_head), params["wo"])
    return out, (k, v)


# ---------------------------------------------------------------------------
# chunked prefill (arena-direct)
# ---------------------------------------------------------------------------

def attn_chunk(params, x, offsets, lengths, slots, cache_k, cache_v, *,
               n_heads, n_kv_heads, d_head, theta, window, softcap=0.0,
               qk_norm=False):
    """Chunked prefill against the decode arena (HALO's CiM -> CiD handoff).

    x: [N, C, d] — N packed chunk rows of up to C tokens; row ``n`` carries
    tokens ``[offsets[n], offsets[n]+lengths[n])`` of the request living in
    arena slot ``slots[n]``.  cache_k/v: [B, R, Hkv, Dh] decode arena
    (R = min(window, S) ring for sliding-window runs, R = S otherwise).

    Entries at positions < offsets[n] were written by earlier chunks of the
    same request; the chunk attends over that history + itself (causal,
    windowed) and then writes its own K/V into the arena.  History is
    gathered BEFORE the write: a ring entry the chunk is about to overwrite
    is still needed by the chunk's early queries.  Padded rows
    (slots[n] >= B) and padded positions (j >= lengths[n]) scatter out of
    bounds and are dropped.

    Returns (out [N, C, d_model], new_cache_k, new_cache_v).
    """
    N, C, _ = x.shape
    B, R = cache_k.shape[0], cache_k.shape[1]
    offs = jnp.asarray(offsets, jnp.int32)
    lens = jnp.asarray(lengths, jnp.int32)
    slot = jnp.asarray(slots, jnp.int32)
    j = jnp.arange(C, dtype=jnp.int32)
    positions = offs[:, None] + j[None, :]                       # [N, C]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           positions, theta, qk_norm)

    row = jnp.clip(slot, 0, B - 1)
    prev_k = cache_k[row]                                        # [N, R, ...]
    prev_v = cache_v[row]
    s_idx = jnp.arange(R, dtype=jnp.int32)
    # ring slot s holds the largest position p < off with p % R == s
    # (for full-attention runs R == S, so this reduces to p == s when
    # s < off and "not yet written" otherwise — one formula for both)
    prev_pos = offs[:, None] - 1 - ((offs[:, None] - 1 - s_idx[None, :]) % R)
    chunk_pos = jnp.where(j[None, :] < lens[:, None], positions, -1)
    kv_k = jnp.concatenate([prev_k, k], axis=1)                  # [N, R+C, ...]
    kv_v = jnp.concatenate([prev_v, v], axis=1)
    kv_pos = jnp.concatenate([prev_pos, chunk_pos], axis=1)      # [N, R+C]

    Hkv = n_kv_heads
    G = n_heads // Hkv
    qg = q.reshape(N, C, Hkv, G, d_head)
    scores = jnp.einsum("nqhgd,nkhd->nhgqk", qg, kv_k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(d_head)
    scores = _maybe_softcap(scores, softcap)
    pq = positions[:, :, None]                                   # [N, C, 1]
    pk = kv_pos[:, None, :]                                      # [N, 1, R+C]
    w = jnp.where(window > 0, window, jnp.iinfo(jnp.int32).max)
    valid = (pk >= 0) & (pk <= pq) & ((pq - pk) < w)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("nhgqk,nkhd->nqhgd", probs.astype(kv_v.dtype), kv_v,
                     preferred_element_type=jnp.float32)
    ctx = ctx.reshape(N, C, n_heads * d_head).astype(x.dtype)
    out = matmul(ctx, params["wo"])

    # arena write: ring discipline keeps only the row's last R positions
    # (earlier chunk positions a later same-chunk token wraps onto must
    # not be scattered — duplicate scatter indices are order-undefined)
    keep = (j[None, :] < lens[:, None]) & (j[None, :] >= lens[:, None] - R)
    w_slot = jnp.where(keep, jnp.broadcast_to(slot[:, None], (N, C)), B)
    w_idx = jnp.where(keep, positions % R, R)
    new_k = cache_k.at[w_slot, w_idx].set(k, mode="drop")
    new_v = cache_v.at[w_slot, w_idx].set(v, mode="drop")
    return out, new_k, new_v


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def attn_decode_q8(params, x, cache, pos, *, n_heads, n_kv_heads,
                   d_head, theta, window, softcap=0.0, qk_norm=False,
                   slot=None, extra_mask=None):
    """int8-KV decode — the HALO-faithful memory format (CiD computes int8
    end to end; Section IV-A).  The cache stores int8 values with one f32
    scale per (token, kv-head); BOTH attention contractions run as s8 x s8
    ``dot_general`` (the MXU's native int8 path, = CiD's 8-bit bank MACs):

      scores[s] = (q_q . k_q[s]) * q_scale * k_scale[s]
      out       = (p'_q . v_q)   * p'_scale          with p' = p * v_scale[s]

    folding the per-token v_scale into p BEFORE quantizing keeps the second
    contraction exact up to int8 rounding.  HBM traffic per token: S*(Hkv*Dh
    + 4) bytes per cache side — 2x less than bf16, 4x less than f32.

    cache: {"k": int8 [B,S,Hkv,Dh], "k_scale": f32 [B,S,Hkv], "v", "v_scale"}
    """
    from repro.distributed.policy import constrain
    from repro.serving.quantized_cache import quantize_token

    B = x.shape[0]
    S = cache["k"].shape[1]
    pos_in = jnp.asarray(pos, jnp.int32)
    pos = jnp.broadcast_to(pos_in, (B,))
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           pos[:, None], theta, qk_norm)
    # quantize the new K/V entry (per kv-head) and splice into the arena
    k_q, k_s = quantize_token(k)                       # [B,1,Hkv,Dh],[B,1,Hkv]
    v_q, v_s = quantize_token(v)
    if slot is None:
        slot = pos_in % S if pos_in.ndim == 0 else pos % S
    slot = jnp.asarray(slot, jnp.int32)
    if slot.ndim == 0:
        ck = jax.lax.dynamic_update_slice(cache["k"], k_q, (0, slot, 0, 0))
        cks = jax.lax.dynamic_update_slice(cache["k_scale"], k_s, (0, slot, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v_q, (0, slot, 0, 0))
        cvs = jax.lax.dynamic_update_slice(cache["v_scale"], v_s, (0, slot, 0))
    else:
        bidx = jnp.arange(B)
        ck = cache["k"].at[bidx, slot].set(k_q[:, 0])
        cks = cache["k_scale"].at[bidx, slot].set(k_s[:, 0])
        cv = cache["v"].at[bidx, slot].set(v_q[:, 0])
        cvs = cache["v_scale"].at[bidx, slot].set(v_s[:, 0])

    Hkv = n_kv_heads
    G = n_heads // Hkv
    # quantize q per head
    q_q, q_s = quantize_token(q.reshape(B, Hkv, G, d_head))    # [B,Hkv,G,Dh]
    # s8 x s8 scores: [B,Hkv,G,Dh] . [B,S,Hkv,Dh] -> [B,Hkv,G,S]
    s_i32 = jax.lax.dot_general(
        q_q, ck, (((3,), (3,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.int32)                      # [B,Hkv,G,S]
    scores = (s_i32.astype(jnp.float32)
              * q_s[..., None]
              * cks.transpose(0, 2, 1)[:, :, None, :])
    scores = scores / math.sqrt(d_head)
    scores = _maybe_softcap(scores, softcap)
    slots = jnp.arange(S, dtype=jnp.int32)
    written = slots[None, :] <= pos[:, None]
    wrapped = pos[:, None] >= S
    valid = written | wrapped
    if extra_mask is not None:
        valid = valid & extra_mask
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)                    # [B,Hkv,G,S]
    # fold v_scale into p, re-quantize, s8 x s8 attn_v
    p_scaled = probs * cvs.transpose(0, 2, 1)[:, :, None, :]
    p_q, p_s = quantize_token(p_scaled)                        # scale [B,Hkv,G]
    ctx_i32 = jax.lax.dot_general(
        p_q, cv, (((3,), (1,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.int32)                      # [B,Hkv,G,Dh]
    ctx = ctx_i32.astype(jnp.float32) * p_s[..., None]
    ctx = ctx.reshape(B, 1, n_heads * d_head).astype(x.dtype)
    out = matmul(ctx, params["wo"])
    new_cache = {"k": ck, "k_scale": cks, "v": cv, "v_scale": cvs}
    return out, new_cache


def attn_decode(params, x, cache_k, cache_v, pos, *, n_heads, n_kv_heads,
                d_head, theta, window, softcap=0.0, qk_norm=False,
                slot=None, extra_mask=None):
    """One-token decode against a (possibly ring-buffer) KV cache.

    x: [B, 1, d_model]; cache_k/v: [B, S, Hkv, Dh]; pos: scalar or [B] int32
    (absolute position of the NEW token).  Returns (out [B,1,d], new_k, new_v).

    ``slot`` optionally overrides the physical write index (serving engine
    with right-padded prompts); ``extra_mask`` [B, S] marks additionally
    invalid cache entries (e.g. prompt padding).

    The cache sequence axis S may be sharded across the 'model' mesh axis;
    the softmax over S then lowers to local GEMVs + tiny all-reduces
    (flash-decode semantics via GSPMD).
    """
    B = x.shape[0]
    S = cache_k.shape[1]
    pos_in = jnp.asarray(pos, jnp.int32)
    pos = jnp.broadcast_to(pos_in, (B,))
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, d_head,
                           pos[:, None], theta, qk_norm)
    # ring-buffer slot for the new entry: scalar -> dynamic_update_slice
    # (dry-run / aligned batch), per-batch vector -> scatter (serving engine)
    if slot is None:
        slot = pos_in % S if pos_in.ndim == 0 else pos % S
    slot = jnp.asarray(slot, jnp.int32)
    if slot.ndim == 0:
        ck = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    else:
        bidx = jnp.arange(B)
        ck = cache_k.at[bidx, slot].set(k[:, 0])
        cv = cache_v.at[bidx, slot].set(v[:, 0])
    from repro.distributed.policy import constrain
    ck = constrain(ck, "kv_bshd")
    cv = constrain(cv, "kv_bshd")

    Hkv = n_kv_heads
    G = n_heads // Hkv
    qg = q.reshape(B, Hkv, G, d_head)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, ck,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(d_head)
    scores = _maybe_softcap(scores, softcap)
    # validity: slot s was written iff s <= pos (before wrap) else always.
    slots = jnp.arange(S, dtype=jnp.int32)
    written = slots[None, :] <= pos[:, None]
    wrapped = pos[:, None] >= S
    valid = written | wrapped
    if extra_mask is not None:
        valid = valid & extra_mask
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgs,bshd->bhgd", probs.astype(cv.dtype), cv,
                     preferred_element_type=jnp.float32)
    ctx = ctx.reshape(B, 1, n_heads * d_head).astype(x.dtype)
    out = matmul(ctx, params["wo"])
    return out, ck, cv
