"""Model assembly: config -> (init, train-forward, prefill, decode_step).

The layer stack is compiled as a sequence of RUNS: maximal contiguous groups
of layers with identical block structure (kind, ffn kind, window, rope theta).
Each run is executed with a single ``jax.lax.scan`` over its stacked
parameters, which keeps compile time O(#distinct-run-shapes) instead of
O(n_layers) — essential for dry-running 60-80 layer configs on 512 host
devices.  Examples:

  qwen3-1.7b      -> 1 run  (28 x attn+dense)
  gemma3-1b       -> 9 runs (5 local | 1 global | ... | 2 local)
  deepseek-v2     -> 2 runs (1 x mla+dense | 59 x mla+moe)
  mamba2-2.7b     -> 1 run  (64 x ssd)
  zamba2-2.7b     -> 18 runs (9 x [6 ssd | shared-attn]); the shared attention
                     block's weights are stored ONCE and reused per invocation.

Caches are a list aligned with the runs; windowed-attention runs allocate a
ring buffer of length min(window, seq), SSD runs a constant-size recurrent
state, MLA runs a compressed-latent cache.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.policy import constrain
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    embed_init,
    ffn,
    ffn_init,
    matmul,
    rmsnorm,
    rmsnorm_init,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# run plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunSpec:
    kind: str                 # "attn" | "ssm" | "shared_attn"
    n_layers: int             # 0 for shared_attn
    ffn_kind: str = "dense"   # "dense" | "moe" | "none"
    window: int = 0           # 0 = full attention
    theta: float = 10000.0
    layer_start: int = 0      # first absolute layer index of this run


def build_plan(cfg: ModelConfig) -> List[RunSpec]:
    runs: List[RunSpec] = []
    if cfg.family == "hybrid":
        h = cfg.hybrid
        n_groups = cfg.n_layers // h.shared_attn_every
        for g in range(n_groups):
            runs.append(RunSpec("ssm", h.shared_attn_every, "none",
                                layer_start=g * h.shared_attn_every))
            runs.append(RunSpec("shared_attn", 0, "none"))
        rem = cfg.n_layers - n_groups * h.shared_attn_every
        if rem:
            runs.append(RunSpec("ssm", rem, "none",
                                layer_start=n_groups * h.shared_attn_every))
        return runs

    kinds = cfg.layer_kinds()

    def sig(i: int) -> Tuple[str, str]:
        k = kinds[i]
        f = "none" if (k == "ssm" and cfg.d_ff == 0) else cfg.ffn_kind(i)
        return (k, f)

    i = 0
    while i < cfg.n_layers:
        kind, ffn_kind = sig(i)
        j = i
        while j < cfg.n_layers and sig(j) == (kind, ffn_kind):
            j += 1
        window = 0
        theta = cfg.attn.rope_theta
        if kind == "attn_local":
            window = cfg.attn.sliding_window
            if cfg.attn.rope_local_theta:
                theta = cfg.attn.rope_local_theta
        runs.append(RunSpec("attn" if kind.startswith("attn") else "ssm",
                            j - i, ffn_kind, window, theta, layer_start=i))
        i = j
    return runs


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack(key, n: int, init_fn):
    """Stack n independently-initialized param trees along axis 0."""
    keys = jax.random.split(key, n)
    trees = [init_fn(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs, 0), *trees)


def padded_vocab(cfg: ModelConfig) -> int:
    return -(-cfg.vocab_size // 256) * 256


def _layer_init(key, cfg: ModelConfig, run: RunSpec):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": rmsnorm_init(d, dtype)}
    if run.kind == "attn":
        if cfg.mla.enabled:
            p["attn"] = mla_mod.mla_init(ks[0], d, cfg.n_heads, cfg.mla, dtype)
        else:
            p["attn"] = attn_mod.attn_init(
                ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, dtype,
                qk_norm=cfg.attn.qk_norm)
        p["ln2"] = rmsnorm_init(d, dtype)
        if run.ffn_kind == "moe":
            p["moe"] = moe_mod.moe_init(ks[1], d, cfg.moe, dtype)
        elif run.ffn_kind == "dense":
            p["ffn"] = ffn_init(ks[1], d, cfg.d_ff, dtype)
    elif run.kind == "ssm":
        p["ssm"] = ssm_mod.ssm_init(ks[0], d, cfg.ssm, dtype)
        if run.ffn_kind == "dense":
            p["ln2"] = rmsnorm_init(d, dtype)
            p["ffn"] = ffn_init(ks[1], d, cfg.d_ff, dtype)
    return p


def _shared_attn_init(key, cfg: ModelConfig):
    """Zamba2 shared transformer block operating on concat([h, embed])."""
    dtype = jnp.dtype(cfg.dtype)
    h = cfg.hybrid
    d_in = cfg.d_model * (2 if h.concat_embedding else 1)
    nh = h.shared_attn_n_heads
    ks = jax.random.split(key, 4)
    return {
        "ln1": rmsnorm_init(d_in, dtype),
        "attn": attn_mod.attn_init(ks[0], d_in, nh, nh, d_in // nh, dtype,
                                   d_model_out=d_in),
        "ln2": rmsnorm_init(d_in, dtype),
        "ffn": ffn_init(ks[1], d_in, cfg.d_ff, dtype),
        "down": jax.random.normal(ks[2], (d_in, cfg.d_model), jnp.float32)
                .astype(dtype) / (d_in ** 0.5),
    }


def init_params(key, cfg: ModelConfig) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    V = padded_vocab(cfg)
    plan = build_plan(cfg)
    ks = jax.random.split(key, len(plan) + 4)
    params: Params = {}
    if cfg.n_codebooks > 1:
        params["embed"] = _stack(
            ks[-1], cfg.n_codebooks,
            lambda k: embed_init(k, V, cfg.d_model, dtype))      # [K,V,d]
    else:
        params["embed"] = embed_init(ks[-1], V, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks > 1:
            params["lm_head"] = _stack(
                ks[-2], cfg.n_codebooks,
                lambda k: embed_init(k, V, cfg.d_model, dtype).T)
        else:
            params["lm_head"] = embed_init(ks[-2], V, cfg.d_model, dtype).T
    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    runs_params: List[Params] = []
    shared_done = False
    for r, run in enumerate(plan):
        if run.kind == "shared_attn":
            if not shared_done:
                params["shared_attn"] = _shared_attn_init(ks[r], cfg)
                shared_done = True
            runs_params.append({})                              # weights shared
        else:
            runs_params.append(
                _stack(ks[r], run.n_layers,
                       lambda k, run=run: _layer_init(k, cfg, run)))
    params["runs"] = runs_params
    return params


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def cache_len(run: RunSpec, seq_len: int) -> int:
    if run.window > 0:
        return min(run.window, seq_len)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> List[Any]:
    """Allocate the decode cache for every run (zeros)."""
    dtype = jnp.dtype(cfg.dtype)
    caches: List[Any] = []
    for run in build_plan(cfg):
        if run.kind == "attn":
            S = cache_len(run, seq_len)
            if cfg.mla.enabled:
                w = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                caches.append({"latent": jnp.zeros(
                    (run.n_layers, batch, S, w), dtype)})
            else:
                shape = (run.n_layers, batch, S, cfg.n_kv_heads, cfg.d_head)
                caches.append({"k": jnp.zeros(shape, dtype),
                               "v": jnp.zeros(shape, dtype)})
        elif run.kind == "ssm":
            s = cfg.ssm
            di = s.d_inner(cfg.d_model)
            nh = s.n_heads(cfg.d_model)
            conv_dim = di + 2 * s.n_groups * s.d_state
            caches.append({
                "conv": jnp.zeros((run.n_layers, batch, s.d_conv - 1, conv_dim), dtype),
                "state": jnp.zeros((run.n_layers, batch, nh, s.head_dim, s.d_state),
                                   jnp.float32),
            })
        else:  # shared_attn
            h = cfg.hybrid
            d_in = cfg.d_model * (2 if h.concat_embedding else 1)
            dh = d_in // h.shared_attn_n_heads
            shape = (batch, seq_len, h.shared_attn_n_heads, dh)
            caches.append({"k": jnp.zeros(shape, dtype),
                           "v": jnp.zeros(shape, dtype)})
    return caches


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> List[Any]:
    """ShapeDtypeStruct tree mirroring init_cache (dry-run, no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, seq_len))


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens):
    """tokens: [B,T] or [B,K,T] (musicgen).  Returns [B,T,d]."""
    if cfg.n_codebooks > 1:
        # sum of per-codebook embeddings (gather per codebook)
        out = 0.0
        for k in range(cfg.n_codebooks):
            out = out + jnp.take(params["embed"][k], tokens[:, k], axis=0)
        return out.astype(jnp.dtype(cfg.dtype))
    return jnp.take(params["embed"], tokens, axis=0)


def lm_logits(params, cfg: ModelConfig, h):
    """h: [B,T,d] -> [B,T,V] (or [B,T,K,V])."""
    if cfg.tie_embeddings:
        table = params["embed"]
        if cfg.n_codebooks > 1:
            return jnp.einsum("btd,kvd->btkv", h, table,
                              preferred_element_type=jnp.float32)
        return jnp.einsum("btd,vd->btv", h, table,
                          preferred_element_type=jnp.float32)
    head = params["lm_head"]
    if cfg.n_codebooks > 1:
        return jnp.einsum("btd,kdv->btkv", h, head,
                          preferred_element_type=jnp.float32)
    return jnp.einsum("btd,dv->btv", h, head,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# run bodies
# ---------------------------------------------------------------------------

def _attn_layer_prefill(cfg, run, lp, x, positions, want_cache: bool):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla.enabled:
        a, cache = mla_mod.mla_prefill(lp["attn"], h, positions,
                                       n_heads=cfg.n_heads, m=cfg.mla)
        kv = (cache,)
    else:
        a, (k, v) = attn_mod.attn_prefill(
            lp["attn"], h, positions,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            theta=run.theta, window=jnp.int32(run.window),
            softcap=cfg.attn.logit_softcap, qk_norm=cfg.attn.qk_norm)
        kv = (k, v)
    x = x + a
    aux = jnp.float32(0.0)
    if run.ffn_kind == "moe":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        f, aux = moe_mod.moe_apply(lp["moe"], h, cfg.moe, cfg.act)
        x = x + f
    elif run.ffn_kind == "dense":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, cfg.act)
    if not want_cache:
        kv = None
    return x, kv, aux


def _attn_layer_decode(cfg, run, lp, x, cache, pos):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla.enabled:
        a, latent = mla_mod.mla_decode(lp["attn"], h, cache["latent"], pos,
                                       n_heads=cfg.n_heads, m=cfg.mla)
        new_cache = {"latent": latent}
    elif "k_scale" in cache:
        # int8 KV arena (HALO-faithful decode format, serving engine opt-in)
        a, new_cache = attn_mod.attn_decode_q8(
            lp["attn"], h, cache, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            theta=run.theta, window=jnp.int32(run.window),
            softcap=cfg.attn.logit_softcap, qk_norm=cfg.attn.qk_norm)
        x = x + a
        if run.ffn_kind == "moe":
            h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            f, _ = moe_mod.moe_apply(lp["moe"], h, cfg.moe, cfg.act)
            x = x + f
        elif run.ffn_kind == "dense":
            h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
            x = x + ffn(lp["ffn"], h, cfg.act)
        return x, new_cache
    else:
        a, ck, cv = attn_mod.attn_decode(
            lp["attn"], h, cache["k"], cache["v"], pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            theta=run.theta, window=jnp.int32(run.window),
            softcap=cfg.attn.logit_softcap, qk_norm=cfg.attn.qk_norm)
        new_cache = {"k": ck, "v": cv}
    x = x + a
    if run.ffn_kind == "moe":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        f, _ = moe_mod.moe_apply(lp["moe"], h, cfg.moe, cfg.act)
        x = x + f
    elif run.ffn_kind == "dense":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, cfg.act)
    return x, new_cache


def _attn_layer_decode_paged(cfg, run, lp, x, cache, bt, pos):
    """One attention layer of a paged one-token decode step.

    ``cache`` is the run's page pool slice ([n_pages, P, ...] leaves) and
    ``bt`` the [B, W] block table; per-slot validity is encoded in the
    table (inactive slots carry all-sentinel rows), so no merge-with-mask
    pass is needed — dropped scatters ARE the mask.
    """
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla.enabled:
        if "latent_scale" in cache:
            a, latent, lscale = mla_mod.mla_decode_paged(
                lp["attn"], h, cache["latent"], bt, pos,
                n_heads=cfg.n_heads, m=cfg.mla,
                scales=cache["latent_scale"])
            new_cache = {"latent": latent, "latent_scale": lscale}
        else:
            a, latent = mla_mod.mla_decode_paged(
                lp["attn"], h, cache["latent"], bt, pos,
                n_heads=cfg.n_heads, m=cfg.mla)
            new_cache = {"latent": latent}
    elif "k_scale" in cache:
        # quantized pools: int8 pages, or packed-int4 (uint8 nibble pairs)
        q_decode = (attn_mod.attn_decode_q4_paged
                    if cache["k"].dtype == jnp.uint8
                    else attn_mod.attn_decode_q8_paged)
        a, new_cache = q_decode(
            lp["attn"], h, cache, bt, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            theta=run.theta, window=run.window,
            softcap=cfg.attn.logit_softcap, qk_norm=cfg.attn.qk_norm)
    else:
        a, new_cache = attn_mod.attn_decode_paged(
            lp["attn"], h, cache, bt, pos,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            theta=run.theta, window=run.window,
            softcap=cfg.attn.logit_softcap, qk_norm=cfg.attn.qk_norm)
    x = x + a
    if run.ffn_kind == "moe":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        f, _ = moe_mod.moe_apply(lp["moe"], h, cfg.moe, cfg.act)
        x = x + f
    elif run.ffn_kind == "dense":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, cfg.act)
    return x, new_cache


def _attn_layer_chunk(cfg, run, lp, x, offsets, lengths, slots, cache):
    """One attention layer of a packed prefill chunk (arena-direct write)."""
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla.enabled:
        a, latent = mla_mod.mla_chunk(lp["attn"], h, offsets, lengths, slots,
                                      cache["latent"],
                                      n_heads=cfg.n_heads, m=cfg.mla)
        new_cache = {"latent": latent}
    else:
        a, ck, cv = attn_mod.attn_chunk(
            lp["attn"], h, offsets, lengths, slots, cache["k"], cache["v"],
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            theta=run.theta, window=jnp.int32(run.window),
            softcap=cfg.attn.logit_softcap, qk_norm=cfg.attn.qk_norm)
        new_cache = {"k": ck, "v": cv}
    x = x + a
    if run.ffn_kind == "moe":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        f, _ = moe_mod.moe_apply(lp["moe"], h, cfg.moe, cfg.act)
        x = x + f
    elif run.ffn_kind == "dense":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, cfg.act)
    return x, new_cache


def _attn_layer_chunk_paged(cfg, run, lp, x, offsets, lengths, slots, cache,
                            bt):
    """One attention layer of a packed prefill chunk against the page pool."""
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla.enabled:
        if "latent_scale" in cache:
            a, latent, lscale = mla_mod.mla_chunk_paged(
                lp["attn"], h, offsets, lengths, slots, cache["latent"], bt,
                n_heads=cfg.n_heads, m=cfg.mla,
                scales=cache["latent_scale"])
            new_cache = {"latent": latent, "latent_scale": lscale}
        else:
            a, latent = mla_mod.mla_chunk_paged(
                lp["attn"], h, offsets, lengths, slots, cache["latent"], bt,
                n_heads=cfg.n_heads, m=cfg.mla)
            new_cache = {"latent": latent}
    else:
        a, new_cache = attn_mod.attn_chunk_paged(
            lp["attn"], h, offsets, lengths, slots, cache, bt,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            theta=run.theta, window=run.window,
            softcap=cfg.attn.logit_softcap, qk_norm=cfg.attn.qk_norm)
    x = x + a
    if run.ffn_kind == "moe":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        f, _ = moe_mod.moe_apply(lp["moe"], h, cfg.moe, cfg.act)
        x = x + f
    elif run.ffn_kind == "dense":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, cfg.act)
    return x, new_cache


def _attn_layer_chunk_packed(cfg, run, lp, x, seg, cache, pack_align):
    """One attention layer of a PACKED prefill stream (arena-direct)."""
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla.enabled:
        a, latent = mla_mod.mla_chunk_packed(lp["attn"], h, seg,
                                             cache["latent"],
                                             n_heads=cfg.n_heads, m=cfg.mla)
        new_cache = {"latent": latent}
    else:
        a, ck, cv = attn_mod.attn_chunk_packed(
            lp["attn"], h, seg, cache["k"], cache["v"],
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            theta=run.theta, window=run.window,
            softcap=cfg.attn.logit_softcap, qk_norm=cfg.attn.qk_norm,
            pack_align=pack_align)
        new_cache = {"k": ck, "v": cv}
    x = x + a
    if run.ffn_kind == "moe":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        f, _ = moe_mod.moe_apply(lp["moe"], h, cfg.moe, cfg.act)
        x = x + f
    elif run.ffn_kind == "dense":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, cfg.act)
    return x, new_cache


def _attn_layer_chunk_packed_paged(cfg, run, lp, x, seg, cache, bt,
                                   pack_align):
    """One attention layer of a PACKED prefill stream against the page pool."""
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla.enabled:
        if "latent_scale" in cache:
            a, latent, lscale = mla_mod.mla_chunk_packed_paged(
                lp["attn"], h, seg, cache["latent"], bt,
                n_heads=cfg.n_heads, m=cfg.mla,
                scales=cache["latent_scale"])
            new_cache = {"latent": latent, "latent_scale": lscale}
        else:
            a, latent = mla_mod.mla_chunk_packed_paged(
                lp["attn"], h, seg, cache["latent"], bt,
                n_heads=cfg.n_heads, m=cfg.mla)
            new_cache = {"latent": latent}
    else:
        a, new_cache = attn_mod.attn_chunk_packed_paged(
            lp["attn"], h, seg, cache, bt,
            n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
            theta=run.theta, window=run.window,
            softcap=cfg.attn.logit_softcap, qk_norm=cfg.attn.qk_norm,
            pack_align=pack_align)
    x = x + a
    if run.ffn_kind == "moe":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        f, _ = moe_mod.moe_apply(lp["moe"], h, cfg.moe, cfg.act)
        x = x + f
    elif run.ffn_kind == "dense":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, cfg.act)
    return x, new_cache


def _ssm_layer_prefill(cfg, run, lp, x, want_cache: bool):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    o, (conv_state, state) = ssm_mod.ssm_prefill(lp["ssm"], h, cfg.d_model, cfg.ssm)
    x = x + o
    if run.ffn_kind == "dense":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, cfg.act)
    return x, ((conv_state, state) if want_cache else None)


def _ssm_layer_decode(cfg, run, lp, x, cache, pos):
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    o, conv_state, state = ssm_mod.ssm_decode(
        lp["ssm"], h, cache["conv"], cache["state"], cfg.d_model, cfg.ssm)
    x = x + o
    if run.ffn_kind == "dense":
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + ffn(lp["ffn"], h, cfg.act)
    return x, {"conv": conv_state, "state": state}


def _shared_attn_apply(cfg, sp, x, embed0, positions, cache, pos, phase: str):
    """Zamba2 shared block.  Returns (x, new_cache)."""
    h = cfg.hybrid
    inp = jnp.concatenate([x, embed0], axis=-1) if h.concat_embedding else x
    d_in = inp.shape[-1]
    nh = h.shared_attn_n_heads
    dh = d_in // nh
    y = rmsnorm(sp["ln1"], inp, cfg.norm_eps)
    if phase == "decode":
        a, ck, cv = attn_mod.attn_decode(
            sp["attn"], y, cache["k"], cache["v"], pos,
            n_heads=nh, n_kv_heads=nh, d_head=dh,
            theta=cfg.attn.rope_theta, window=jnp.int32(0))
        new_cache = {"k": ck, "v": cv}
    else:
        a, (k, v) = attn_mod.attn_prefill(
            sp["attn"], y, positions,
            n_heads=nh, n_kv_heads=nh, d_head=dh,
            theta=cfg.attn.rope_theta, window=jnp.int32(0))
        new_cache = {"k": k, "v": v}
    inp = inp + a
    y = rmsnorm(sp["ln2"], inp, cfg.norm_eps)
    inp = inp + ffn(sp["ffn"], y, cfg.act)
    x = x + matmul(inp, sp["down"])
    return x, new_cache


# ---------------------------------------------------------------------------
# full-model passes
# ---------------------------------------------------------------------------

def forward(params: Params, cfg: ModelConfig, batch: Dict[str, Any],
            *, phase: str = "train", cache: Optional[List[Any]] = None,
            pos=None, remat: bool = False, return_hidden: bool = False,
            block_tables: Optional[List[Any]] = None):
    """Unified forward.

    phase == "train"/"prefill": batch["tokens"] [B,T] (or [B,K,T]); optional
        batch["vision_embeds"] [B,F,d].  Returns (logits, new_cache, aux_loss);
        new_cache is None for train.
    phase == "decode": batch["tokens"] [B,1] (or [B,K,1]); ``cache`` and
        ``pos`` required.  Returns (logits [B,1,...], new_cache, 0.0).
        With ``block_tables`` (one [B, W] table per run) ``cache`` is the
        PAGED pool from ``serving.kv_pool.KVPool`` and decode routes
        through the paged attention paths (requires ``supports_paged``).
    """
    plan = build_plan(cfg)
    want_cache = phase == "prefill"
    x = embed_tokens(params, cfg, batch["tokens"])
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([ve, x], axis=1)
    x = constrain(x, "act_btd")
    B, T = x.shape[0], x.shape[1]
    if phase == "decode":
        positions = None
    else:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    embed0 = x if cfg.hybrid.enabled else None
    aux_total = jnp.float32(0.0)
    new_caches: List[Any] = []

    for r, run in enumerate(plan):
        rp = params["runs"][r]
        if run.kind == "shared_attn":
            c = cache[r] if cache is not None else None
            x, nc = _shared_attn_apply(cfg, params["shared_attn"], x, embed0,
                                       positions, c, pos, phase)
            new_caches.append(nc if (want_cache or phase == "decode") else None)
            continue

        if phase == "decode":
            c = cache[r]
            if run.kind == "attn" and block_tables is not None:
                bt = block_tables[r]

                def body(carry, xs, run=run, bt=bt):
                    xx, _ = carry
                    lp, lc = xs
                    xx, nc = _attn_layer_decode_paged(cfg, run, lp, xx, lc,
                                                      bt, pos)
                    return (xx, None), nc
            elif run.kind == "attn":
                def body(carry, xs, run=run):
                    xx, _ = carry
                    lp, lc = xs
                    xx, nc = _attn_layer_decode(cfg, run, lp, xx, lc, pos)
                    return (xx, None), nc
            else:
                def body(carry, xs, run=run):
                    xx, _ = carry
                    lp, lc = xs
                    xx, nc = _ssm_layer_decode(cfg, run, lp, xx, lc, pos)
                    return (xx, None), nc
            (x, _), ys = jax.lax.scan(body, (x, None), (rp, c))
            new_caches.append(ys)
        else:
            if run.kind == "attn":
                def body(carry, xs, run=run):
                    xx, _ = carry
                    (lp,) = xs
                    xx, kv, aux = _attn_layer_prefill(cfg, run, lp, xx,
                                                      positions, want_cache)
                    return (xx, None), (kv, aux)
            else:
                def body(carry, xs, run=run):
                    xx, _ = carry
                    (lp,) = xs
                    xx, kv = _ssm_layer_prefill(cfg, run, lp, xx, want_cache)
                    return (xx, None), (kv, jnp.float32(0.0))
            b = body
            if remat:
                b = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            (x, _), (kvs, auxs) = jax.lax.scan(b, (x, None), (rp,))
            aux_total = aux_total + jnp.sum(auxs)
            if want_cache:
                new_caches.append(_pack_prefill_cache(cfg, run, kvs, T))
            else:
                new_caches.append(None)
        x = constrain(x, "act_btd")

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        # caller applies the LM head itself (chunked cross-entropy path)
        out_cache = new_caches if (want_cache or phase == "decode") else None
        return x, out_cache, aux_total
    if phase == "prefill":
        # only the last position's logits are needed to start decoding
        logits = lm_logits(params, cfg, x[:, -1:, :])
    else:
        logits = lm_logits(params, cfg, x)
    out_cache = new_caches if (want_cache or phase == "decode") else None
    return logits, out_cache, aux_total


def _pack_prefill_cache(cfg: ModelConfig, run: RunSpec, kvs, T: int):
    """Convert scan-stacked prefill K/V into the decode cache layout.

    Windowed runs keep the last ``min(W, T)`` entries, rolled so slot ``s``
    holds the position with ``pos % W == s`` — consistent with the decode
    ring buffer for any T.
    """
    S = cache_len(run, T)

    def trim(x, axis=2):
        x = jax.lax.slice_in_dim(x, x.shape[axis] - S, x.shape[axis], axis=axis)
        if run.window > 0 and T > S and T % S != 0:
            x = jnp.roll(x, shift=T % S, axis=axis)
        return x

    if run.kind == "attn" and cfg.mla.enabled:
        (latent,) = kvs
        return {"latent": trim(latent)}
    if run.kind == "attn":
        k, v = kvs
        return {"k": trim(k), "v": trim(v)}
    conv_state, state = kvs
    return {"conv": conv_state, "state": state}


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """True iff every run can prefill incrementally against the decode arena.

    Attention runs (GQA, sliding-window, MLA) replay their history from the
    arena KV, so a prompt can be fed in chunks.  SSM and shared-attention
    runs carry a recurrent / rolled state across positions that
    ``ssm_prefill`` cannot currently resume from — those plans fall back to
    whole-prompt prefill (still arena-direct via ``prefill_into_arena``).
    """
    return all(run.kind == "attn" for run in build_plan(cfg))


def supports_paged(cfg: ModelConfig) -> bool:
    """True iff every run can live in the paged block-pool KV arena.

    Attention runs (GQA, sliding-window, MLA) index their cache by
    position, so positions can be relocated onto pages through a block
    table.  SSM and shared-attention runs carry recurrent / whole-sequence
    state that has no per-position granularity — those plans keep the
    dense arena."""
    return all(run.kind == "attn" for run in build_plan(cfg))


def forward_chunk(params: Params, cfg: ModelConfig, tokens, offsets,
                  lengths, slots, cache: List[Any],
                  block_tables: Optional[List[Any]] = None,
                  return_all_logits: bool = False):
    """Packed chunked prefill, writing K/V directly into the decode arena.

    tokens: [N, C] (or [N, K, C] multi-codebook) — N chunk rows padded to C
    tokens; row ``n`` holds prompt tokens [offsets[n], offsets[n]+lengths[n])
    of the request in arena slot ``slots[n]``.  ``cache`` is the full decode
    arena from ``init_cache(cfg, B, S)``; rows other than the addressed
    slots are untouched (padded rows scatter out of bounds and drop).
    With ``block_tables`` the arena is the PAGED pool (serving.kv_pool)
    and writes route through the per-run block tables instead.

    Returns (last_logits [N, 1, ...], new_cache): the logits of each row's
    last valid position — only meaningful for rows whose chunk completes
    the prompt.  With ``return_all_logits`` the logits of EVERY window
    position come back instead ([N, C, ...]) — the speculative-decode
    verify program scores each draft token against the position that
    predicts it (see serving/speculative.py); positions past a row's
    ``lengths`` are garbage the caller must mask.  Requires
    ``supports_chunked_prefill(cfg)``.
    """
    plan = build_plan(cfg)
    x = embed_tokens(params, cfg, tokens)
    x = constrain(x, "act_btd")
    N = x.shape[0]
    lengths = jnp.asarray(lengths, jnp.int32)
    new_caches: List[Any] = []
    for r, run in enumerate(plan):
        if run.kind != "attn":
            raise NotImplementedError(
                f"chunked prefill over {run.kind!r} runs; gate on "
                "supports_chunked_prefill() and use prefill_into_arena()")
        rp = params["runs"][r]
        bt = block_tables[r] if block_tables is not None else None

        def body(carry, xs, run=run, bt=bt):
            xx, _ = carry
            lp, lc = xs
            if bt is None:
                xx, nc = _attn_layer_chunk(cfg, run, lp, xx, offsets,
                                           lengths, slots, lc)
            else:
                xx, nc = _attn_layer_chunk_paged(cfg, run, lp, xx, offsets,
                                                 lengths, slots, lc, bt)
            return (xx, None), nc

        (x, _), ys = jax.lax.scan(body, (x, None), (rp, cache[r]))
        new_caches.append(ys)
        x = constrain(x, "act_btd")
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if return_all_logits:
        return lm_logits(params, cfg, x), new_caches             # [N, C, ...]
    last = jnp.clip(lengths - 1, 0, x.shape[1] - 1)
    h_last = x[jnp.arange(N), last][:, None, :]                  # [N, 1, d]
    return lm_logits(params, cfg, h_last), new_caches


def forward_chunk_packed(params: Params, cfg: ModelConfig, tokens, starts,
                         offsets, lengths, slots, cache: List[Any],
                         block_tables: Optional[List[Any]] = None,
                         pack_align: int = 8):
    """PACKED chunked prefill: one flat token stream instead of [N, C] rows.

    tokens: [T] — N segments (one per request chunk) laid out back to back
    at ``starts`` [N] (non-decreasing, aligned to ``pack_align``; pad
    segments carry start == T).  Segment ``n`` holds prompt tokens
    [offsets[n], offsets[n]+lengths[n]) of the request in arena slot
    ``slots[n]``.  Only inter-segment alignment slack plus the final
    pow2-bucket tail is padding — mixed-length chunk batches no longer pay
    max-length padding on every row (the packing-prefetch scheduler shape;
    HALO keeps CiM prefill utilization high the same way).

    Returns (last_logits [N, 1, V], new_cache): logits of each segment's
    last valid position — meaningful only for segments completing their
    prompt, like ``forward_chunk``.  Requires supports_chunked_prefill()
    and a single codebook (packed streams are [T], not [K, T]).
    """
    if cfg.n_codebooks > 1:
        raise NotImplementedError("packed prefill is single-codebook only")
    plan = build_plan(cfg)
    tokens = jnp.asarray(tokens, jnp.int32)
    T = tokens.shape[-1]
    x = embed_tokens(params, cfg, tokens[None])                  # [1, T, d]
    x = constrain(x, "act_btd")
    seg = attn_mod.make_packed_segs(starts, offsets, lengths, slots, T)
    new_caches: List[Any] = []
    for r, run in enumerate(plan):
        if run.kind != "attn":
            raise NotImplementedError(
                f"packed prefill over {run.kind!r} runs; gate on "
                "supports_chunked_prefill()")
        rp = params["runs"][r]
        bt = block_tables[r] if block_tables is not None else None

        def body(carry, xs, run=run, bt=bt):
            xx, _ = carry
            lp, lc = xs
            if bt is None:
                xx, nc = _attn_layer_chunk_packed(cfg, run, lp, xx, seg,
                                                  lc, pack_align)
            else:
                xx, nc = _attn_layer_chunk_packed_paged(cfg, run, lp, xx,
                                                        seg, lc, bt,
                                                        pack_align)
            return (xx, None), nc

        (x, _), ys = jax.lax.scan(body, (x, None), (rp, cache[r]))
        new_caches.append(ys)
        x = constrain(x, "act_btd")
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    last = jnp.clip(seg.starts + seg.lengths - 1, 0, T - 1)      # [N]
    h_last = x[0, last][:, None, :]                              # [N, 1, d]
    return lm_logits(params, cfg, h_last), new_caches


def splice_arena(cfg: ModelConfig, cache: List[Any], piece: List[Any],
                 slot) -> List[Any]:
    """Write a single-request prefill cache (batch=1 ``piece``) into arena
    slot ``slot`` — the CiM -> CiD handoff for run families that cannot
    chunk (SSM recurrent state, shared attention).  Pure jnp (traceable
    ``slot``), so the whole handoff stays inside one jitted program.

    Attention pieces arrive from ``_pack_prefill_cache`` already trimmed /
    rolled to ring order; the last ``min(P, R)`` entries land in arena
    positions [0, pl) exactly as the decode ring expects.
    """
    plan = build_plan(cfg)
    slot = jnp.asarray(slot, jnp.int32)
    out: List[Any] = []
    for run, arena, p in zip(plan, cache, piece):
        if run.kind == "ssm":
            upd = {}
            for key in arena:
                starts = (0, slot) + (0,) * (arena[key].ndim - 2)
                upd[key] = jax.lax.dynamic_update_slice(
                    arena[key], p[key].astype(arena[key].dtype), starts)
            out.append(upd)
            continue
        d: Dict[str, Any] = {}
        for key in arena:
            a, pc = arena[key], p[key]
            # attn caches: [L, B, S, ...] (batch=1, seq=2);
            # shared_attn:  [B, S, ...]   (batch=0, seq=1)
            b_ax, ax = (1, 2) if run.kind == "attn" else (0, 1)
            pl = min(pc.shape[ax], a.shape[ax])
            pc = jax.lax.slice_in_dim(pc, pc.shape[ax] - pl, pc.shape[ax],
                                      axis=ax)
            starts = tuple(slot if i == b_ax else 0 for i in range(a.ndim))
            d[key] = jax.lax.dynamic_update_slice(
                a, pc.astype(a.dtype), starts)
        out.append(d)
    return out


def prefill_into_arena(params: Params, cfg: ModelConfig, batch, slot,
                       cache: List[Any]):
    """Whole-prompt prefill + arena splice as ONE jitted program (no
    host-side cache surgery).  Returns (last_logits [1, 1, ...], new_cache)."""
    logits, piece, _ = forward(params, cfg, batch, phase="prefill")
    return logits, splice_arena(cfg, cache, piece, slot)


def pad_cache(cfg: ModelConfig, cache: List[Any], prompt_len: int,
              max_len: int) -> List[Any]:
    """Grow a prefill cache (length == prompt_len) to ``max_len`` slots so
    decoding can append.  Windowed runs stay at ring size min(W, max_len);
    SSM states are length-independent."""
    plan = build_plan(cfg)
    out = []
    for run, c in zip(plan, cache):
        if run.kind == "ssm" or c is None:
            out.append(c)
            continue
        target = cache_len(run, max_len)

        def grow(x, axis=2 if run.kind == "attn" else 1):
            axis_ = 2 if run.kind == "attn" else 1
            cur = x.shape[axis_]
            if cur >= target:
                return x
            pad = [(0, 0)] * x.ndim
            pad[axis_] = (0, target - cur)
            return jnp.pad(x, pad)

        out.append(jax.tree.map(grow, c))
    return out


# convenience wrappers ------------------------------------------------------

def forward_train(params, cfg, batch, remat: bool = True):
    logits, _, aux = forward(params, cfg, batch, phase="train", remat=remat)
    return logits, aux


def prefill(params, cfg, batch):
    logits, cache, _ = forward(params, cfg, batch, phase="prefill")
    return logits, cache


def decode_step(params, cfg, batch, cache, pos):
    logits, cache, _ = forward(params, cfg, batch, phase="decode",
                               cache=cache, pos=pos)
    return logits, cache
