"""DeepSeek-V2 Multi-head Latent Attention (MLA).

Prefill materializes per-head K/V from the compressed latent (compute-bound
GEMMs -> HALO's CiM path).  Decode uses the *absorbed* formulation: only the
latent c_kv [B, S, r] and the shared rope-key [B, S, dr] are cached, and the
per-head up-projections W_UK / W_UV are folded into the query / output sides.
Per decoded token this is a pure GEMV sweep over the latent cache — exactly
the memory-bound shape HALO maps to CiD.

Cache layout: [B, S, r + dr] so the S axis can be sequence-sharded over the
'model' mesh axis like the plain GQA cache.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.attention import NEG_INF
from repro.models.layers import apply_rope, dense_init, matmul, rmsnorm, rmsnorm_init


def mla_init(key, d_model: int, n_heads: int, m: MLAConfig, dtype):
    ks = jax.random.split(key, 8)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    p: Dict[str, Any] = {}
    if m.q_lora_rank > 0:
        p["wq_a"] = dense_init(ks[0], d_model, m.q_lora_rank, dtype)
        p["q_norm"] = rmsnorm_init(m.q_lora_rank, dtype)
        p["wq_b"] = dense_init(ks[1], m.q_lora_rank, n_heads * qk_dim, dtype)
    else:
        p["wq"] = dense_init(ks[0], d_model, n_heads * qk_dim, dtype)
    # joint KV down-projection: latent r + shared rope key dr
    p["wkv_a"] = dense_init(ks[2], d_model, m.kv_lora_rank + m.qk_rope_head_dim, dtype)
    p["kv_norm"] = rmsnorm_init(m.kv_lora_rank, dtype)
    # up-projections kept per-head for absorption: [H, r, nope] / [H, r, v]
    wkv_b = dense_init(
        ks[3], m.kv_lora_rank,
        n_heads * (m.qk_nope_head_dim + m.v_head_dim), dtype)
    wkv_b = wkv_b.reshape(m.kv_lora_rank, n_heads, m.qk_nope_head_dim + m.v_head_dim)
    p["w_uk"] = wkv_b[:, :, : m.qk_nope_head_dim].transpose(1, 0, 2)  # [H, r, nope]
    p["w_uv"] = wkv_b[:, :, m.qk_nope_head_dim:].transpose(1, 0, 2)   # [H, r, v]
    p["wo"] = dense_init(ks[4], n_heads * m.v_head_dim, d_model, dtype)
    return p


def _queries(params, x, n_heads, m: MLAConfig, positions):
    B, T, _ = x.shape
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if "wq_a" in params:
        ql = matmul(x, params["wq_a"])
        ql = rmsnorm(params["q_norm"], ql)
        q = matmul(ql, params["wq_b"])
    else:
        q = matmul(x, params["wq"])
    q = q.reshape(B, T, n_heads, qk_dim)
    from repro.distributed.policy import constrain
    q = constrain(q, "act_bthd")
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, theta=10000.0)
    return q_nope, q_rope


def _latent(params, x, m: MLAConfig, positions):
    kv = matmul(x, params["wkv_a"])
    c_kv = rmsnorm(params["kv_norm"], kv[..., : m.kv_lora_rank])
    k_rope = kv[..., m.kv_lora_rank:]                           # [B,T,dr]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, theta=10000.0)[:, :, 0]
    return c_kv, k_rope


def mla_prefill(params, x, positions, *, n_heads, m: MLAConfig,
                block_q: int = 512, pad_mask=None):
    """Materialized prefill.  Returns out [B,T,d] and latent cache [B,T,r+dr]."""
    B, T, _ = x.shape
    q_nope, q_rope = _queries(params, x, n_heads, m, positions)
    c_kv, k_rope = _latent(params, x, m, positions)
    # materialize per-head K (nope) and V from the latent: GEMM (CiM path)
    k_nope = jnp.einsum("btr,hrn->bthn", c_kv, params["w_uk"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("btr,hrn->bthn", c_kv, params["w_uv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    # blockwise over query blocks to bound live memory at long T
    nq = max(T // block_q, 1)
    bq = T // nq
    qn = q_nope.reshape(B, nq, bq, n_heads, m.qk_nope_head_dim)
    qr = q_rope.reshape(B, nq, bq, n_heads, m.qk_rope_head_dim)
    pq = positions.reshape(B, nq, bq)

    def q_block_inner(qnb, qrb, pqb):
        s = jnp.einsum("bqhn,bthn->bhqt", qnb, k_nope,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bqhr,btr->bhqt", qrb, k_rope,
                        preferred_element_type=jnp.float32)
        s *= scale
        mask = positions[:, None, None, :] <= pqb[:, None, :, None]
        if pad_mask is not None:
            mask = mask & pad_mask[:, None, None, :]
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqt,bthv->bqhv", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.astype(x.dtype)

    from repro.distributed.policy import get_policy
    pol = get_policy()
    if pol is not None and pol.sp_enabled:
        # sequence-parallel: K/V stay replicated (they come whole from the
        # latent), q blocks sharded over 'model' -> vmap keeps them local
        outs = jax.vmap(q_block_inner)(
            qn.swapaxes(0, 1), qr.swapaxes(0, 1), pq.swapaxes(0, 1))
    else:
        _, outs = jax.lax.scan(
            lambda _, inp: (None, q_block_inner(*inp)), None,
            (qn.swapaxes(0, 1), qr.swapaxes(0, 1), pq.swapaxes(0, 1)))
    out = outs.swapaxes(0, 1).reshape(B, T, n_heads * m.v_head_dim)
    out = matmul(out, params["wo"])
    cache = jnp.concatenate([c_kv, k_rope], axis=-1)            # [B,T,r+dr]
    return out, cache


def mla_chunk(params, x, offsets, lengths, slots, cache, *,
              n_heads, m: MLAConfig):
    """Chunked prefill against the latent decode arena (absorbed form).

    x: [N, C, d] packed chunk rows; cache: [B, S, r+dr] arena.  The MLA
    arena is position-indexed (no ring), so the chunk's latents are
    scattered in FIRST (padded rows/positions drop out of bounds) and the
    C queries then run the absorbed decode formulation over each row's full
    arena — entries above the query position (stale previous occupants,
    later pad) are masked.  Returns (out [N, C, d], new_cache).
    """
    N, C, _ = x.shape
    B, S = cache.shape[0], cache.shape[1]
    offs = jnp.asarray(offsets, jnp.int32)
    lens = jnp.asarray(lengths, jnp.int32)
    slot = jnp.asarray(slots, jnp.int32)
    j = jnp.arange(C, dtype=jnp.int32)
    positions = offs[:, None] + j[None, :]                      # [N, C]
    q_nope, q_rope = _queries(params, x, n_heads, m, positions)
    c_new, kr_new = _latent(params, x, m, positions)
    entry = jnp.concatenate([c_new, kr_new], axis=-1)           # [N, C, r+dr]
    keep = j[None, :] < lens[:, None]
    w_slot = jnp.where(keep, jnp.broadcast_to(slot[:, None], (N, C)), B)
    w_idx = jnp.where(keep, positions, S)
    cache = cache.at[w_slot, w_idx].set(entry, mode="drop")
    lat = cache[jnp.clip(slot, 0, B - 1)]                       # [N, S, r+dr]
    c_kv = lat[..., : m.kv_lora_rank]
    k_rope = lat[..., m.kv_lora_rank:]
    q_lat = jnp.einsum("nqhd,hrd->nqhr", q_nope, params["w_uk"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = jnp.einsum("nqhr,nsr->nhqs", q_lat, c_kv,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("nqhd,nsd->nhqs", q_rope, k_rope,
                    preferred_element_type=jnp.float32)
    s *= scale
    valid = (jnp.arange(S, dtype=jnp.int32)[None, None, :]
             <= positions[:, :, None])                          # [N, C, S]
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("nhqs,nsr->nqhr", p.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    ctx = jnp.einsum("nqhr,hrv->nqhv", ctx_lat, params["w_uv"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = matmul(ctx.reshape(N, C, n_heads * m.v_head_dim), params["wo"])
    return out, cache


def mla_chunk_paged(params, x, offsets, lengths, slots, cache, block_table,
                    *, n_heads, m: MLAConfig, scales=None):
    """Chunked prefill against the PAGED latent pool.

    cache: [n_pages, P, r+dr]; block_table: [B, W] int32 (sentinel >=
    n_pages).  Position ``pos`` of a slot lives at page ``bt[slot, pos//P]``
    offset ``pos % P`` (the MLA arena is position-indexed — no ring).  As
    in ``mla_chunk`` the chunk's latents are scattered in FIRST, then the C
    queries run the absorbed decode formulation over the row's gathered
    pages.  Returns (out [N, C, d], new_cache) — or, with ``scales`` (f32
    [n_pages, P] per-token scale pages riding the same block table; the
    latent pool is then int8, HALO's end-to-end-int8 memory format),
    (out, new_cache, new_scales): writes quantize per token, gathers
    dequantize before the absorbed sweep.
    """
    n_rows, C, _ = x.shape
    n_pages, P = cache.shape[0], cache.shape[1]
    B, W = block_table.shape[0], block_table.shape[1]
    S = W * P
    offs = jnp.asarray(offsets, jnp.int32)
    lens = jnp.asarray(lengths, jnp.int32)
    slot = jnp.asarray(slots, jnp.int32)
    bt = jnp.asarray(block_table, jnp.int32)
    j = jnp.arange(C, dtype=jnp.int32)
    positions = offs[:, None] + j[None, :]                      # [N, C]
    q_nope, q_rope = _queries(params, x, n_heads, m, positions)
    c_new, kr_new = _latent(params, x, m, positions)
    entry = jnp.concatenate([c_new, kr_new], axis=-1)           # [N, C, r+dr]
    keep = j[None, :] < lens[:, None]
    valid_row = (slot >= 0) & (slot < B)
    bt_rows = bt[jnp.clip(slot, 0, B - 1)]                      # [N, W]
    w_page = jnp.take_along_axis(bt_rows, positions // P, axis=1)
    w_page = jnp.where(keep & valid_row[:, None], w_page, n_pages)
    w_off = jnp.where(keep, positions % P, P)
    pages = jnp.clip(bt_rows, 0, n_pages - 1)
    if scales is not None:
        from repro.serving.quantized_cache import dequantize, quantize_token
        e_q, e_s = quantize_token(entry)            # [N,C,w] int8, [N,C]
        cache = cache.at[w_page, w_off].set(e_q, mode="drop")
        scales = scales.at[w_page, w_off].set(e_s, mode="drop")
        lat = dequantize(cache[pages], scales[pages]).astype(x.dtype)
    else:
        cache = cache.at[w_page, w_off].set(entry, mode="drop")
        lat = cache[pages]                                      # [N, W, P, w]
    lat = lat.reshape(n_rows, S, lat.shape[-1])
    c_kv = lat[..., : m.kv_lora_rank]
    k_rope = lat[..., m.kv_lora_rank:]
    q_lat = jnp.einsum("nqhd,hrd->nqhr", q_nope, params["w_uk"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = jnp.einsum("nqhr,nsr->nhqs", q_lat, c_kv,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("nqhd,nsd->nhqs", q_rope, k_rope,
                    preferred_element_type=jnp.float32)
    s *= scale
    valid = (jnp.arange(S, dtype=jnp.int32)[None, None, :]
             <= positions[:, :, None])                          # [N, C, S]
    valid &= ~jnp.repeat(bt_rows >= n_pages, P, axis=1)[:, None, :]
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("nhqs,nsr->nqhr", p.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    ctx = jnp.einsum("nqhr,hrv->nqhv", ctx_lat, params["w_uv"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = matmul(ctx.reshape(n_rows, C, n_heads * m.v_head_dim), params["wo"])
    if scales is not None:
        return out, cache, scales
    return out, cache


def mla_chunk_packed(params, x, seg, cache, *, n_heads, m: MLAConfig):
    """Packed-stream chunked prefill against the latent decode arena.

    x: [1, T, d] — one flat stream of N segments described by ``seg`` (a
    ``models.attention.PackedSegs``); cache: [B, S, r+dr].  Same
    scatter-first absorbed formulation as ``mla_chunk``: the stream's
    latents land in the arena (invalid tokens drop out of bounds), then
    every token runs the absorbed sweep over its OWN slot's arena with
    entries above its position masked — the softmax axis is the arena
    axis S exactly as in the padded path, so the math is order-identical.
    Returns (out [1, T, d], new_cache).
    """
    _, T, _ = x.shape
    B, S = cache.shape[0], cache.shape[1]
    positions = seg.positions[None]                             # [1, T]
    q_nope, q_rope = _queries(params, x, n_heads, m, positions)
    c_new, kr_new = _latent(params, x, m, positions)
    q_nope, q_rope = q_nope[0], q_rope[0]                       # [T, H, .]
    entry = jnp.concatenate([c_new, kr_new], axis=-1)[0]        # [T, r+dr]
    w_slot = jnp.where(seg.valid, seg.tok_slot, B)
    w_idx = jnp.where(seg.valid, seg.positions, S)
    cache = cache.at[w_slot, w_idx].set(entry, mode="drop")
    lat = cache[jnp.clip(seg.tok_slot, 0, B - 1)]               # [T, S, r+dr]
    c_kv = lat[..., : m.kv_lora_rank]
    k_rope = lat[..., m.kv_lora_rank:]
    q_lat = jnp.einsum("thd,hrd->thr", q_nope, params["w_uk"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = jnp.einsum("thr,tsr->ths", q_lat, c_kv,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("thd,tsd->ths", q_rope, k_rope,
                    preferred_element_type=jnp.float32)
    s *= scale
    valid = (jnp.arange(S, dtype=jnp.int32)[None, :]
             <= seg.positions[:, None])                         # [T, S]
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("ths,tsr->thr", p.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    ctx = jnp.einsum("thr,hrv->thv", ctx_lat, params["w_uv"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = matmul(ctx.reshape(1, T, n_heads * m.v_head_dim), params["wo"])
    return out, cache


def mla_chunk_packed_paged(params, x, seg, cache, block_table, *,
                           n_heads, m: MLAConfig, scales=None):
    """Packed-stream chunked prefill against the PAGED latent pool.

    Same stream contract as ``mla_chunk_packed``; the arena is the pool
    ``cache`` [n_pages, P, r+dr] addressed via ``block_table`` [B, W]
    exactly as in ``mla_chunk_paged`` (position-indexed, sentinel pages
    drop / mask; with ``scales`` the pool is int8 + per-token scale pages).
    Returns (out [1, T, d], new_cache[, new_scales]).
    """
    _, T, _ = x.shape
    n_pages, P = cache.shape[0], cache.shape[1]
    B, W = block_table.shape[0], block_table.shape[1]
    S = W * P
    positions = seg.positions[None]                             # [1, T]
    q_nope, q_rope = _queries(params, x, n_heads, m, positions)
    c_new, kr_new = _latent(params, x, m, positions)
    q_nope, q_rope = q_nope[0], q_rope[0]                       # [T, H, .]
    entry = jnp.concatenate([c_new, kr_new], axis=-1)[0]        # [T, r+dr]
    bt = jnp.asarray(block_table, jnp.int32)
    bt_rows = bt[jnp.clip(seg.slots, 0, B - 1)]                 # [N, W]
    bt_tok = bt_rows[seg.seg_id]                                # [T, W]
    valid_row = (seg.tok_slot >= 0) & (seg.tok_slot < B)
    w_page = jnp.take_along_axis(
        bt_tok, (seg.positions // P)[:, None], axis=1)[:, 0]
    w_page = jnp.where(seg.valid & valid_row, w_page, n_pages)
    w_off = jnp.where(seg.valid, seg.positions % P, P)
    pages = jnp.clip(bt_tok, 0, n_pages - 1)
    if scales is not None:
        from repro.serving.quantized_cache import dequantize, quantize_token
        e_q, e_s = quantize_token(entry)            # [T,w] int8, [T]
        cache = cache.at[w_page, w_off].set(e_q, mode="drop")
        scales = scales.at[w_page, w_off].set(e_s, mode="drop")
        lat = dequantize(cache[pages], scales[pages]).astype(x.dtype)
    else:
        cache = cache.at[w_page, w_off].set(entry, mode="drop")
        lat = cache[pages]                                      # [T, W, P, w]
    lat = lat.reshape(T, S, lat.shape[-1])
    c_kv = lat[..., : m.kv_lora_rank]
    k_rope = lat[..., m.kv_lora_rank:]
    q_lat = jnp.einsum("thd,hrd->thr", q_nope, params["w_uk"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = jnp.einsum("thr,tsr->ths", q_lat, c_kv,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("thd,tsd->ths", q_rope, k_rope,
                    preferred_element_type=jnp.float32)
    s *= scale
    valid = (jnp.arange(S, dtype=jnp.int32)[None, :]
             <= seg.positions[:, None])                         # [T, S]
    valid &= ~jnp.repeat(bt_tok >= n_pages, P, axis=1)
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("ths,tsr->thr", p.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    ctx = jnp.einsum("thr,hrv->thv", ctx_lat, params["w_uv"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = matmul(ctx.reshape(1, T, n_heads * m.v_head_dim), params["wo"])
    if scales is not None:
        return out, cache, scales
    return out, cache


def mla_decode_paged(params, x, cache, block_table, pos, *, n_heads,
                     m: MLAConfig, scales=None):
    """Absorbed paged decode: GEMV sweep over the gathered latent pages.

    cache: [n_pages, P, r+dr]; block_table: [B, W]; pos: [B].  The engine
    hands inactive slots all-sentinel rows so their writes drop.  With
    ``scales`` (f32 [n_pages, P]) the pool is int8 latents + per-token
    scale pages — the GEMV sweep then streams r+dr+4 bytes per cached
    token instead of 4*(r+dr) (HALO's int8 CiD memory format) — and the
    return is (out, new_cache, new_scales).
    """
    B = x.shape[0]
    n_pages, P = cache.shape[0], cache.shape[1]
    W = block_table.shape[1]
    S = W * P
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q_nope, q_rope = _queries(params, x, n_heads, m, pos[:, None])
    c_new, kr_new = _latent(params, x, m, pos[:, None])
    new_entry = jnp.concatenate([c_new, kr_new], axis=-1)       # [B,1,r+dr]
    bt = jnp.asarray(block_table, jnp.int32)
    bidx = jnp.arange(B)
    w_page = bt[bidx, pos // P]
    pages = jnp.clip(bt, 0, n_pages - 1)
    if scales is not None:
        from repro.serving.quantized_cache import dequantize, quantize_token
        e_q, e_s = quantize_token(new_entry)        # [B,1,w] int8, [B,1]
        cache = cache.at[w_page, pos % P].set(e_q[:, 0], mode="drop")
        scales = scales.at[w_page, pos % P].set(e_s[:, 0], mode="drop")
        lat = dequantize(cache[pages], scales[pages]).astype(x.dtype)
    else:
        cache = cache.at[w_page, pos % P].set(new_entry[:, 0], mode="drop")
        lat = cache[pages]                                      # [B, W, P, w]
    lat = lat.reshape(B, S, lat.shape[-1])
    c_kv = lat[..., : m.kv_lora_rank]                           # [B,S,r]
    k_rope = lat[..., m.kv_lora_rank:]                          # [B,S,dr]
    q_lat = jnp.einsum("bqhn,hrn->bhr", q_nope, params["w_uk"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = jnp.einsum("bhr,bsr->bhs", q_lat, c_kv,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bqhr,bsr->bhs", q_rope, k_rope,
                    preferred_element_type=jnp.float32)
    s *= scale
    valid = (jnp.arange(S, dtype=jnp.int32)[None, :] <= pos[:, None]) \
        & ~jnp.repeat(bt >= n_pages, P, axis=1)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", p.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    ctx = jnp.einsum("bhr,hrv->bhv", ctx_lat, params["w_uv"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = matmul(ctx.reshape(B, 1, n_heads * m.v_head_dim), params["wo"])
    if scales is not None:
        return out, cache, scales
    return out, cache


def mla_decode(params, x, cache, pos, *, n_heads, m: MLAConfig,
               slot=None, extra_mask=None):
    """Absorbed decode: GEMV sweep over the latent cache (CiD path).

    cache: [B, S, r+dr]; pos: scalar/[B] absolute position of the new token.
    """
    from repro.distributed.policy import constrain
    B = x.shape[0]
    S = cache.shape[1]
    pos_in = jnp.asarray(pos, jnp.int32)
    pos = jnp.broadcast_to(pos_in, (B,))
    q_nope, q_rope = _queries(params, x, n_heads, m, pos[:, None])
    c_new, kr_new = _latent(params, x, m, pos[:, None])
    new_entry = jnp.concatenate([c_new, kr_new], axis=-1)       # [B,1,r+dr]
    if slot is None:
        slot = (jnp.minimum(pos_in, S - 1) if pos_in.ndim == 0
                else jnp.minimum(pos, S - 1))
    slot = jnp.asarray(slot, jnp.int32)
    if slot.ndim == 0:
        cache = jax.lax.dynamic_update_slice(cache, new_entry, (0, slot, 0))
    else:
        cache = cache.at[jnp.arange(B), slot].set(new_entry[:, 0])
    cache = constrain(cache, "latent_bsr")
    c_kv = cache[..., : m.kv_lora_rank]                         # [B,S,r]
    k_rope = cache[..., m.kv_lora_rank:]                        # [B,S,dr]
    # absorb W_UK into q: q_lat [B,H,r]
    q_lat = jnp.einsum("bqhn,hrn->bhr", q_nope, params["w_uk"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = jnp.einsum("bhr,bsr->bhs", q_lat, c_kv,
                   preferred_element_type=jnp.float32)
    s += jnp.einsum("bqhr,bsr->bhs", q_rope, k_rope,
                    preferred_element_type=jnp.float32)
    s *= scale
    valid = jnp.arange(S, dtype=jnp.int32)[None, :] <= pos[:, None]
    if extra_mask is not None:
        valid = valid & extra_mask
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", p.astype(c_kv.dtype), c_kv,
                         preferred_element_type=jnp.float32).astype(x.dtype)
    # absorb W_UV on the output side
    ctx = jnp.einsum("bhr,hrv->bhv", ctx_lat, params["w_uv"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = matmul(ctx.reshape(B, 1, n_heads * m.v_head_dim), params["wo"])
    return out, cache
