"""Shared model-building primitives (pure functional JAX).

All parameters are plain pytrees (nested dicts of jnp arrays).  Modules are
(init, apply) function pairs; stacked variants (leading layer axis) are used
with ``jax.lax.scan`` over homogeneous layer segments.

Dtype policy: parameters are stored in ``param_dtype`` (bf16 for full configs,
f32 for reduced smoke configs); matmuls accumulate in f32
(``preferred_element_type``); norms/softmax always compute in f32.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (llama-style)."""
    if scale is None:
        scale = 1.0 / math.sqrt(in_dim)
    w = jax.random.truncated_normal(key, -3.0, 3.0, (in_dim, out_dim), jnp.float32)
    return (w * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    w = jax.random.normal(key, (vocab, dim), jnp.float32)
    return (w * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5, *, gemma_style: bool = False):
    """RMSNorm in f32.  gemma_style uses (1 + scale) parameterization."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    if gemma_style:
        scale = 1.0 + scale
    return (xf * scale).astype(dt)


def head_rmsnorm(scale, x, eps: float = 1e-5):
    """Per-head RMSNorm over the last (head) dim — qwen3/gemma3 qk_norm."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta) -> jnp.ndarray:
    """Inverse frequencies [dim//2] (f32).  ``theta`` may be traced."""
    exponent = jnp.arange(0, dim, 2, dtype=jnp.float32) / dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta) -> jnp.ndarray:
    """Apply rotary embedding.

    x: [..., T, H, D] (or [..., T, D] with H folded); positions: broadcastable
    to [..., T].  Rotates pairs (x[2i], x[2i+1]) — interleaved convention.
    """
    dt = x.dtype
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                   # [d/2]
    ang = positions[..., None].astype(jnp.float32) * inv         # [..., T, d/2]
    # expand over the head axis: x is [..., T, H, D] -> ang [..., T, 1, d/2]
    ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xf = x.astype(jnp.float32)
    x1 = xf[..., 0::2]
    x2 = xf[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# FFN (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def ffn_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff, dtype),
        "wi_up": dense_init(k2, d_model, d_ff, dtype),
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }


def _act(x, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    return jax.nn.silu(x)


def ffn(params, x, act: str = "silu"):
    from repro.distributed.policy import constrain
    g = matmul(x, params["wi_gate"])
    u = matmul(x, params["wi_up"])
    h = _act(g, act) * u
    if h.ndim == 3:
        h = constrain(h, "act_btf")
    return matmul(h.astype(x.dtype), params["wo"])


# ---------------------------------------------------------------------------
# matmul with f32 accumulation
# ---------------------------------------------------------------------------

# Decode-shaped quantized matmuls (token dim <= this) route through the
# fused int8 Pallas GEMV (kernels/gemv_cid._gemv_q_kernel) so the weight
# bytes cross HBM at int8 width with in-kernel dequant — HALO's CiD decode
# mapping.  The threshold catches decode (T=1) and speculative verify
# windows (T = bucketed k+1) but not prefill chunks, which stay on the
# GEMM path (CiM).
GEMV_TOKEN_DIM_MAX = 8

# trace-time route counter: incremented each time a jitted program traces
# the fused-GEMV path.  Tests/benches assert decode programs actually
# contain the kernel (a program counter, not a timing claim).
_gemv_routes = 0


def gemv_route_count() -> int:
    return _gemv_routes


def reset_gemv_route_count() -> None:
    global _gemv_routes
    _gemv_routes = 0


def matmul(x, w):
    """x @ w with f32 accumulation, result cast back to x.dtype.

    ``w`` may be an int8 weight-only-quantized dict {"q","scale"}
    (serving/quantized_weights.py); the dequant fuses into the operand read
    on TPU, so HBM/all-gather traffic is the int8 width.  Decode-shaped
    calls (token dim <= GEMV_TOKEN_DIM_MAX, unsharded) route through the
    quantized Pallas GEMV so the int8 bytes are read directly with
    in-kernel dequant instead of materializing a full-width copy.
    """
    from repro.distributed.policy import get_policy, replicate
    global _gemv_routes
    pol = get_policy()
    sp = pol is not None and pol.sp_enabled
    if isinstance(w, dict) and "q" in w:
        q, scale = w["q"], w["scale"]
        if (not sp and q.ndim == 2 and x.ndim >= 2
                and x.shape[-2] <= GEMV_TOKEN_DIM_MAX):
            from repro.kernels import ops as _kops
            _gemv_routes += 1
            lead = x.shape[:-1]
            x2 = x.reshape(-1, x.shape[-1])
            out = _kops.gemv(x2, q, scale.astype(jnp.float32))
            return out.reshape(lead + (q.shape[-1],)).astype(x.dtype)
        if sp:
            # gather the INT8 bytes, dequantize per chip (not vice versa)
            q, scale = replicate(q), replicate(scale)
        w = (q.astype(jnp.float32)
             * scale.astype(jnp.float32)[..., None, :]).astype(x.dtype)
    elif sp:
        w = replicate(w)     # gather at the stored (bf16) width
    out = jnp.einsum("...i,io->...o", x, w,
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def softmax_f32(scores, axis: int = -1):
    return jax.nn.softmax(scores.astype(jnp.float32), axis=axis)
