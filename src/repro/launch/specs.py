"""ShapeDtypeStruct stand-ins for every (arch x shape) cell + their shardings.

``input_specs(cfg, shape)`` returns the exact pytree the corresponding step
function consumes, built from ShapeDtypeStruct only — no device allocation.
The dry-run lowers ``jit(step).lower(**specs)`` with these.

Modality frontends are STUBS per the task sheet: vlm archs get a
``vision_embeds`` [B, F, d] array standing in for precomputed InternViT patch
embeddings; the audio arch feeds per-codebook token ids directly (the EnCodec
tokenizer itself is out of scope).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import batch_pspec, cache_pspecs
from repro.models.transformer import cache_specs

SDS = jax.ShapeDtypeStruct


def _batch_axes_spec(cfg: ModelConfig, mesh: Mesh, B: int) -> Any:
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    b = batch_pspec(mesh.axis_names, batch_size=B, mesh_shape=shape)
    return tuple(b) if b != (None,) else None


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                      ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(specs, shardings) for the train batch."""
    B, T = shape.global_batch, shape.seq_len
    b_ax = _batch_axes_spec(cfg, mesh, B)
    specs: Dict[str, Any] = {}
    shards: Dict[str, Any] = {}
    if cfg.n_codebooks > 1:
        specs["tokens"] = SDS((B, cfg.n_codebooks, T), jnp.int32)
        shards["tokens"] = NamedSharding(mesh, P(b_ax, None, None))
    elif cfg.frontend == "vision":
        F = cfg.n_frontend_tokens
        specs["tokens"] = SDS((B, T - F), jnp.int32)
        specs["vision_embeds"] = SDS((B, F, cfg.d_model), jnp.dtype(cfg.dtype))
        shards["tokens"] = NamedSharding(mesh, P(b_ax, None))
        shards["vision_embeds"] = NamedSharding(mesh, P(b_ax, None, None))
    else:
        specs["tokens"] = SDS((B, T), jnp.int32)
        shards["tokens"] = NamedSharding(mesh, P(b_ax, None))
    return specs, shards


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                        ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    return train_input_specs(cfg, shape, mesh)


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       *, q8_kv: bool = False
                       ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Specs for (batch, cache, pos) of a single decode step at full context.

    The cache stands at seq_len occupancy — the worst-case serve_step the
    shape sheet asks for (one new token against a seq_len KV cache).
    ``q8_kv``: int8 KV arena (HALO-faithful decode format).
    """
    B, S = shape.global_batch, shape.seq_len
    b_ax = _batch_axes_spec(cfg, mesh, B)
    if cfg.n_codebooks > 1:
        tok = SDS((B, cfg.n_codebooks, 1), jnp.int32)
        tok_s = NamedSharding(mesh, P(b_ax, None, None))
    else:
        tok = SDS((B, 1), jnp.int32)
        tok_s = NamedSharding(mesh, P(b_ax, None))
    batch = {"tokens": tok}
    batch_shard = {"tokens": tok_s}
    if q8_kv:
        from repro.serving.quantized_cache import quantized_cache_specs
        cache = quantized_cache_specs(cfg, B, S)
    else:
        cache = cache_specs(cfg, B, S)
    cspec = cache_pspecs(cfg, mesh, B, cache_tree=cache)
    cache_shard = [
        jax.tree.map(lambda s: NamedSharding(mesh, s), cs,
                     is_leaf=lambda x: isinstance(x, P))
        for cs in cspec
    ]
    pos = SDS((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())
    specs = {"batch": batch, "cache": cache, "pos": pos}
    shards = {"batch": batch_shard, "cache": cache_shard, "pos": pos_shard}
    return specs, shards
