"""Training driver.

Small-config CPU runs (examples, CI) and production-mesh runs share this
entrypoint; the mesh/shardings path is exercised for real by the dry-run
(launch/dryrun.py) and by the 8-device sharded tests.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import sys



def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default=None,
                    help="adamw | adafactor | sgd (default: policy)")
    ap.add_argument("--schedule", default=None, help="cosine | wsd | constant")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")

    from repro.configs.base import get_config
    from repro.data.pipeline import DataConfig
    from repro.launch.training_config import optimizer_policy
    from repro.optim.optimizers import make_optimizer
    from repro.optim.schedules import make_schedule
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, dtype="float32")

    if args.optimizer:
        sched_name = args.schedule or (
            "wsd" if args.arch.startswith("minicpm") else "cosine")
        sched = make_schedule(sched_name, args.lr, args.steps,
                              max(args.steps // 20, 1))
        opt = make_optimizer(args.optimizer, sched)
    else:
        opt = optimizer_policy(cfg, args.lr, args.steps)

    data_cfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                          vocab_size=cfg.vocab_size, seed=args.seed,
                          n_codebooks=cfg.n_codebooks)
    tc = TrainerConfig(total_steps=args.steps,
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir,
                       n_microbatches=args.microbatches,
                       log_every=args.log_every, seed=args.seed,
                       remat=not args.reduced)
    trainer = Trainer(cfg, opt, data_cfg, tc)
    out = trainer.run()
    first = trainer.history[0]["loss"] if trainer.history else float("nan")
    print(f"arch={cfg.name} steps={out['steps_run']} "
          f"loss {first:.4f} -> {out['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
