"""Re-analyze saved dry-run HLOs under different modeling assumptions —
the §Perf iteration tool that does NOT need a recompile.

  PYTHONPATH=src python -m repro.launch.reanalyze \
      --hlo results/hlo/qwen3-8b_prefill_32k_pod1.hlo \
      --cell "qwen3-8b|prefill_32k|pod1" \
      --kernel-regions q_block_inner,kv_block,bhgqk

``--kernel-regions`` lists Python function names and einsum-label fragments
whose HLO regions are deployed as Pallas TPU kernels (flash attention fwd +
bwd): their internal tensors are VMEM-resident and charged zero HBM traffic.
Baseline = no regions.  The flags are recorded with the output row so every
§Perf claim is reproducible.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import (
    COLLECTIVE_WEIGHT,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
)

# flash-attention (GQA + MLA) kernel region tokens: the inner-block Python
# functions plus the einsum labels their VJP ops inherit.
FLASH_REGIONS = (
    "q_block_inner", "kv_block", "q_block",
    "bqhgd,bkhd->bhgqk", "bhgqk,bkhd->bhgqd",          # GQA fwd
    "bqhn,bthn->bhqt", "bhqt,bthv->bqhv",              # MLA fwd
)


def analyze_file(path: str, kernel_regions=(), n_chips: int = 256,
                 model_flops: float = 0.0) -> dict:
    text = open(path).read()
    hs = analyze_hlo(text, kernel_regions=tuple(kernel_regions))
    weighted = sum(COLLECTIVE_WEIGHT.get(k, 1.0) * v
                   for k, v in hs.coll_bytes.items())
    t_c = hs.flops / PEAK_FLOPS
    t_m = hs.hbm_bytes / HBM_BW
    t_l = weighted / ICI_BW
    row = {
        "hlo": path,
        "kernel_regions": list(kernel_regions),
        "flops_per_chip": hs.flops,
        "hbm_bytes_per_chip": hs.hbm_bytes,
        "coll_bytes_per_chip": weighted,
        "t_compute_s": t_c,
        "t_memory_s": t_m,
        "t_collective_s": t_l,
        "bottleneck": max(
            {"compute": t_c, "memory": t_m, "collective": t_l}.items(),
            key=lambda kv: kv[1])[0],
        "coll_by_kind": {k: v for k, v in hs.coll_bytes.items()},
        "top_shapes": hs.top_shapes(8),
    }
    if model_flops:
        t_useful = model_flops / n_chips / PEAK_FLOPS
        row["roofline_frac"] = t_useful / max(t_c, t_m, t_l)
        row["useful_flops_frac"] = model_flops / (hs.flops * n_chips)
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo", required=True)
    ap.add_argument("--cell", default="")
    ap.add_argument("--kernel-regions", default="")
    ap.add_argument("--flash", action="store_true",
                    help="use the canonical flash-attention region set")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args(argv)

    regions = [t for t in args.kernel_regions.split(",") if t]
    if args.flash:
        regions = list(FLASH_REGIONS) + regions

    mf = 0.0
    if args.arch and args.shape:
        from repro.configs.base import SHAPES, get_config
        from repro.launch.roofline import model_flops_for
        cfg = get_config(args.arch)
        sh = SHAPES[args.shape]
        mf = model_flops_for(cfg, sh.kind, sh.seq_len, sh.global_batch)

    row = analyze_file(args.hlo, regions, model_flops=mf)
    row["cell"] = args.cell
    print(json.dumps(row, indent=1, default=str))
    return 0


if __name__ == "__main__":
    sys.exit(main())
