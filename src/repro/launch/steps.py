"""Step functions: the jit boundaries of the framework.

``make_train_step``   (state, batch) -> (state, metrics)
``make_prefill_step`` (params, batch) -> (last_logits, cache)
``make_decode_step``  (params, batch, cache, pos) -> (logits, cache)

Memory discipline baked in here (sized against the 16 GB/chip v5e budget;
see launch/training_config.py for the per-architecture numbers):

* remat (activation checkpointing) on every layer scan during training;
* cross-entropy is computed CHUNKED over the token axis so the full
  [tokens, vocab] logits tensor is never materialized (gemma3's 262k vocab
  at 1M tokens/step would otherwise be 1.1 TB of f32 logits);
* optional microbatch gradient accumulation (``n_microbatches``) via
  ``lax.scan`` with f32 (or bf16) accumulators;
* gradient clipping by global norm before the optimizer update.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import forward, lm_logits
from repro.optim.grad_utils import clip_by_global_norm
from repro.optim.optimizers import Optimizer

Pytree = Any


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def _ce_chunk(params, cfg: ModelConfig, h, labels, mask):
    """Cross-entropy for one token chunk.  h [B,tc,d]; labels [B,tc] or
    [B,K,tc] (musicgen).  Returns (sum_loss, sum_count)."""
    from repro.distributed.policy import constrain
    logits = lm_logits(params, cfg, h)                     # [B,tc,V] / [B,tc,K,V]
    logits = constrain(logits, "logits4" if logits.ndim == 4 else "logits")
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    if cfg.n_codebooks > 1:
        labels = labels.swapaxes(1, 2)                     # [B,tc,K]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if cfg.n_codebooks > 1:
        nll = nll.mean(axis=-1)                            # avg codebooks
    nll = nll * mask
    return jnp.sum(nll), jnp.sum(mask)


def token_loss(params, cfg: ModelConfig, hidden, labels, mask,
               chunk: int = 2048):
    """Chunked next-token CE.  hidden [B,T,d]; labels/mask token-aligned.

    The chunk function is rematerialized so backward re-forms each logits
    chunk instead of saving it — O(B*chunk*V) live instead of O(B*T*V).
    """
    B, T, _ = hidden.shape
    nc = max(T // chunk, 1)
    tc = T // nc
    if nc * tc != T:                                       # ragged tail: one shot
        loss, cnt = _ce_chunk(params, cfg, hidden, labels, mask)
        return loss / jnp.maximum(cnt, 1.0)

    hs = hidden.reshape(B, nc, tc, -1).swapaxes(0, 1)      # [nc,B,tc,d]
    if cfg.n_codebooks > 1:
        ls = labels.reshape(B, labels.shape[1], nc, tc).transpose(2, 0, 1, 3)
    else:
        ls = labels.reshape(B, nc, tc).swapaxes(0, 1)
    ms = mask.reshape(B, nc, tc).swapaxes(0, 1)

    ce = jax.checkpoint(functools.partial(_ce_chunk, params, cfg))

    def body(carry, inp):
        h, l, m = inp
        s, c = ce(h, l, m)
        return (carry[0] + s, carry[1] + c), None

    (loss, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                  (hs, ls, ms))
    return loss / jnp.maximum(cnt, 1.0)


def _shift_labels(cfg: ModelConfig, tokens):
    """Next-token labels + mask from the token array itself."""
    if cfg.n_codebooks > 1:                                # [B,K,T]
        labels = jnp.concatenate(
            [tokens[..., 1:], jnp.zeros_like(tokens[..., :1])], axis=-1)
        T = tokens.shape[-1]
        mask = (jnp.arange(T) < T - 1).astype(jnp.float32)
        mask = jnp.broadcast_to(mask, (tokens.shape[0], T))
        return labels, mask
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    T = tokens.shape[1]
    mask = jnp.broadcast_to((jnp.arange(T) < T - 1).astype(jnp.float32),
                            tokens.shape)
    return labels, mask


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Any],
            aux_weight: float = 1e-2, remat: bool = True):
    hidden, _, aux = forward(params, cfg, batch, phase="train", remat=remat,
                             return_hidden=True)
    if "labels" in batch:
        labels, mask = batch["labels"], batch.get(
            "loss_mask",
            jnp.ones(hidden.shape[:2], jnp.float32))
    else:
        labels, mask = _shift_labels(cfg, batch["tokens"])
        if hidden.shape[1] != mask.shape[-1]:              # vision prefix
            F = hidden.shape[1] - mask.shape[-1]
            hidden = hidden[:, F:]
    ce = token_loss(params, cfg, hidden, labels, mask)
    return ce + aux_weight * aux, (ce, aux)


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    *, n_microbatches: int = 1, clip_norm: float = 1.0,
                    accum_dtype: str = "float32",
                    aux_weight: float = 1e-2,
                    remat: bool = True) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt_state": ..., "step": int32[]}.
    batch["tokens"]: [B_global, T] int32 (plus optional vision_embeds/labels).
    """
    adt = jnp.dtype(accum_dtype)

    def grads_of(params, batch):
        (l, (ce, aux)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch, aux_weight, remat)
        return g, l, ce, aux

    def train_step(state, batch):
        params = state["params"]
        if n_microbatches <= 1:
            grads, l, ce, aux = grads_of(params, batch)
        else:
            def split(x):
                return x.reshape((n_microbatches, x.shape[0] // n_microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)

            def body(carry, b):
                g_acc, l_acc, ce_acc, aux_acc = carry
                g, l, ce, aux = grads_of(params, b)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(adt), g_acc, g)
                return (g_acc, l_acc + l, ce_acc + ce, aux_acc + aux), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (grads, l, ce, aux), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0), jnp.float32(0), jnp.float32(0)),
                mb)
            inv = 1.0 / n_microbatches
            grads = jax.tree.map(lambda g: g * inv, grads)
            l, ce, aux = l * inv, ce * inv, aux * inv

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(
            grads, state["opt_state"], params, state["step"])
        new_state = {"params": new_params, "opt_state": new_opt,
                     "step": state["step"] + 1}
        metrics = {"loss": l, "ce": ce, "aux": aux, "grad_norm": gnorm}
        return new_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, optimizer: Optimizer) -> Pytree:
    from repro.models.transformer import init_params
    params = init_params(key, cfg)
    return {"params": params, "opt_state": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        logits, cache, _ = forward(params, cfg, batch, phase="prefill")
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, batch, cache, pos):
        logits, cache, _ = forward(params, cfg, batch, phase="decode",
                                   cache=cache, pos=pos)
        return logits, cache
    return decode_step
