"""Per-architecture training policies (optimizer, schedule, memory knobs).

The optimizer choice is a MEMORY policy: at 256 chips x
16 GB, f32 Adam state (8 bytes/param) fits only models under ~50B params.
Larger models downgrade the moment dtypes; arctic-480b additionally factors
the second moment (Adafactor) — 480e9 params * 10B/param would be 4.8 TB of
optimizer+grad state otherwise.

minicpm-2b uses its own published WSD schedule; everything else defaults to
cosine.
"""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.optim.optimizers import Optimizer, adafactor, adamw
from repro.optim.schedules import make_schedule


def schedule_policy(cfg: ModelConfig, lr: float = 3e-4,
                    total_steps: int = 10_000, warmup_steps: int = None):
    if warmup_steps is None:
        warmup_steps = min(200, max(total_steps // 10, 1))
    name = "wsd" if cfg.name.startswith("minicpm") else "cosine"
    return make_schedule(name, lr, total_steps, warmup_steps)


def optimizer_policy(cfg: ModelConfig, lr: float = 3e-4,
                     total_steps: int = 10_000) -> Optimizer:
    sched = schedule_policy(cfg, lr, total_steps)
    n = cfg.param_count()
    if n > 150e9:
        # arctic-480b / deepseek-class: factored 2nd moment, bf16 momentum
        return adafactor(sched, momentum_dtype="bfloat16")
    if n > 20e9:
        # mid-size: full Adam but bf16 moments (4 bytes/param state)
        return adamw(sched, state_dtype="bfloat16")
    return adamw(sched, state_dtype="float32")


def grad_accum_policy(cfg: ModelConfig, shape_tokens: int) -> int:
    """Microbatch count for train_step (1 = no accumulation; remat +
    chunked-CE already bound activation memory for every assigned config)."""
    return 1
