"""Static analysis of post-SPMD HLO text: FLOPs / HBM bytes / collective
bytes WITH while-loop trip-count multiplication.

Why this exists: ``compiled.cost_analysis()`` counts each ``while`` body
ONCE.  Our models run layer stacks and CE chunks under ``lax.scan``, so XLA's
own numbers under-report by the trip count (28-80x for the layer loops) —
verified empirically (useful_flops_frac > 1 without this pass).

Model:
  * FLOPs  — 2*M*N*K per ``dot``; elementwise/reduce ops are ignored
    (consistent with the MODEL_FLOPS = 6*N*D convention, which also counts
    GEMMs only).  Convolutions would be counted if present (our SSM conv
    lowers to multiplies, already excluded on both sides).
  * HBM bytes — ANCHORS-ONLY FUSION MODEL, calibrated for what Mosaic/XLA-TPU
    materializes rather than what the CPU backend's per-op wrapped fusions
    suggest (naive operand+result summing was 10-30x inflated: the CPU HLO
    materializes ~16 separate f32 copies of the residual stream per layer
    for chains that Mosaic fuses into 1-2 passes, and charges loop-carried
    buffers in full per iteration):
      - dot / convolution: operand bytes + result bytes (MXU streams both);
      - dynamic-slice: 2x slice bytes;  dynamic-update-slice: 2x update
        bytes (in-place on TPU — NOT the full carried buffer);
      - reduce / gather / scatter / sort: operands + result;
      - while / conditional / call: free (bodies counted via call graph,
        carries are aliased in place);
      - pointwise / broadcast / convert / transpose / wrapped fusions: FREE —
        assumed fused into the neighbouring anchors.  This makes the memory
        term a fusion-optimal LOWER bound; the true TPU number sits between
        it and +~2 residual-stream passes per norm (small vs the dots).
      - entry parameters: read once per step.
  * Collective bytes — result-shape bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, weighted by a ring
    model factor (all-reduce=2, others=1) in the caller (roofline.py).
  * kernel_regions — names of Python functions whose HLO regions are
    implemented as Pallas TPU kernels in deployment (e.g. the flash
    attention inner loop): ops whose stack-frame provenance lands in one of
    these functions are VMEM-resident on TPU and charged zero HBM traffic.
    The dry-run resolves ``stack_frame_id`` through the FileLocations /
    StackFrames tables XLA appends to the HLO dump.  Baseline analyses pass
    kernel_regions=() — the discount is an explicit, reported modeling step.

Call-graph propagation: fusion -> calls=..., while -> body/condition,
conditional -> branch computations, sort/reduce/scatter -> to_apply (counted
but their comparators contribute ~0).  While trip count comes from XLA's
``backend_config known_trip_count`` annotation (fallback: the literal bound
in the condition's ``compare(iter, constant(N))``); unknown conditions
default to 1 and are reported so the caller can see coverage.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

# ops that do not touch HBM (metadata / aliasing only)
FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "opt-barrier", "partition-id",
    "replica-id", "iota", "copy-start", "copy-done",
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\(")
_CALL_REF_MULTI = re.compile(r"branch_computations=\{([^}]*)\}")
_DIMS_ATTR = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_IN_COND = re.compile(r"constant\((\d+)\)")
# XLA annotates counted loops directly (observed on CPU + TPU backends):
#   backend_config={"known_trip_count":{"n":"28"},...}
_KNOWN_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_TOKEN.findall(text):
        if dt in DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shapes: List[Tuple[str, List[int]]]) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CompStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)
    # (callee, multiplier, count_bytes): multiplier > 1 for while bodies.
    # count_bytes=False for fusion/to_apply interiors — a fusion's traffic
    # is its call-site operands/result; only its FLOPs (dots inside TPU
    # kOutput fusions) propagate.
    calls: List[Tuple[str, float, bool]] = field(default_factory=list)
    bytes_by_shape: Dict[str, float] = field(default_factory=dict)


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, float] = field(default_factory=dict)
    n_while_known: int = 0
    n_while_unknown: int = 0
    bytes_by_shape: Dict[str, float] = field(default_factory=dict)

    def top_shapes(self, n: int = 10) -> List[Tuple[str, float]]:
        return sorted(self.bytes_by_shape.items(),
                      key=lambda kv: -kv[1])[:n]

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes.values())


def _split_computations(text: str) -> Dict[str, List[str]]:
    """name -> op lines.

    Computation headers sit at column 0: ``%name (params...) -> type {`` or
    ``ENTRY %name (...) -> ... {``.  Params may contain nested parens (tuple
    types), so we key off the column-0 ``%``/``ENTRY`` + trailing ``{`` only.
    Body ops are indented; the closing ``}`` is back at column 0.
    """
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            if not line or line[0].isspace():
                continue
            is_entry = line.startswith("ENTRY")
            body = line[5:].lstrip() if is_entry else line
            if body.startswith("%") and line.endswith("{"):
                name = body[1:].split(" ", 1)[0].split("(", 1)[0]
                cur = name
                comps[cur] = []
                if is_entry:
                    entry = cur
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    comps["__entry__"] = [entry or ""]
    return comps


_OPERANDS = re.compile(r"%([\w\.\-]+)")


def _dot_flops(line: str, shapes_by_name: Dict[str, Tuple[str, List[int]]]
               ) -> float:
    """2 * prod(result dims) * prod(contracted lhs dims).

    Scheduled HLO lists operands by NAME only; lhs dims come from the
    definition-site shape map."""
    shapes = _shape_list(line.split(" dot(")[0])
    if not shapes:
        return 0.0
    _, res_dims = shapes[0]
    _, _, post = line.partition(" dot(")
    arg_region = post.split(")")[0]
    opnds = _OPERANDS.findall(arg_region)
    lhs_dims: List[int] = []
    if opnds and opnds[0] in shapes_by_name:
        lhs_dims = shapes_by_name[opnds[0]][1]
    m = _DIMS_ATTR.search(line)
    if m:
        cdims = [int(d) for d in m.group(1).split(",") if d]
    else:
        cdims = [len(lhs_dims) - 1] if lhs_dims else []
    k = 1
    for ci in cdims:
        if ci < len(lhs_dims):
            k *= lhs_dims[ci]
    out = 1
    for d in res_dims:
        out *= d
    return 2.0 * out * k


# ---------------------------------------------------------------------------
# stack-frame provenance (FileNames / FunctionNames / FileLocations /
# StackFrames tables at the bottom of the HLO dump)
# ---------------------------------------------------------------------------

_TABLE_ROW = re.compile(r"^(\d+)\s+(.*)$")
_STACK_FRAME_ATTR = re.compile(r"stack_frame_id=(\d+)")
_OP_NAME_META = re.compile(r'op_name="([^"]*)"')
_SCOPE_FN = re.compile(r"\(([\w\.<>]+)\)")


def parse_stack_tables(text: str):
    """Returns frame_id -> frozenset(function names on the stack).

    Primary source: the FileNames / FunctionNames / FileLocations /
    StackFrames tables some XLA versions append to the HLO dump.  When the
    backend does not emit those tables (observed: ``as_text()`` on current
    CPU builds prints only per-op ``metadata={op_name=...}``), provenance is
    reconstructed from the op_name scopes instead: ``jit(f)/jit(main)/dot``
    names the traced functions ``f`` and ``main`` on that op's stack.  The
    fallback synthesizes one pseudo-frame per distinct op_name."""
    sections: Dict[str, Dict[int, str]] = {}
    cur = None
    for line in text.splitlines():
        s = line.strip()
        if s in ("FileNames", "FunctionNames", "FileLocations", "StackFrames"):
            cur = s
            sections[cur] = {}
            continue
        if cur is None:
            continue
        m = _TABLE_ROW.match(s)
        if not m:
            cur = None
            continue
        sections[cur][int(m.group(1))] = m.group(2)

    fn_names = {i: v.strip('"') for i, v in
                sections.get("FunctionNames", {}).items()}
    loc_fn: Dict[int, str] = {}
    for i, v in sections.get("FileLocations", {}).items():
        m = re.search(r"function_name_id=(\d+)", v)
        if m:
            loc_fn[i] = fn_names.get(int(m.group(1)), "")
    frames: Dict[int, Tuple[int, int]] = {}
    for i, v in sections.get("StackFrames", {}).items():
        ml = re.search(r"file_location_id=(\d+)", v)
        mp = re.search(r"parent_frame_id=(\d+)", v)
        if ml and mp:
            frames[i] = (int(ml.group(1)), int(mp.group(1)))

    memo: Dict[int, frozenset] = {}

    def chain(fid: int, depth: int = 0) -> frozenset:
        if fid in memo:
            return memo[fid]
        if fid not in frames or depth > 200:
            return frozenset()
        loc, parent = frames[fid]
        names = {loc_fn.get(loc, "")}
        if parent != fid:
            names |= chain(parent, depth + 1)
        out = frozenset(n for n in names if n)
        memo[fid] = out
        return out

    if frames:
        return {fid: chain(fid) for fid in frames}

    # fallback: synthesize frames from op_name metadata scopes
    out: Dict[int, frozenset] = {}
    seen: Dict[str, int] = {}
    for m in _OP_NAME_META.finditer(text):
        op_name = m.group(1)
        if op_name in seen:
            continue
        names = frozenset(fn for fn in _SCOPE_FN.findall(op_name) if fn)
        if names:
            seen[op_name] = len(seen) + 1
            out[-seen[op_name]] = names        # negative ids: synthetic
    return out


def _trip_count(cond_lines: List[str]) -> Optional[int]:
    """Largest literal in the condition computation's compare/constant ops.

    XLA lowers counted loops to ``compare(iter, constant(N)), direction=LT``;
    taking the max literal is robust to extra bookkeeping constants."""
    best = None
    for line in cond_lines:
        if "compare" in line or "constant" in line:
            for c in _CONST_IN_COND.findall(line):
                v = int(c)
                if best is None or v > best:
                    best = v
    return best


def analyze_hlo(text: str, kernel_regions: Tuple[str, ...] = ()) -> HloStats:
    comps = _split_computations(text)
    entry = comps.pop("__entry__")[0]
    frame_fns = parse_stack_tables(text) if kernel_regions else {}
    kr = frozenset(kernel_regions)

    stats: Dict[str, CompStats] = {}
    unknown_whiles = 0
    known_whiles = 0

    # pass 1: result shapes + producer op + first operand, by op name
    shapes_by_name: Dict[str, Tuple[str, List[int]]] = {}
    producer: Dict[str, Tuple[str, Optional[str]]] = {}
    for name, lines in comps.items():
        for line in lines:
            m = _OP_LINE.match(line)
            if not m:
                continue
            op_name, type_region, opk = m.groups()
            sh = _shape_list(type_region)
            if sh and op_name not in shapes_by_name:
                shapes_by_name[op_name] = sh[0]
                _, _, post = line.partition(f" {opk}(")
                first = _OPERANDS.findall(post.split(")")[0]) if post else []
                producer[op_name] = (opk, first[0] if first else None)

    def _source_dtype(name: str, depth: int = 0) -> str:
        """Chase through convert/copy/bitcast (incl. CPU's convert-wrapping
        fusions and ``call``-wrapped parallel transpose/copy computations) to
        the dtype that actually streams from HBM — a bf16 or int8 cache read
        must not be charged at the f32/s32 width of its fused upcast."""
        if depth > 8 or name not in shapes_by_name:
            return shapes_by_name.get(name, ("f32", []))[0]
        opk, first = producer.get(name, ("", None))
        same_elems = (first is not None and sorted(
            shapes_by_name.get(name, ("", [0]))[1]) == sorted(
            shapes_by_name.get(first, ("", [1]))[1]))
        passthrough = opk in ("convert", "copy", "bitcast", "transpose",
                              "reshape") or (opk in ("fusion", "call")
                                             and same_elems)
        if passthrough and first:
            return _source_dtype(first, depth + 1)
        return shapes_by_name[name][0]

    # DATA dtypes stream from HBM at their stored width: floats, plus the
    # narrow integer formats quantized serving stores (s8 weight banks, s8
    # KV pages, u8 packed-int4 pages, s4/u4, f8).  Wide integers (s32/u32)
    # and pred stay excluded — those are index/bookkeeping buffers, and
    # charging them was the original reason _float_bytes dropped ints.
    _DATA_INT = ("s8", "u8", "s4", "u4")

    def _data_bytes(shapes):
        return _bytes_of([(dt, dims) for dt, dims in shapes
                          if dt.startswith(("f", "bf", "c"))
                          or dt in _DATA_INT])

    def _in_kernel_region(line: str) -> bool:
        """Substring match against (a) the stack-frame function-name chain
        (``_blockwise_attention.<locals>.q_block_inner``) and (b) the raw
        op_name metadata (covers VJP-transposed ops, whose op_name keeps the
        forward einsum labels, e.g. ``bhgqk,bkhd->bhgqd``)."""
        if not kr:
            return False
        mo = re.search(r'op_name="([^"]*)"', line)
        if mo and any(tok in mo.group(1) for tok in kr):
            return True
        m = _STACK_FRAME_ATTR.search(line)
        if not m:
            return False
        fns = frame_fns.get(int(m.group(1)), frozenset())
        return any(any(tok in fn for fn in fns) for tok in kr)

    # second pass: flops / bytes / collectives per computation
    for name, lines in comps.items():
        cs = CompStats()
        for line in lines:
            m = _OP_LINE.match(line)
            if not m:
                continue
            _, type_region, op = m.groups()
            if op in FREE_OPS:
                continue
            # --- flops
            if op == "dot":
                cs.flops += _dot_flops(line, shapes_by_name)
            # --- collectives (use result shape = per-device landed bytes)
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in COLLECTIVES:
                if not op.endswith("-done"):
                    nbytes = _bytes_of(_shape_list(type_region))
                    cs.coll_bytes[base_op] = (
                        cs.coll_bytes.get(base_op, 0.0) + nbytes)
                    cs.coll_count[base_op] = (
                        cs.coll_count.get(base_op, 0) + 1)
            # --- HBM traffic (anchor-based fusion model, see module doc)
            if op.endswith("-done") or _in_kernel_region(line):
                continue
            res_bytes = _data_bytes(_shape_list(type_region))
            _, _, post = line.partition(f" {op}(")
            arg_region = post.split(")")[0] if post else ""
            opnds = _OPERANDS.findall(arg_region)

            def opnd_bytes(i, chase: bool = False):
                if i < len(opnds) and opnds[i] in shapes_by_name:
                    dt, dims = shapes_by_name[opnds[i]]
                    if chase:
                        dt = _source_dtype(opnds[i])
                    if dt not in DTYPE_BYTES or not (
                            dt.startswith(("f", "bf", "c", "s", "u"))):
                        return 0.0
                    n = 1
                    for d in dims:
                        n *= d
                    return n * DTYPE_BYTES[dt]
                return 0.0

            if op in ("dot", "convolution"):
                # operands charged at their HBM source dtype (int8 caches /
                # bf16 weights read through fused upcasts stay 1-2 B/elem)
                contrib = res_bytes + sum(
                    opnd_bytes(i, chase=True) for i in range(len(opnds)))
            elif op == "dynamic-slice":
                # one READ of the slice, at the SOURCE buffer's dtype (a
                # convert fused into the slice consumer must not double the
                # charged bytes: bf16 caches were showing up as f32 reads)
                src_dt = (shapes_by_name.get(opnds[0], ("f32", []))[0]
                          if opnds else "f32")
                sh = _shape_list(type_region)
                if sh and src_dt in DTYPE_BYTES:
                    n = 1
                    for d in sh[0][1]:
                        n *= d
                    contrib = n * DTYPE_BYTES[src_dt]
                else:
                    contrib = res_bytes
            elif op == "dynamic-update-slice":
                contrib = opnd_bytes(1)                # in-place slice write
            elif op in ("reduce", "reduce-window", "sort", "gather",
                        "scatter"):
                contrib = res_bytes + sum(
                    opnd_bytes(i) for i in range(len(opnds)))
            elif base_op in COLLECTIVES and not op.endswith("-done"):
                contrib = res_bytes                    # landed buffer write
            elif op == "fusion":
                # only slice-update fusions are anchors; classify by the
                # called computation's name (CPU wraps DS/DUS/gather thus)
                called = re.search(r"calls=%?([\w\.\-]+)", line)
                cname = called.group(1) if called else ""
                if "dynamic-update-slice" in cname or "scatter" in cname:
                    small = min((opnd_bytes(i) for i in range(len(opnds))
                                 if opnd_bytes(i) > 0), default=0.0)
                    contrib = small                    # in-place update write
                elif "dynamic-slice" in cname:
                    contrib = res_bytes                # slice read
                elif "gather" in cname or "reduce" in cname:
                    contrib = 2 * res_bytes
                else:
                    contrib = 0.0                      # pointwise: fused
            else:
                contrib = 0.0                          # pointwise / control
            if contrib:
                cs.hbm_bytes += contrib
                sh = _shape_list(type_region)
                key = f"{op}:{sh[0][0]}{sh[0][1]}" if sh else op
                cs.bytes_by_shape[key] = (
                    cs.bytes_by_shape.get(key, 0.0) + contrib)
            # --- call graph
            if op == "while":
                body = cond = None
                mm = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                if mm:
                    body = mm.group(1)
                if mc:
                    cond = mc.group(1)
                mt = _KNOWN_TRIP.search(line)
                if mt:
                    tc = int(mt.group(1))
                else:
                    tc = _trip_count(comps.get(cond, [])) if cond else None
                if tc is None:
                    tc = 1
                    unknown_whiles += 1
                else:
                    known_whiles += 1
                if body:
                    cs.calls.append((body, float(tc), True))
            elif op == "conditional":
                mb = _CALL_REF_MULTI.search(line)
                if mb:
                    for ref in mb.group(1).split(","):
                        ref = ref.strip().lstrip("%")
                        if ref:
                            cs.calls.append((ref, 1.0, True))
                else:
                    for ref in re.findall(
                            r"(?:true_computation=|false_computation=)"
                            r"%?([\w\.\-]+)", line):
                        cs.calls.append((ref, 1.0, True))
            elif op == "call":
                for ref in re.findall(r"to_apply=%?([\w\.\-]+)", line):
                    cs.calls.append((ref, 1.0, True))
            else:
                # fusion / reduce / sort interiors: FLOPs only
                for ref in re.findall(
                        r"(?:calls=|to_apply=)%?([\w\.\-]+)", line):
                    cs.calls.append((ref, 1.0, False))
        stats[name] = cs

    # propagate through the call graph (memoized DFS)
    memo: Dict[str, HloStats] = {}
    visiting: Set[str] = set()

    def total(name: str) -> HloStats:
        if name in memo:
            return memo[name]
        if name in visiting or name not in stats:
            return HloStats()
        visiting.add(name)
        cs = stats[name]
        agg = HloStats(flops=cs.flops, hbm_bytes=cs.hbm_bytes,
                       coll_bytes=dict(cs.coll_bytes),
                       coll_count={k: float(v)
                                   for k, v in cs.coll_count.items()},
                       bytes_by_shape=dict(cs.bytes_by_shape))
        for callee, mult, count_bytes in cs.calls:
            sub = total(callee)
            agg.flops += mult * sub.flops
            if not count_bytes:
                continue
            agg.hbm_bytes += mult * sub.hbm_bytes
            for k, v in sub.coll_bytes.items():
                agg.coll_bytes[k] = agg.coll_bytes.get(k, 0.0) + mult * v
            for k, v in sub.coll_count.items():
                agg.coll_count[k] = agg.coll_count.get(k, 0.0) + mult * v
            for k, v in sub.bytes_by_shape.items():
                agg.bytes_by_shape[k] = (
                    agg.bytes_by_shape.get(k, 0.0) + mult * v)
        visiting.discard(name)
        memo[name] = agg
        return agg

    if not entry:
        # fall back: the computation with the most flops
        entry = max(stats, key=lambda n: stats[n].flops) if stats else ""
    out = total(entry)
    # entry parameters (weights, caches, batch) are streamed once per step
    for line in comps.get(entry, []):
        m = _OP_LINE.match(line)
        if m and m.group(3) == "parameter":
            out.hbm_bytes += _data_bytes(_shape_list(m.group(2)))
    out.n_while_known = known_whiles
    out.n_while_unknown = unknown_whiles
    return out
