"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device,
and only launch/dryrun.py sets XLA_FLAGS for 512 placeholder devices.

Mesh geometry (TPU v5e pods):
  single-pod   (16, 16)        axes ("data", "model")  — 256 chips
  multi-pod    (2, 16, 16)     axes ("pod", "data", "model") — 512 chips

The "model" axis carries TP / EP / sequence(cache) sharding; "data" carries
batch + FSDP parameter sharding; "pod" is pure data parallelism whose
gradient all-reduce crosses the inter-pod links (the axis gradient
compression targets — see optim/grad_utils.py).
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    # single-pod mesh on a 512-device host platform: take the first pod
    assert len(devices) >= n, (
        f"need {n} devices, have {len(devices)} — dryrun.py must set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=512 first")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh over however many devices this process sees (tests)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: Optional[int] = None, model: Optional[int] = None):
    """Small mesh over the host's actual devices — used by sharded CPU tests
    (run under XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    n = len(jax.devices())
    if data is None and model is None:
        model = 1
        data = n
    data = data or n // (model or 1)
    model = model or n // data
    assert data * model == n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"))


def phase_device_groups(devices: Optional[List] = None
                        ) -> Tuple[List, List]:
    """Split the visible devices into (prefill_group, decode_group) for
    disaggregated serving (serving/executor.DisaggregatedExecutor).

    HALO dedicates DIFFERENT hardware to each phase (CiM prefill, CiD
    decode); here the analogue is disjoint halves of the device list —
    prefill takes the first half, decode the second.  A single-device
    host cannot split, so both groups share that one device: program
    pinning becomes a no-op while the handoff/migration accounting (the
    2.5D-link analogue) still runs for real, which is what keeps greedy
    streams bit-identical colocated vs disaggregated in tests."""
    devs = list(devices) if devices is not None else list(jax.devices())
    if len(devs) < 2:
        return devs, devs
    half = len(devs) // 2
    return devs[:half], devs[half:]


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Batch-sharding axes: ("pod","data") when the pod axis exists."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
