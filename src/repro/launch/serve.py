"""Serving driver: batched requests through the phase-disaggregated engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 16 --prompt-len 48 --max-new 24 --strategy halo \
      --prefill-chunk 16 --max-prefill-tokens 32

Reports per-request TTFT/TPOT and the per-tick phase occupancy that the
chunked-prefill scheduler produces (fraction of ticks running prefill and
decode together — HALO's interleaved CiM/CiD utilization at serving level).
``--paged`` swaps the dense arena for the block-pool KV cache
(serving/kv_pool.py): capacity becomes pool-bounded (``--n-pages`` x
``--page-size`` tokens, so prompts may exceed --max-len), exhaustion
preempts the youngest request, and the report adds resident KV bytes +
preemption counts.  ``--kv-dtype int8`` stores KV pages quantized (GQA
k/v or MLA latents, per-token scale pages); ``--kv-dtype int4`` packs
GQA pages two nibbles per byte for ~4x KV-byte reduction.
``--weights-dtype int8`` quantizes matmul weights per output channel at
engine build and routes decode-shaped matmuls through the fused
dequantizing GEMV kernel — the TPU analogue of HALO's int8 CiD banks.
``--prefix-cache`` (with ``--paged``) reuses shared-prompt KV pages
copy-on-write through a radix prefix cache; ``--shared-prefix N`` gives
every request the same N-token prompt head so the cache has something to
hit, and the report adds hit rate + prefill tokens skipped.
``--speculative`` (with ``--paged``) turns on the draft/verify loop
(serving/speculative.py): ``--spec-k`` draft tokens per decode tick from
the model-free n-gram drafter, or from a small draft model with
``--draft <arch>``; the report adds acceptance rate and tokens/tick.

Sampling is PER REQUEST (``SamplingParams``): ``--temperature 0`` IS
greedy — the CLI no longer rewrites 0 to 1e-6 — and ``--temperature``,
``--top-k``, ``--top-p``, ``--stop-token`` (repeatable) apply to every
request.  ``--mixed-sampling`` makes odd-indexed requests sample at the
given temperature while even-indexed ones stay greedy — a mixed batch
runs in ONE program per tick, and the report's finish-reason counts
show what ended each stream.

Observability (docs/serving.md §Observability): ``--trace-out t.json``
records request-lifecycle spans and per-tick phase events as Chrome
trace-event JSON (open in Perfetto), ``--metrics m.prom`` dumps the
engine's metrics registry as Prometheus text, and ``--slo-ttft-ms`` /
``--slo-tpot-ms`` attach per-request deadlines so the report includes
goodput (fraction of requests meeting their SLO).

``--traffic`` replaces the fixed submit-everything-at-once batch with a
seeded arrival trace replayed through the ASYNC front-end
(serving/frontend.py + serving/traffic.py): ``--rate-rps`` arrivals per
second for ``--duration-s`` seconds (``--arrival onoff`` for bursty
ON-OFF instead of Poisson), prompt/output lengths drawn per request up
to --prompt-len/--max-new, paced in real time (``--time-scale`` scales
the clock; 0 submits in trace order with no waiting — deterministic).
``--admission`` turns on shed-before-thrash admission control: requests
whose projected TTFT busts their deadline are refused at submit
(``--admission-tick-cost-s`` fixes the projection's seconds-per-tick —
deterministic decisions — instead of the live tick-wall EMA;
``--max-pending-tokens`` adds the structural backpressure cap).  The
report becomes the traffic scorecard: goodput under SLO, TTFT/TPOT
percentiles over served requests, shed/defer rates, preemption counts.
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--strategy", default="halo",
                    choices=["halo", "cent", "attacc"])
    ap.add_argument("--prefill-chunk", type=int, default=2048,
                    help="tokens per prefill chunk (chunked prefill)")
    ap.add_argument("--max-prefill-tokens", type=int, default=8192,
                    help="per-tick prefill token budget")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 enables device-side sampling "
                         "(per-request SamplingParams — no epsilon rewrite)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=0.0,
                    help="nucleus sampling threshold (0 = off)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stop-token", type=int, action="append", default=[],
                    metavar="ID",
                    help="extra stop-token id (repeatable; finish reason "
                         "'stop')")
    ap.add_argument("--mixed-sampling", action="store_true",
                    help="odd-indexed requests sample at --temperature, "
                         "even-indexed ones stay greedy (exercises "
                         "per-request sampling heterogeneity in one "
                         "program)")
    ap.add_argument("--speculative", action="store_true",
                    help="speculative decoding: draft/verify loop over "
                         "the paged arena (paged only)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="draft tokens per verify window (default 4)")
    ap.add_argument("--draft", default=None, metavar="ARCH",
                    help="draft-model arch (e.g. qwen3-1.7b); default is "
                         "the model-free n-gram drafter")
    ap.add_argument("--paged", action="store_true",
                    help="paged block-pool KV arena (capacity = pool, "
                         "not max_len; preemption on exhaustion)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged)")
    ap.add_argument("--n-pages", type=int, default=64,
                    help="pages per run pool (paged)")
    ap.add_argument("--kv-dtype", default="f32",
                    choices=["f32", "int8", "int4"],
                    help="int8: quantized KV pages (GQA k/v or MLA latents) "
                         "with per-token scale pages; int4: packed GQA "
                         "pages, two nibbles per byte (paged only)")
    ap.add_argument("--weights-dtype", default="f32",
                    choices=["f32", "int8"],
                    help="int8: per-channel weight quantization at engine "
                         "build; decode-shaped matmuls run the fused "
                         "dequant GEMV (HALO's int8 CiD datapath)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache: shared-prompt KV pages are "
                         "reused copy-on-write (paged only)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of system prompt shared by every request "
                         "(exercises the prefix cache)")
    ap.add_argument("--executor", default="colocated",
                    choices=["colocated", "disaggregated"],
                    help="disaggregated: prefill/decode programs pinned to "
                         "separate device groups, KV migrates at the "
                         "prefill->decode handoff (HALO's 2.5D link)")
    ap.add_argument("--host-spill-pages", type=int, default=0,
                    help="host-memory KV tier size in pages per run: "
                         "preemption swaps pages out instead of "
                         "recomputing, prefix evictions demote to host "
                         "(paged only; 0 = off)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the run "
                         "(request lifecycle spans + per-tick phase "
                         "events; open in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="write the metrics registry as Prometheus-style "
                         "text exposition after the run")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="per-request TTFT deadline in ms; enables the "
                         "goodput / SLO-attainment report")
    ap.add_argument("--slo-tpot-ms", type=float, default=None,
                    help="per-request TPOT deadline in ms; enables the "
                         "goodput / SLO-attainment report")
    ap.add_argument("--traffic", action="store_true",
                    help="replace the fixed batch with a seeded arrival "
                         "trace replayed through the async front-end "
                         "(one client task per arrival); --requests is "
                         "ignored, the trace is --rate-rps x --duration-s")
    ap.add_argument("--rate-rps", type=float, default=None,
                    help="mean arrivals per second (traffic; default 4)")
    ap.add_argument("--duration-s", type=float, default=None,
                    help="trace horizon in seconds (traffic; default 2)")
    ap.add_argument("--arrival", default=None,
                    choices=["poisson", "onoff"],
                    help="arrival process (traffic; default poisson; "
                         "onoff = bursty exponential ON/OFF dwells)")
    ap.add_argument("--time-scale", type=float, default=None,
                    help="replay clock multiplier (traffic; default 1 = "
                         "real time; 0 = submit in trace order with no "
                         "waiting — deterministic)")
    ap.add_argument("--admission", action="store_true",
                    help="SLO-aware admission control: shed requests "
                         "whose projected TTFT busts their deadline "
                         "instead of letting the pool thrash")
    ap.add_argument("--admission-tick-cost-s", type=float, default=None,
                    help="fix the admission projection's seconds-per-tick "
                         "(deterministic decisions) instead of the live "
                         "tick-wall EMA")
    ap.add_argument("--max-pending-tokens", type=int, default=None,
                    help="structural backpressure cap on queued prefill "
                         "tokens: beyond it best-effort submits defer, "
                         "deadline-carrying ones shed")
    args = ap.parse_args(argv)
    if (args.draft or args.spec_k is not None) and not args.speculative:
        ap.error("--draft/--spec-k require --speculative")
    if args.speculative and not args.paged:
        ap.error("--speculative requires --paged (the draft/verify loop "
                 "runs over the paged arena)")
    if args.mixed_sampling and args.temperature <= 0.0:
        ap.error("--mixed-sampling needs --temperature > 0 (the stochastic "
                 "half samples at that temperature)")
    if args.kv_dtype != "f32" and not args.paged:
        ap.error("--kv-dtype int8/int4 requires --paged (quantized pages "
                 "live in the block-pool arena)")
    if args.host_spill_pages and not args.paged:
        ap.error("--host-spill-pages requires --paged (the spill tier "
                 "stores device pool pages)")
    if args.spec_k is None:
        args.spec_k = 4
    if not args.traffic and any(v is not None for v in (
            args.rate_rps, args.duration_s, args.arrival, args.time_scale)):
        ap.error("--rate-rps/--duration-s/--arrival/--time-scale require "
                 "--traffic")
    if not args.admission and (args.admission_tick_cost_s is not None
                               or args.max_pending_tokens is not None):
        ap.error("--admission-tick-cost-s/--max-pending-tokens require "
                 "--admission")
    if args.traffic and args.mixed_sampling:
        ap.error("--traffic and --mixed-sampling are exclusive (trace "
                 "requests share one SamplingParams)")
    if args.rate_rps is None:
        args.rate_rps = 4.0
    if args.duration_s is None:
        args.duration_s = 2.0
    if args.arrival is None:
        args.arrival = "poisson"
    if args.time_scale is None:
        args.time_scale = 1.0

    import jax
    from repro.configs.base import get_config
    from repro.models.transformer import init_params
    from repro.serving.engine import ServeConfig, ServingEngine
    from repro.serving.metrics import SLO
    from repro.serving.sampling import SamplingParams
    from repro.serving.scheduler import AdmissionConfig, PhaseAwareConfig
    from repro.serving.tracing import Tracer

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, dtype="float32")

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    spec = None
    if args.speculative:
        from repro.serving.speculative import SpecConfig
        spec = SpecConfig(
            k=args.spec_k,
            drafter="model" if args.draft else "ngram",
            draft_arch=args.draft)
    admission = None
    if args.admission:
        admission = AdmissionConfig(
            tick_cost_s=args.admission_tick_cost_s,
            max_pending_tokens=args.max_pending_tokens)
    sc = ServeConfig(
        max_batch=args.max_batch, max_len=args.max_len,
        phase=PhaseAwareConfig(strategy=args.strategy,
                               max_decode_batch=args.max_batch,
                               prefill_chunk=args.prefill_chunk,
                               max_prefill_tokens=args.max_prefill_tokens),
        seed=args.seed,
        paged=args.paged, page_size=args.page_size, n_pages=args.n_pages,
        kv_dtype=args.kv_dtype, weights_dtype=args.weights_dtype,
        prefix_cache=args.prefix_cache,
        speculative=spec,
        executor=args.executor, host_spill_pages=args.host_spill_pages,
        admission=admission)
    # tracing is opt-in: enabled=False keeps the hot loop at one branch
    # per instrumentation point and the token streams bit-identical
    tracer = Tracer(enabled=bool(args.trace_out))
    slo = None
    if args.slo_ttft_ms is not None or args.slo_tpot_ms is not None:
        slo = SLO(ttft_ms=args.slo_ttft_ms, tpot_ms=args.slo_tpot_ms)
    engine = ServingEngine(cfg, params, sc, tracer=tracer)

    rng = np.random.default_rng(args.seed)
    stop = tuple(args.stop_token)
    mode_s = "mixed" if args.mixed_sampling else (
        "greedy" if args.temperature <= 0.0 else
        f"t={args.temperature}")
    t0 = time.monotonic()
    if args.traffic:
        import asyncio

        from repro.serving.frontend import AsyncEngine
        from repro.serving.traffic import (TenantSpec, TrafficConfig,
                                           replay, synthesize)
        if cfg.n_codebooks > 1:
            ap.error("--traffic supports single-codebook archs only "
                     "(trace prompts are 1-D token sequences)")
        if args.shared_prefix >= args.prompt_len:
            ap.error("--traffic needs --shared-prefix < --prompt-len "
                     "(a prompt needs at least one non-shared token)")
        # prompt/output lengths draw uniformly from [half, full] so the
        # trace exercises mixed shapes the way real traffic does
        p_lo = max(args.shared_prefix + 1, (args.prompt_len + 1) // 2)
        tenant = TenantSpec(
            name="cli", rate_rps=args.rate_rps, arrival=args.arrival,
            prompt_len=(min(p_lo, args.prompt_len), args.prompt_len),
            output_len=(max(1, (args.max_new + 1) // 2), args.max_new),
            shared_prefix_len=args.shared_prefix,
            n_prefixes=2 if args.shared_prefix else 1,
            slo=slo)
        events = synthesize(TrafficConfig(
            tenants=(tenant,), duration_s=args.duration_s,
            seed=args.seed, vocab_size=cfg.vocab_size))
        sp = SamplingParams(temperature=args.temperature,
                            top_k=args.top_k, top_p=args.top_p,
                            seed=args.seed, stop=stop)

        async def _go():
            async with AsyncEngine(engine) as fe:
                return await replay(fe, events,
                                    time_scale=args.time_scale,
                                    sampling=sp)

        report = asyncio.run(_go())
        wall = time.monotonic() - t0
        print(f"arch={cfg.name} strategy={args.strategy} "
              f"chunk={args.prefill_chunk} chunked={engine.chunked} "
              f"sampling={mode_s} "
              f"traffic[{args.arrival} rate={args.rate_rps:g}rps "
              f"dur={args.duration_s:g}s scale={args.time_scale:g}] "
              f"admission={'on' if admission else 'off'}")
        print(report.render())
    else:
        shared = rng.integers(0, cfg.vocab_size,
                              (min(args.shared_prefix, args.prompt_len),),
                              dtype=np.int32)
        for i in range(args.requests):
            L = args.prompt_len
            if cfg.n_codebooks > 1:
                prompt = rng.integers(0, cfg.vocab_size,
                                      (cfg.n_codebooks, L), dtype=np.int32)
            else:
                tail = rng.integers(0, cfg.vocab_size, (L - len(shared),),
                                    dtype=np.int32)
                prompt = np.concatenate([shared, tail])
            # per-request sampling: --temperature 0 IS greedy (no 1e-6
            # rewrite); --mixed-sampling keeps even-indexed requests
            # greedy
            temp = args.temperature
            if args.mixed_sampling and i % 2 == 0:
                temp = 0.0
            engine.submit(prompt, sampling=SamplingParams(
                temperature=temp, top_k=args.top_k, top_p=args.top_p,
                seed=args.seed + i, max_new_tokens=args.max_new,
                stop=stop),
                slo=slo)
        done = engine.run_until_drained()
        wall = time.monotonic() - t0

        # NaN-guarded latency stats: a request that never emitted a token
        # (max_new 0, abort, stop on submit) reports NaN ttft/tpot and is
        # excluded here; its finish_reason is surfaced below instead
        ttfts = [r.ttft for r in done if not np.isnan(r.ttft)]
        tpots = [r.tpot for r in done if not np.isnan(r.tpot)]
        reasons = {}
        for r in done:
            reasons[r.finish_reason] = reasons.get(r.finish_reason, 0) + 1
        total_new = sum(len(r.generated) for r in done)
        reasons_s = " ".join(f"{k}={v}" for k, v in sorted(
            reasons.items(), key=lambda kv: str(kv[0])))
        print(f"arch={cfg.name} strategy={args.strategy} "
              f"chunk={args.prefill_chunk} chunked={engine.chunked} "
              f"sampling={mode_s} "
              f"requests={len(done)} tokens={total_new} wall={wall:.2f}s")
        ttft_p50 = np.median(ttfts) * 1e3 if ttfts else float("nan")
        tpot_p50 = np.median(tpots) * 1e3 if tpots else float("nan")
        print(f"TTFT p50={ttft_p50:.1f}ms  "
              f"TPOT p50={tpot_p50:.1f}ms  "
              f"throughput={total_new / wall:.1f} tok/s  "
              f"finish[{reasons_s}]")
    occ = engine.phase_occupancy()
    decode_ticks = [t.wall_s for t in engine.tick_log
                    if t.decode_reqs and not t.prefill_reqs]
    print(f"ticks={engine.n_ticks} "
          f"occupancy prefill={occ['prefill']:.2f} decode={occ['decode']:.2f} "
          f"mixed={occ['mixed']:.2f}  "
          f"decode-tick p50="
          f"{np.median(decode_ticks)*1e3 if decode_ticks else 0:.1f}ms  "
          f"host-transfers={engine.host_transfers}")
    kv = engine.kv_bytes()
    mode = (f"paged[{args.n_pages}x{args.page_size},{args.kv_dtype}]"
            if args.paged else f"dense[max_len={args.max_len}]")
    print(f"kv={mode} weights={args.weights_dtype} "
          f"reserved={kv['reserved']/1e6:.2f}MB "
          f"peak-resident={kv['peak_resident']/1e6:.2f}MB "
          f"preemptions={engine.preemptions}")
    if args.prefix_cache:
        ps = engine.prefix_stats()
        print(f"prefix-cache hit-rate={ps['hit_rate']:.2f} "
              f"tokens-from-cache={ps['hit_tokens']:.0f} "
              f"prefill-executed={ps['prefill_tokens_executed']:.0f} "
              f"cow-copies={ps['cow_copies']:.0f} "
              f"evicted-pages={ps['cache_evicted_pages']:.0f}")
    if args.speculative:
        ss = engine.spec_stats()
        drafter = args.draft or "ngram"
        print(f"speculative drafter={drafter} k={args.spec_k} "
              f"windows={ss['windows']:.0f} "
              f"acceptance={ss['acceptance_rate']:.2f} "
              f"tokens/tick={ss['tokens_per_tick']:.2f}")
    if args.executor == "disaggregated":
        xs = engine.executor.stats()
        print(f"disaggregated prefill-devices={xs['prefill_devices']} "
              f"decode-devices={xs['decode_devices']} "
              f"migrated-pages={xs['migrated_pages']} "
              f"migrated={xs['migrated_bytes']/1e6:.2f}MB "
              f"handoff-batches={xs['migration_batches']}")
    if args.host_spill_pages:
        c = engine.counts()
        print(f"host-tier pages={args.host_spill_pages} "
              f"swap-out={c['swap_out_bytes']/1e6:.2f}MB "
              f"swap-in={c['swap_in_bytes']/1e6:.2f}MB "
              f"swap-resumes={c['swap_resumes']} "
              f"recompute-resumes={c['recompute_preemptions']} "
              f"resident-pages={c['host_resident_pages']}")
    if slo is not None:
        g = engine.goodput()
        slo_s = " ".join(
            f"{k}={v}" for k, v in (("ttft_ms", args.slo_ttft_ms),
                                    ("tpot_ms", args.slo_tpot_ms))
            if v is not None)
        print(f"slo[{slo_s}] attained={g['slo_attained']}/{g['slo_total']} "
              f"goodput={g['goodput']:.2f} "
              f"ttft-violations={g['ttft_violations']} "
              f"tpot-violations={g['tpot_violations']}")
    if args.trace_out:
        engine.tracer.write(args.trace_out)
        print(f"trace: {len(engine.tracer.events())} events -> "
              f"{args.trace_out}")
    if args.metrics:
        snap = engine.metrics_snapshot()  # refreshes gauges before render
        with open(args.metrics, "w") as f:
            f.write(engine.metrics.render())
        print(f"metrics: {len(snap['counters'])} counters "
              f"{len(snap['gauges'])} gauges "
              f"{len(snap['histograms'])} histograms -> {args.metrics}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
