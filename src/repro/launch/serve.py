"""Serving driver: batched requests through the phase-disaggregated engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --requests 16 --prompt-len 48 --max-new 24 --strategy halo
"""

from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--strategy", default="halo",
                    choices=["halo", "cent", "attacc"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    from repro.configs.base import get_config
    from repro.models.transformer import init_params
    from repro.serving.engine import ServeConfig, ServingEngine
    from repro.serving.scheduler import PhaseAwareConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, dtype="float32")

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    sc = ServeConfig(max_batch=args.max_batch, max_len=args.max_len,
                     phase=PhaseAwareConfig(strategy=args.strategy,
                                            max_decode_batch=args.max_batch))
    engine = ServingEngine(cfg, params, sc)

    rng = np.random.default_rng(args.seed)
    t0 = time.monotonic()
    for i in range(args.requests):
        L = args.prompt_len
        if cfg.n_codebooks > 1:
            prompt = rng.integers(0, cfg.vocab_size,
                                  (cfg.n_codebooks, L), dtype=np.int32)
        else:
            prompt = rng.integers(0, cfg.vocab_size, (L,), dtype=np.int32)
        engine.submit(prompt, max_new_tokens=args.max_new)
    done = engine.run_until_drained()
    wall = time.monotonic() - t0

    ttfts = [r.ttft for r in done]
    tpots = [r.tpot for r in done]
    total_new = sum(len(r.generated) for r in done)
    print(f"arch={cfg.name} strategy={args.strategy} "
          f"requests={len(done)} tokens={total_new} wall={wall:.2f}s")
    print(f"TTFT p50={np.median(ttfts)*1e3:.1f}ms  "
          f"TPOT p50={np.median(tpots)*1e3:.1f}ms  "
          f"throughput={total_new / wall:.1f} tok/s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
