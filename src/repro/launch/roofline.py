"""Roofline analysis from compiled dry-run artifacts.

Three terms, all in seconds, per (arch x shape x mesh) cell — the module XLA
gives us after SPMD partitioning is the PER-DEVICE program, so every quantity
below is per-chip and is divided by per-chip peaks:

  compute    = HLO_FLOPs_per_chip / peak_FLOPs          (197 TF/s bf16, v5e)
  memory     = HLO_bytes_per_chip / HBM_bw              (819 GB/s)
  collective = link_bytes_per_chip / ICI_bw             (~50 GB/s/link)

``cost_analysis()`` provides flops and bytes.  Collective bytes are NOT in
cost_analysis: we parse the post-SPMD HLO (``compiled.as_text()``) and sum
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, weighting all-reduce x2 (ring = reduce-scatter +
all-gather).  Per-op shapes like ``bf16[8,128,2048]`` are parsed directly.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

# TPU v5e hardware constants (task sheet)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

COLLECTIVE_WEIGHT = {
    "all-reduce": 2.0,        # ring: RS + AG
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"ragged-all-to-all)(?:-start)?\(")


def _shape_bytes(text: str) -> float:
    """Sum byte sizes of every dtype[dims] group in a shape string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def weighted_bytes(self) -> float:
        return sum(COLLECTIVE_WEIGHT.get(k, 1.0) * v
                   for k, v in self.bytes_by_kind.items())

    @property
    def raw_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum RESULT-shape bytes of every collective op in post-SPMD HLO.

    The result shape (left of '=') is what lands on each device; for
    all-reduce it equals the operand shape, for all-gather it is the gathered
    output.  '-start' variants (async) are counted; '-done' ops carry the
    same buffer and are skipped to avoid double counting.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if "-done" in s.split("=")[0] if "=" in s else False:
            continue
        m = _OP_RE.search(s)
        if not m:
            continue
        kind = m.group(1)
        if f"{kind}-done" in s:
            continue
        # result shape: text between '=' and the op name
        lhs_rhs = s.split("=", 1)
        if len(lhs_rhs) != 2:
            continue
        result_part = lhs_rhs[1].split(kind)[0]
        nbytes = _shape_bytes(result_part)
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0.0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class Roofline:
    flops: float                  # per-chip HLO flops
    hbm_bytes: float              # per-chip bytes accessed
    coll_bytes: float             # per-chip weighted collective bytes
    model_flops: float            # 6*N*D (or 6*N_active*D) useful flops, total
    n_chips: int
    collectives: Optional[CollectiveStats] = None
    xla_flops: float = 0.0        # XLA cost_analysis (loop bodies counted 1x)
    xla_bytes: float = 0.0
    n_while_unknown: int = 0      # while loops whose trip count we missed

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline lower bound on step time (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        """MODEL_FLOPS / (per-chip HLO flops * chips) — remat/pad waste."""
        total_hlo = self.flops * self.n_chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_frac(self) -> float:
        """Fraction of the dominant-term roofline the USEFUL flops achieve:
        (model_flops / chips / peak) / t_bound — 1.0 means the step is
        perfectly compute-bound with zero overhead flops."""
        t_useful = self.model_flops / self.n_chips / PEAK_FLOPS
        return t_useful / self.t_bound if self.t_bound else 0.0

    def row(self) -> Dict[str, float]:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
            "xla_flops_per_chip": self.xla_flops,
            "xla_bytes_per_chip": self.xla_bytes,
            "n_while_unknown": self.n_while_unknown,
        }


def model_flops_for(cfg, shape_kind: str, seq_len: int, batch: int,
                    n_new_tokens: int = 1) -> float:
    """MODEL_FLOPS = 6*N*D for train, 2*N*D for inference forward (per the
    standard convention), with N = active params (MoE counts top-k only)."""
    n_active = cfg.active_param_count()
    if shape_kind == "train":
        tokens = seq_len * batch
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = seq_len * batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    tokens = n_new_tokens * batch
    flops = 2.0 * n_active * tokens
    # add KV-cache attention flops (not in param count): 2 * 2 * ctx * H * dh
    try:
        if cfg.family in ("ssm",):
            pass
        else:
            dh = cfg.d_head
            H = cfg.n_heads
            kinds = cfg.layer_kinds()
            for k in kinds:
                if k == "ssm":
                    continue
                ctx = seq_len
                if k == "attn_local" and cfg.attn.sliding_window:
                    ctx = min(seq_len, cfg.attn.sliding_window)
                flops += tokens * 4.0 * ctx * H * dh
    except Exception:
        pass
    return flops


def analyze(compiled, *, n_chips: int, model_flops: float,
            hlo_text: Optional[str] = None,
            kernel_regions: Tuple[str, ...] = ()) -> Roofline:
    """Roofline terms from the compiled module.

    FLOPs / bytes / collective bytes come from our own HLO static analysis
    (launch/hlo_analysis.py) because XLA's cost_analysis counts while-loop
    bodies once — our lax.scan layer stacks would be under-reported 28-80x.
    XLA's numbers are kept as a cross-check in ``xla_*``.

    ``kernel_regions``: Python function names whose HLO is deployed as a
    Pallas TPU kernel — their internal tensors are VMEM-resident and charged
    zero HBM traffic (see hlo_analysis module doc).  Empty for baselines.
    """
    from repro.launch.hlo_analysis import analyze_hlo

    ca = compiled.cost_analysis()
    if isinstance(ca, list):                 # some backends return [dict]
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))

    text = hlo_text if hlo_text is not None else compiled.as_text()
    hs = analyze_hlo(text, kernel_regions=kernel_regions)
    weighted = sum(COLLECTIVE_WEIGHT.get(k, 1.0) * v
                   for k, v in hs.coll_bytes.items())
    coll = CollectiveStats(bytes_by_kind=dict(hs.coll_bytes),
                           count_by_kind={k: int(v) for k, v
                                          in hs.coll_count.items()})
    return Roofline(flops=max(hs.flops, xla_flops),
                    hbm_bytes=hs.hbm_bytes,
                    coll_bytes=weighted,
                    model_flops=model_flops, n_chips=n_chips,
                    collectives=coll,
                    xla_flops=xla_flops, xla_bytes=xla_bytes,
                    n_while_unknown=hs.n_while_unknown)


def memory_summary(compiled) -> Dict[str, float]:
    try:
        m = compiled.memory_analysis()
        return {
            "argument_gb": m.argument_size_in_bytes / 2**30,
            "output_gb": m.output_size_in_bytes / 2**30,
            "temp_gb": m.temp_size_in_bytes / 2**30,
            "alias_gb": getattr(m, "alias_size_in_bytes", 0) / 2**30,
            "code_gb": getattr(m, "generated_code_size_in_bytes", 0) / 2**30,
        }
    except Exception as e:                    # backend without the API
        return {"error": str(e)}
