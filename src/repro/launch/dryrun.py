import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent: for each cell we
build the production mesh (16x16 single-pod / 2x16x16 multi-pod), lower the
appropriate step function against ShapeDtypeStruct inputs with the real
shardings, compile it, and extract:

  * memory_analysis()  — per-chip bytes: proves the cell fits 16 GB HBM;
  * cost_analysis()    — per-chip FLOPs / bytes for the roofline terms;
  * post-SPMD HLO      — collective-op bytes for the collective term.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp


def _cell_id(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}|{shape}|{'pod2' if multi_pod else 'pod1'}"


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             save_hlo: str | None = None, donate: bool = True,
             seq_parallel: bool = False, q8_kv: bool = False,
             int8_weights: bool = False,
             n_microbatches: int = 1, variant: str = "") -> dict:
    """Lower + compile one cell; return the stats row."""
    from repro.configs.base import SHAPES, get_config
    from repro.distributed.sharding import (
        shardings_from_pspecs, train_state_pspecs)
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import analyze, memory_summary, model_flops_for
    from repro.launch.specs import (
        decode_input_specs, prefill_input_specs, train_input_specs)
    from repro.launch.steps import (
        make_decode_step, make_prefill_step, make_train_step)
    from repro.launch.training_config import optimizer_policy
    from repro.models.transformer import init_params
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.policy import ShardingPolicy, sharding_policy

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()

    batch_axes = ("pod", "data") if multi_pod else ("data",)
    n_data = 32 if multi_pod else 16
    shard_batch = shape.global_batch % n_data == 0
    seq_axes = ("model",) if shard_batch else (batch_axes + ("model",))
    policy = ShardingPolicy(mesh, batch_axes=batch_axes,
                            seq_axes=seq_axes, shard_batch=shard_batch,
                            seq_parallel=seq_parallel and shape.kind != "decode")

    params_tree = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    if int8_weights and shape.kind != "train":
        from repro.serving.quantized_weights import quantize_params
        params_tree = jax.eval_shape(quantize_params, params_tree)

    if shape.kind == "train":
        optimizer = optimizer_policy(cfg)
        opt_tree = jax.eval_shape(optimizer.init, params_tree)
        pspecs = train_state_pspecs(cfg, opt_state_tree=opt_tree,
                                    params_tree=params_tree)
        state_shard = shardings_from_pspecs(mesh, pspecs)
        state_specs = {
            "params": params_tree,
            "opt_state": opt_tree,
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        in_specs, in_shard = train_input_specs(cfg, shape, mesh)
        step = make_train_step(cfg, optimizer, n_microbatches=n_microbatches)
        metrics_shard = {k: NamedSharding(mesh, P()) for k in
                         ("loss", "ce", "aux", "grad_norm")}
        with mesh, sharding_policy(policy):
            jitted = jax.jit(
                step,
                in_shardings=(state_shard, in_shard),
                out_shardings=(state_shard, metrics_shard),
                donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state_specs, in_specs)
            compiled = lowered.compile()
        mf = model_flops_for(cfg, "train", shape.seq_len, shape.global_batch)

    elif shape.kind == "prefill":
        params_psp = train_state_pspecs(cfg, params_tree=params_tree)["params"]
        if seq_parallel:
            # inference: embed / lm_head stored replicated — GSPMD otherwise
            # all-gathers the full f32 table per step (§Perf B3)
            from jax.sharding import PartitionSpec as _P
            params_psp = dict(params_psp)
            for k in ("embed", "lm_head"):
                if k in params_psp:
                    params_psp[k] = jax.tree.map(
                        lambda s: _P(), params_psp[k],
                        is_leaf=lambda x: isinstance(x, _P))
        pspec = shardings_from_pspecs(mesh, params_psp)
        in_specs, in_shard = prefill_input_specs(cfg, shape, mesh)
        step = make_prefill_step(cfg)
        with mesh, sharding_policy(policy):
            jitted = jax.jit(step, in_shardings=(pspec, in_shard))
            lowered = jitted.lower(params_tree, in_specs)
            compiled = lowered.compile()
        mf = model_flops_for(cfg, "prefill", shape.seq_len, shape.global_batch)

    else:  # decode
        # decode weights are read-only: TP-only sharding, data axis = batch
        pspec = shardings_from_pspecs(
            mesh, train_state_pspecs(cfg, fsdp_axis=None,
                                     params_tree=params_tree)["params"])
        specs, shards = decode_input_specs(cfg, shape, mesh, q8_kv=q8_kv)
        step = make_decode_step(cfg)
        with mesh, sharding_policy(policy):
            jitted = jax.jit(
                step,
                in_shardings=(pspec, shards["batch"], shards["cache"],
                              shards["pos"]),
                donate_argnums=(2,) if donate else ())
            lowered = jitted.lower(params_tree, specs["batch"],
                                   specs["cache"], specs["pos"])
            compiled = lowered.compile()
        mf = model_flops_for(cfg, "decode", shape.seq_len, shape.global_batch)

    compile_s = time.time() - t0
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    # with int8 weights the decode matmuls route through the Pallas GEMV
    # (in-kernel dequant, models/layers.matmul); on this CPU dry-run host
    # the kernel traces in interpret mode as jit(gemv) while-loops whose
    # internal slices would be mis-charged as HBM traffic.  Deployed, the
    # kernel region is VMEM-resident — same modeling step as the flash
    # regions in reanalyze.py; the s8 banks themselves stay charged once
    # via the entry parameters.
    regions = ("jit(gemv)",) if int8_weights and shape.kind == "decode" else ()
    roof = analyze(compiled, n_chips=n_chips, model_flops=mf, hlo_text=hlo,
                   kernel_regions=regions)
    mem = memory_summary(compiled)
    cid = _cell_id(arch, shape_name, multi_pod)
    if variant:
        cid += f"|{variant}"
    row = {
        "cell": cid,
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "compile_s": round(compile_s, 1),
        "memory": mem,
        "collectives": {
            "bytes_by_kind": roof.collectives.bytes_by_kind,
            "count_by_kind": roof.collectives.count_by_kind,
        },
        **roof.row(),
    }
    return row


def applicable_cells(multi_pod: bool):
    from repro.configs.base import applicable_shapes, get_config, list_archs
    cells = []
    for arch in list_archs():
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            cells.append((arch, shape.name, multi_pod))
    return cells


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true",
                    help="sequence-parallel prefill/train sharding (§Perf)")
    ap.add_argument("--q8-kv", action="store_true",
                    help="int8 KV cache for decode cells (HALO-faithful)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation microbatches for train cells")
    ap.add_argument("--int8-weights", action="store_true",
                    help="weight-only int8 for inference cells (HALO int8)")
    ap.add_argument("--variant", default="",
                    help="label appended to the cell id in the output row")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args(argv)

    if args.all:
        cells = applicable_cells(args.multi_pod)
        if args.both_meshes:
            cells = applicable_cells(False) + applicable_cells(True)
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape, args.multi_pod)]

    done = set()
    if args.skip_done and args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    done.add(json.loads(line)["cell"])
                except Exception:
                    pass

    failures = []
    for arch, shape, mp in cells:
        cid = _cell_id(arch, shape, mp)
        if cid in done:
            print(f"SKIP {cid} (done)", flush=True)
            continue
        print(f"==== {cid} ====", flush=True)
        save_hlo = None
        if args.hlo_dir:
            os.makedirs(args.hlo_dir, exist_ok=True)
            fname = cid + (f"|{args.variant}" if args.variant else "")
            save_hlo = os.path.join(args.hlo_dir,
                                    fname.replace("|", "_") + ".hlo")
        try:
            row = run_cell(arch, shape, multi_pod=mp, save_hlo=save_hlo,
                           seq_parallel=args.seq_parallel, q8_kv=args.q8_kv,
                           int8_weights=args.int8_weights,
                           n_microbatches=args.microbatches,
                           variant=args.variant)
            print(json.dumps(
                {k: row[k] for k in ("cell", "compile_s", "t_compute_s",
                                     "t_memory_s", "t_collective_s",
                                     "bottleneck", "useful_flops_frac")},
                default=str), flush=True)
            if args.out:
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "a") as f:
                    f.write(json.dumps(row, default=str) + "\n")
        except Exception as e:
            traceback.print_exc()
            failures.append((cid, repr(e)))
            print(f"FAIL {cid}: {e}", flush=True)

    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for cid, err in failures:
            print(f"  {cid}: {err}")
        return 1
    print(f"\nall {len(cells)} cells OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
