"""Fault tolerance: heartbeats, restart policy, straggler mitigation,
elastic rescale planning.

On a real multi-pod deployment these hooks sit in the launcher (one process
per host, jax.distributed initialized); the control logic below is
host-agnostic and fully unit-tested here with simulated clocks/failures:

  * ClusterMonitor — heartbeat table; a worker missing ``timeout`` seconds
    of heartbeats is declared dead; the monitor triggers the restart policy.
  * RestartPolicy — decides between IN-PLACE restart (single worker flake:
    rejoin from the latest checkpoint), ELASTIC DOWN (lost capacity:
    continue on a smaller data axis) and ABORT (below quorum).
  * StragglerMitigator — per-step worker timing EWMA; workers persistently
    slower than ``threshold`` x median are flagged for eviction — on TPU
    pods a straggler stalls every collective, so eviction + elastic-down
    beats waiting (the same logic used by production SPMD trainers).
  * plan_elastic_rescale — maps a desired worker count to a new mesh shape
    and the data-slice remapping (loader.with_workers) that preserves the
    global batch stream.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Tuple


class WorkerState(Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    DEAD = "dead"
    EVICTED = "evicted"


@dataclass
class WorkerInfo:
    worker_id: int
    last_heartbeat: float
    state: WorkerState = WorkerState.HEALTHY
    step_times: List[float] = field(default_factory=list)
    ewma_step_s: float = 0.0


class ClusterMonitor:
    """Heartbeat table over N workers.  ``clock`` injectable for tests."""

    def __init__(self, n_workers: int, *, timeout_s: float = 60.0,
                 suspect_s: float = 20.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.suspect_s = suspect_s
        self.clock = clock
        now = clock()
        self.workers: Dict[int, WorkerInfo] = {
            w: WorkerInfo(w, now) for w in range(n_workers)}

    def heartbeat(self, worker_id: int) -> None:
        w = self.workers[worker_id]
        w.last_heartbeat = self.clock()
        if w.state == WorkerState.SUSPECT:
            w.state = WorkerState.HEALTHY

    def sweep(self) -> List[int]:
        """Advance state machine; returns newly-dead worker ids."""
        now = self.clock()
        newly_dead = []
        for w in self.workers.values():
            if w.state in (WorkerState.DEAD, WorkerState.EVICTED):
                continue
            silence = now - w.last_heartbeat
            if silence > self.timeout_s:
                w.state = WorkerState.DEAD
                newly_dead.append(w.worker_id)
            elif silence > self.suspect_s:
                w.state = WorkerState.SUSPECT
        return newly_dead

    def healthy(self) -> List[int]:
        return [w.worker_id for w in self.workers.values()
                if w.state in (WorkerState.HEALTHY, WorkerState.SUSPECT)]

    def evict(self, worker_id: int) -> None:
        self.workers[worker_id].state = WorkerState.EVICTED


class Action(Enum):
    CONTINUE = "continue"
    RESTART_IN_PLACE = "restart_in_place"   # worker rejoins from checkpoint
    ELASTIC_DOWN = "elastic_down"           # shrink the data axis
    ABORT = "abort"


@dataclass
class RestartPolicy:
    """min_quorum: fraction of workers below which training aborts.
    max_in_place: how many times a worker may flake before being treated
    as lost capacity."""

    n_workers: int
    min_quorum: float = 0.5
    max_in_place: int = 3
    _flakes: Dict[int, int] = field(default_factory=dict)

    def decide(self, dead: List[int], n_healthy: int) -> Action:
        if not dead:
            return Action.CONTINUE
        if n_healthy < math.ceil(self.min_quorum * self.n_workers):
            return Action.ABORT
        for w in dead:
            self._flakes[w] = self._flakes.get(w, 0) + 1
        if all(self._flakes[w] <= self.max_in_place for w in dead):
            return Action.RESTART_IN_PLACE
        return Action.ELASTIC_DOWN


class StragglerMitigator:
    """EWMA per-worker step times; flag persistent stragglers.

    ``threshold``: multiple of the healthy median that counts as straggling;
    ``patience``: consecutive flagged steps before eviction is recommended.
    """

    def __init__(self, n_workers: int, *, threshold: float = 1.5,
                 patience: int = 5, alpha: float = 0.3):
        self.threshold = threshold
        self.patience = patience
        self.alpha = alpha
        self.ewma: Dict[int, float] = {w: 0.0 for w in range(n_workers)}
        self.strikes: Dict[int, int] = {w: 0 for w in range(n_workers)}

    def record_step(self, times: Dict[int, float]) -> List[int]:
        """times: worker -> step seconds.  Returns workers to evict."""
        for w, t in times.items():
            prev = self.ewma.get(w, 0.0)
            self.ewma[w] = t if prev == 0.0 else (
                self.alpha * t + (1 - self.alpha) * prev)
        vals = sorted(v for v in self.ewma.values() if v > 0)
        if not vals:
            return []
        median = vals[len(vals) // 2]
        evict = []
        for w, v in self.ewma.items():
            if v > self.threshold * median:
                self.strikes[w] = self.strikes.get(w, 0) + 1
                if self.strikes[w] >= self.patience:
                    evict.append(w)
            else:
                self.strikes[w] = 0
        return evict


# ---------------------------------------------------------------------------
# elastic rescale planning
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ElasticPlan:
    old_workers: int
    new_workers: int
    new_mesh_shape: Tuple[int, ...]
    new_axes: Tuple[str, ...]
    note: str


def plan_elastic_rescale(n_available: int, *, model_parallel: int = 16,
                         chips_per_worker: int = 8) -> ElasticPlan:
    """Largest power-of-two data axis that the surviving chips support,
    keeping the model axis intact (TP degree is architecture-bound; the
    data axis is the elastic one).  Worker = host with 8 chips (v5e)."""
    chips = n_available * chips_per_worker
    data = max(1, chips // model_parallel)
    data = 2 ** int(math.log2(data))
    used_chips = data * model_parallel
    used_workers = used_chips // chips_per_worker
    return ElasticPlan(
        old_workers=n_available,
        new_workers=used_workers,
        new_mesh_shape=(data, model_parallel),
        new_axes=("data", "model"),
        note=(f"{n_available} hosts x{chips_per_worker} chips -> mesh "
              f"({data},{model_parallel}), {n_available - used_workers} "
              "hosts held as hot spares"),
    )
