from repro.runtime.fault_tolerance import (
    WorkerState,
    ClusterMonitor,
    RestartPolicy,
    StragglerMitigator,
    ElasticPlan,
    plan_elastic_rescale,
)
from repro.runtime.trainer import Trainer, TrainerConfig

__all__ = [
    "WorkerState", "ClusterMonitor", "RestartPolicy", "StragglerMitigator",
    "ElasticPlan", "plan_elastic_rescale", "Trainer", "TrainerConfig",
]
