"""Trainer: the fault-tolerant training loop.

Single-process version of the per-host agent: steps the jitted train_step,
heartbeats the monitor, checkpoints on schedule, restores-or-initializes on
start, and applies the restart policy when failures are injected (tests) or
detected (deployment).  The same loop runs the CPU examples (tiny configs,
mesh=None) and the full pods (mesh + shardings from distributed/).
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.store import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, ShardedLoader, make_loader
from repro.launch.steps import init_train_state, make_train_step
from repro.optim.optimizers import Optimizer
from repro.runtime.fault_tolerance import (
    Action,
    ClusterMonitor,
    RestartPolicy,
    StragglerMitigator,
)

log = logging.getLogger("repro.trainer")


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    checkpoint_keep: int = 3
    async_checkpoint: bool = True
    log_every: int = 10
    n_microbatches: int = 1
    clip_norm: float = 1.0
    seed: int = 0
    remat: bool = True


class Trainer:
    def __init__(self, cfg: ModelConfig, optimizer: Optimizer,
                 data_cfg: DataConfig, tc: TrainerConfig,
                 *, mesh=None, state_shardings=None, batch_shardings=None,
                 loader: Optional[ShardedLoader] = None):
        self.cfg = cfg
        self.optimizer = optimizer
        self.tc = tc
        self.mesh = mesh
        self.loader = loader or make_loader(data_cfg)
        self.ckpt = (CheckpointManager(tc.checkpoint_dir,
                                       keep=tc.checkpoint_keep)
                     if tc.checkpoint_dir else None)
        self.monitor = ClusterMonitor(1)
        self.policy = RestartPolicy(1)
        self.straggler = StragglerMitigator(1)
        step_fn = make_train_step(cfg, optimizer,
                                  n_microbatches=tc.n_microbatches,
                                  clip_norm=tc.clip_norm, remat=tc.remat)
        jit_kw: Dict[str, Any] = {"donate_argnums": (0,)}
        if state_shardings is not None:
            jit_kw["in_shardings"] = (state_shardings, batch_shardings)
            jit_kw["out_shardings"] = (state_shardings, None)
        self.train_step = jax.jit(step_fn, **jit_kw)
        self.state = None
        self.history: list = []

    # -- lifecycle -----------------------------------------------------------
    def init_or_restore(self) -> int:
        """Returns the step to resume from (0 for fresh runs)."""
        key = jax.random.PRNGKey(self.tc.seed)
        self.state = init_train_state(key, self.cfg, self.optimizer)
        if self.ckpt and self.ckpt.latest_step() is not None:
            step = self.ckpt.latest_step()
            self.state = self.ckpt.restore(self.state)
            extra = self.ckpt.restore_extra()
            self.loader.load_state_dict(
                extra.get("loader", {"step": step}))
            log.info("restored checkpoint at step %d", step)
            return int(step)
        return 0

    def save(self, step: int) -> None:
        if not self.ckpt:
            return
        self.ckpt.save(step, self.state,
                       blocking=not self.tc.async_checkpoint,
                       extra={"loader": self.loader.state_dict()})

    # -- main loop -----------------------------------------------------------
    def run(self, on_step: Optional[Callable[[int, Dict], None]] = None
            ) -> Dict[str, Any]:
        start = self.init_or_restore()
        t_last = time.monotonic()
        for step in range(start, self.tc.total_steps):
            batch = self.loader.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if self.mesh is not None:
                with self.mesh:
                    self.state, metrics = self.train_step(self.state, batch)
            else:
                self.state, metrics = self.train_step(self.state, batch)
            self.monitor.heartbeat(0)
            dead = self.monitor.sweep()
            action = self.policy.decide(dead, len(self.monitor.healthy()))
            if action == Action.ABORT:
                raise RuntimeError("cluster below quorum")
            now = time.monotonic()
            self.straggler.record_step({0: now - t_last})
            t_last = now
            m = {k: float(v) for k, v in metrics.items()}
            self.history.append(m)
            if on_step:
                on_step(step, m)
            if self.tc.log_every and step % self.tc.log_every == 0:
                log.info("step %d loss %.4f grad_norm %.3f",
                         step, m["loss"], m["grad_norm"])
            if (self.tc.checkpoint_every
                    and (step + 1) % self.tc.checkpoint_every == 0):
                self.save(step + 1)
        if self.ckpt:
            self.ckpt.wait()
        return {"final_loss": self.history[-1]["loss"] if self.history
                else float("nan"),
                "steps_run": len(self.history)}
