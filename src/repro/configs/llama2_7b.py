"""llama2-7b — the paper's primary evaluation model (HALO Section V).

32L d_model=4096 32H (MHA) d_ff=11008 vocab=32000.
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    attn=AttnConfig(rope_theta=10000.0),
    source="arXiv:2307.09288",
    notes="paper eval model (HALO Fig. 4-10)",
))
