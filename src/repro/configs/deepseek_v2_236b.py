"""deepseek-v2-236b — MLA (kv_lora=512) + 160-expert top-6 MoE with 2 shared.

[arXiv:2405.04434; hf]  60L d_model=5120 128H d_ff=1536(expert) vocab=102400,
MoE 160e top-6, 2 shared experts, first layer dense (d_ff 12288).
"""
from repro.configs.base import AttnConfig, MLAConfig, MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # MLA: kv heads materialized from the latent
    d_head=128,
    d_ff=12288,              # dense-FFN width for the first dense layer
    vocab_size=102400,
    attn=AttnConfig(rope_theta=10000.0),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared_experts=2, d_ff_expert=1536,
                  first_dense_layers=1),
    source="arXiv:2405.04434",
    notes="MLA compressed KV cache (c_kv=512 + rope 64 per token per layer)",
))
