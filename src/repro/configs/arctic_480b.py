"""arctic-480b — 128-expert top-2 MoE with a parallel dense residual FFN.

[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2, dense residual.
"""
from repro.configs.base import AttnConfig, MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    attn=AttnConfig(rope_theta=10000.0),
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True, d_ff_dense=4864),
    source="hf:Snowflake/snowflake-arctic-base",
    notes="dense-MoE hybrid: every layer = dense FFN residual + 128e top-2 MoE",
))
