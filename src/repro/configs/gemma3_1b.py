"""gemma3-1b — 5:1 local:global attention, 128k-class context.

[hf:google/gemma-3-1b-pt; unverified]  26L d_model=1152 4H (GQA kv=1)
d_ff=6912 vocab=262144.  Five sliding-window (512) layers per one global
layer; local layers use rope_theta=10k, global layers 1M.
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab_size=262144,
    tie_embeddings=True,
    act="gelu",
    attn=AttnConfig(sliding_window=512, local_global_ratio=5,
                    qk_norm=True, rope_theta=1_000_000.0,
                    rope_local_theta=10_000.0),
    source="hf:google/gemma-3-1b-pt",
    notes="5:1 local:global; runs long_500k (only 1/6 of layers keep a full cache)",
))
