"""mamba2-2.7b — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified]  64L d_model=2560 d_ff=0 vocab=50280 ssm_state=128.
Pure Mamba-2: each block is in_proj -> conv -> SSD -> gated norm -> out_proj,
no separate FFN (d_ff=0).
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=80,              # d_inner(5120) / head_dim(64)
    n_kv_heads=80,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    source="arXiv:2405.21060",
    notes="SSD (state-space duality); attention-free; runs long_500k",
))
