"""Config system for the repro framework.

Every architecture is described by a single :class:`ModelConfig` dataclass.
Configs are registered by id (``--arch <id>``) in :data:`REGISTRY` and each
config module in this package registers itself on import.

Two kinds of configs exist:
  * FULL configs — the exact published architecture.  These are only ever
    *lowered* (dry-run, ShapeDtypeStruct) and never allocated on this host.
  * REDUCED configs — ``cfg.reduced()`` returns a tiny config of the same
    family used by CPU smoke tests (few layers, small width, tiny vocab).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0          # DeepSeek-style always-on shared experts
    d_ff_expert: int = 0               # per-expert hidden size
    dense_residual: bool = False       # Arctic-style parallel dense FFN
    d_ff_dense: int = 0                # hidden size of the parallel dense FFN
    router_dtype: str = "float32"
    capacity_factor: float = 1.25      # only used by dropping implementations
    first_dense_layers: int = 0        # DeepSeek: first N layers are dense FFN

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention configuration."""

    kv_lora_rank: int = 0              # compressed KV latent dim (c_kv)
    q_lora_rank: int = 0               # compressed Q latent dim (0 = full-rank Q)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD configuration."""

    d_state: int = 0                   # N — SSM state size per head
    d_conv: int = 4                    # depthwise conv window
    expand: int = 2                    # d_inner = expand * d_model
    head_dim: int = 64                 # P — SSD head dim
    n_groups: int = 1                  # B/C groups (GVA-style)
    chunk_size: int = 256              # SSD chunk length for training/prefill

    @property
    def enabled(self) -> bool:
        return self.d_state > 0

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class AttnConfig:
    """Attention variant configuration."""

    sliding_window: int = 0            # 0 = full attention
    local_global_ratio: int = 0        # gemma3: N local layers per 1 global
    qk_norm: bool = False              # qwen3-style per-head RMSNorm on q,k
    rope_theta: float = 10000.0
    rope_local_theta: float = 0.0      # gemma3 uses a different theta for local
    logit_softcap: float = 0.0


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: SSM backbone + shared attention block."""

    shared_attn_every: int = 0         # apply the shared block every N ssm layers
    shared_attn_n_heads: int = 0
    concat_embedding: bool = True      # shared block sees concat([h, embed])

    @property
    def enabled(self) -> bool:
        return self.shared_attn_every > 0


# ---------------------------------------------------------------------------
# The main config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "ssm", "hybrid", "moe", "vlm", "audio")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                    # 0 -> d_model // n_heads
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"                  # FFN activation (silu => SwiGLU, gelu => GeGLU)
    dtype: str = "bfloat16"
    attn: AttnConfig = field(default_factory=AttnConfig)
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    hybrid: HybridConfig = field(default_factory=HybridConfig)
    # modality frontends (vlm / audio) — the frontend itself is a stub; the
    # model consumes precomputed patch/frame embeddings via input_specs().
    frontend: str = "none"             # none | vision | audio
    n_frontend_tokens: int = 0         # vision patches prepended to the sequence
    n_codebooks: int = 0               # musicgen: parallel EnCodec codebooks
    # bookkeeping
    source: str = ""
    notes: str = ""

    # -- derived ------------------------------------------------------------
    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))
        assert self.family in FAMILIES, self.family

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid / windowed attn)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn.sliding_window > 0  # SWA bounds the per-layer cache

    def layer_kinds(self) -> List[str]:
        """Per-layer block kind: 'attn' | 'attn_local' | 'attn_global' | 'ssm'."""
        kinds: List[str] = []
        for i in range(self.n_layers):
            if self.family in ("ssm", "hybrid"):
                # hybrid (zamba2): every indexed layer is an SSM block; the
                # shared attention block is counted separately (it is not a
                # per-layer module — its weights are stored once).
                kinds.append("ssm")
            elif self.attn.local_global_ratio > 0:
                r = self.attn.local_global_ratio
                kinds.append("attn_global" if (i + 1) % (r + 1) == 0 else "attn_local")
            elif self.attn.sliding_window > 0:
                kinds.append("attn_local")
            else:
                kinds.append("attn")
        return kinds

    def ffn_kind(self, layer_idx: int) -> str:
        if self.moe.enabled and layer_idx >= self.moe.first_dense_layers:
            return "moe"
        return "dense"

    # -- parameter count ----------------------------------------------------
    def param_count(self) -> int:
        """Exact parameter count of the FULL config (embedding included)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        total = V * d                       # token embedding
        if not self.tie_embeddings:
            total += V * d                  # lm head
        if self.n_codebooks > 1:            # musicgen: K embeddings + K heads
            total += (self.n_codebooks - 1) * V * d       # extra embeddings
            total += (self.n_codebooks - 1) * V * d       # extra heads
        total += d                          # final norm
        for i in range(L):
            total += self._layer_params(i)
        if self.hybrid.enabled:
            total += self._shared_attn_params()
        return total

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE counts only routed top-k)."""
        if not self.moe.enabled:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        total = self.param_count()
        moe_layers = L - self.moe.first_dense_layers
        inactive = self.moe.n_experts - self.moe.top_k
        per_expert = 3 * d * self.moe.d_ff_expert
        total -= moe_layers * inactive * per_expert
        return total

    def _attn_params(self, d: int) -> int:
        if self.mla.enabled:
            m = self.mla
            qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
            p = 0
            if m.q_lora_rank > 0:
                p += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_dim
                p += m.q_lora_rank  # q lora norm
            else:
                p += d * self.n_heads * qk_dim
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)      # kv down (+ shared rope key)
            p += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += m.kv_lora_rank                                  # kv lora norm
            p += self.n_heads * m.v_head_dim * d                 # o proj
            return p
        dh = self.d_head
        p = d * self.n_heads * dh                                # q
        p += 2 * d * self.n_kv_heads * dh                        # k, v
        p += self.n_heads * dh * d                               # o
        if self.attn.qk_norm:
            p += 2 * dh
        return p

    def _ffn_params(self, layer_idx: int, d: int) -> int:
        if self.ffn_kind(layer_idx) == "moe":
            m = self.moe
            p = d * m.n_experts                                  # router
            p += m.n_experts * 3 * d * m.d_ff_expert             # routed experts
            p += m.n_shared_experts * 3 * d * m.d_ff_expert      # shared experts
            if m.dense_residual:
                p += 3 * d * m.d_ff_dense                        # parallel dense FFN
            return p
        return 3 * d * self.d_ff                                 # gate/up/down

    def _ssm_params(self, d: int) -> int:
        s = self.ssm
        di = s.d_inner(d)
        nh = s.n_heads(d)
        conv_dim = di + 2 * s.n_groups * s.d_state
        p = d * (2 * di + 2 * s.n_groups * s.d_state + nh)       # in_proj (z,x,B,C,dt)
        p += conv_dim * s.d_conv + conv_dim                      # conv + bias
        p += nh * 2                                              # A_log, D
        p += nh                                                  # dt_bias
        p += di                                                  # gated norm
        p += di * d                                              # out_proj
        return p

    def _layer_params(self, i: int) -> int:
        d = self.d_model
        kind = self.layer_kinds()[i]
        p = 2 * d                                                # 2 pre-norms
        if kind == "ssm":
            p = d + self._ssm_params(d)                          # 1 norm for pure ssm block
            if self.family != "hybrid":
                p += d + self._ffn_params(i, d) if self.d_ff > 0 else 0
            return p
        p += self._attn_params(d)
        p += self._ffn_params(i, d)
        return p

    def _shared_attn_params(self) -> int:
        h = self.hybrid
        d = self.d_model * (2 if h.concat_embedding else 1)
        nh = h.shared_attn_n_heads
        dh = d // nh
        p = 2 * d                                                # norms
        p += 4 * d * nh * dh                                     # qkvo at concat width
        p += 3 * d * (self.d_ff or 4 * d) if False else 0
        p += self.d_model * d                                    # down-projection back
        return p

    # -- reduced config for smoke tests --------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        r = self.ssm
        red_ssm = (
            replace(r, d_state=16, head_dim=16, chunk_size=32)
            if r.enabled else r
        )
        red_moe = (
            replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64 if self.moe.d_ff_expert else 0,
                d_ff_dense=64 if self.moe.dense_residual else 0,
                first_dense_layers=min(self.moe.first_dense_layers, 1),
            )
            if self.moe.enabled else self.moe
        )
        red_mla = (
            replace(self.mla, kv_lora_rank=32, q_lora_rank=(32 if self.mla.q_lora_rank else 0),
                    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
            if self.mla.enabled else self.mla
        )
        red_hybrid = (
            replace(self.hybrid, shared_attn_every=2, shared_attn_n_heads=4)
            if self.hybrid.enabled else self.hybrid
        )
        red_attn = replace(
            self.attn,
            sliding_window=min(self.attn.sliding_window, 16) if self.attn.sliding_window else 0,
        )
        n_layers = 4 if (self.attn.local_global_ratio or self.hybrid.enabled) else 2
        if self.attn.local_global_ratio:
            # keep the 5:1 pattern visible at reduced scale -> use 2:1 over 6 layers
            red_attn = replace(red_attn, local_global_ratio=2)
            n_layers = 6
        n_heads = 4
        d_model = 64
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=max(1, min(self.n_kv_heads * n_heads // max(self.n_heads, 1), n_heads)),
            d_head=16,
            d_ff=128,
            vocab_size=256,
            ssm=red_ssm,
            moe=red_moe,
            mla=red_mla,
            hybrid=red_hybrid,
            attn=red_attn,
            n_frontend_tokens=8 if self.frontend != "none" else 0,
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned per the task sheet; identical for all LM archs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> List[ShapeConfig]:
    """Shapes applicable to an architecture.

    ``long_500k`` requires sub-quadratic attention: it runs for SSM / hybrid /
    sliding-window archs and is skipped for pure full-attention archs
    (quadratic attention at 500k tokens does not fit the chip budget).
    """
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.sub_quadratic:
        out.append(SHAPES["long_500k"])
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    assert cfg.name not in REGISTRY, f"duplicate arch id {cfg.name}"
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import side-effect: populate the registry
    from repro import configs as _pkg  # noqa: F401

    _load_all()
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs() -> List[str]:
    _load_all()
    return sorted(REGISTRY)


_LOADED = False


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # one module per assigned architecture (+ the paper's own eval models)
    from repro.configs import (  # noqa: F401
        mamba2_2p7b,
        minicpm_2b,
        qwen3_1p7b,
        gemma3_1b,
        h2o_danube_1p8b,
        internvl2_76b,
        zamba2_2p7b,
        arctic_480b,
        deepseek_v2_236b,
        musicgen_medium,
        llama2_7b,
        qwen3_8b,
    )
