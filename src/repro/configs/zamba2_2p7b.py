"""zamba2-2.7b — Mamba2 backbone + shared attention blocks (hybrid).

[arXiv:2411.15242; hf]  54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000
ssm_state=64.  54 Mamba2 layers with a single SHARED transformer block applied
every 6 SSM layers; the shared block sees concat([h, embed]) (2*d_model) and
projects back to d_model.
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,              # shared block FFN width
    vocab_size=32000,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    hybrid=HybridConfig(shared_attn_every=6, shared_attn_n_heads=32,
                        concat_embedding=True),
    source="arXiv:2411.15242",
    notes="hybrid; runs long_500k (SSM state constant, shared-attn cache small)",
))
