"""qwen3-8b — the paper's second evaluation model (HALO Section V).

36L d_model=4096 32H (GQA kv=8, d_head=128) d_ff=12288 vocab=151936.
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12288,
    vocab_size=151936,
    attn=AttnConfig(qk_norm=True, rope_theta=1_000_000.0),
    source="arXiv:2505.09388",
    notes="paper eval model (HALO Fig. 7-8)",
))
