"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]  48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048.
4 parallel codebooks with the delay interleaving pattern.  The EnCodec
frontend is a STUB: input_specs() provides per-codebook token ids; the model
sums the K codebook embeddings per position and predicts K heads.
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    act="gelu",
    attn=AttnConfig(rope_theta=10000.0),
    frontend="audio",
    n_codebooks=4,
    source="arXiv:2306.05284",
    notes="EnCodec frontend stubbed; 4 codebooks, sum-embed + 4 lm heads",
))
