from repro.configs.base import (
    REGISTRY,
    SHAPES,
    AttnConfig,
    HybridConfig,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    ShapeConfig,
    SSMConfig,
    applicable_shapes,
    get_config,
    list_archs,
    register,
)

__all__ = [
    "REGISTRY", "SHAPES", "AttnConfig", "HybridConfig", "MLAConfig",
    "MoEConfig", "ModelConfig", "ShapeConfig", "SSMConfig",
    "applicable_shapes", "get_config", "list_archs", "register",
]
