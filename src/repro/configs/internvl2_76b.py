"""internvl2-76b — InternViT frontend (STUB) + InternLM2/llama3-70b-like backbone.

[arXiv:2404.16821; unverified]  80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256.  Per the task sheet the modality frontend is a stub:
``input_specs()`` provides precomputed patch embeddings occupying the first
``n_frontend_tokens`` positions of the sequence.
"""
from repro.configs.base import AttnConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    attn=AttnConfig(rope_theta=500_000.0),
    frontend="vision",
    n_frontend_tokens=256,
    source="arXiv:2404.16821",
    notes="vision frontend stubbed; backbone only",
))
