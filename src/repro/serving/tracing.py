"""Request-lifecycle tracing: Chrome trace-event JSON over the tick loop.

The engine's aggregate counters (serving/metrics.py) say WHAT happened;
a trace says WHEN and TO WHOM.  ``Tracer`` records the serving stack's
story as Chrome trace-event objects — the format Perfetto
(https://ui.perfetto.dev) and ``chrome://tracing`` load directly — laid
out so one screenful answers HALO's attribution questions:

* **track 0 ("ticks")** — one complete ("X") event per engine tick,
  its args carrying the full ``TickRecord`` twin: phase groups, prefill
  tokens, preemptions, migrated pages/bytes (the 2.5D-link analogue),
  swap in/out bytes, new compiles, resident KV.  Summing a tick-arg
  across the track reproduces the registry total EXACTLY — the
  conservation law tests/test_observability.py pins.
* **track req_id + 1 (one per request)** — that request's lifecycle as
  an async "b"/"e" envelope (submit -> finish/abort) containing "X"
  phase spans: ``queued``, ``prefill_chunk`` (args: take, offset),
  ``verify_window`` (drafted/accepted/emitted), ``decode``, ``swap_out``
  / ``swap_in`` (bytes), with instants ("i") for ``preempt``,
  ``first_token``, and ``compile``.

Costs nothing when off: every emitter early-returns on ``enabled`` (the
engine's call sites also guard, so span-argument work is skipped too),
``now()`` returns 0.0 without reading the clock, and the engine's greedy
token streams are bit-identical with tracing on or off — the tracer
never touches device state, only host timestamps.

Timestamps: the engine stamps events with ``time.monotonic()`` seconds
(``Request.t_submit`` etc. use the same clock); the tracer rebases to
its construction instant and converts to the format's microseconds.
Tests may inject a fake ``clock`` for deterministic timelines.
"""

from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional

#: trace "process" id: one serving engine == one process row in Perfetto
PID = 1
#: thread id of the per-tick track; request req_id maps to tid req_id + 1
TICK_TID = 0


class Tracer:
    def __init__(self, enabled: bool = True,
                 clock: Callable[[], float] = time.monotonic):
        self.enabled = enabled
        self._clock = clock
        self._events: List[Dict[str, Any]] = []
        self._named_tids: set = set()
        self._t0 = clock() if enabled else 0.0
        if enabled:
            self._events.append({
                "ph": "M", "pid": PID, "name": "process_name",
                "args": {"name": "serving-engine"}})
            self._name_tid(TICK_TID, "ticks")

    # -- clock -----------------------------------------------------------------
    def now(self) -> float:
        """Engine-clock seconds (0.0 when disabled — callers guard on
        ``enabled`` before doing span-argument work anyway)."""
        return self._clock() if self.enabled else 0.0

    def _ts(self, t: float) -> float:
        """Seconds on the engine clock -> trace microseconds (>= 0:
        ``t_submit`` may predate a tracer attached mid-run)."""
        return max((t - self._t0) * 1e6, 0.0)

    def _name_tid(self, tid: int, name: str) -> None:
        if tid in self._named_tids:
            return
        self._named_tids.add(tid)
        self._events.append({
            "ph": "M", "pid": PID, "tid": tid, "name": "thread_name",
            "args": {"name": name}})

    def _req_tid(self, req_id: int) -> int:
        tid = req_id + 1
        self._name_tid(tid, f"req {req_id}")
        return tid

    # -- emitters ---------------------------------------------------------------
    def begin_request(self, req_id: int, t: float, **args: Any) -> None:
        """Open a request's lifecycle envelope (async "b"; closed by
        ``end_request`` at finish/abort)."""
        if not self.enabled:
            return
        self._events.append({
            "ph": "b", "cat": "request", "id": req_id, "pid": PID,
            "tid": self._req_tid(req_id), "ts": self._ts(t),
            "name": "request", "args": args})

    def end_request(self, req_id: int, t: float, **args: Any) -> None:
        if not self.enabled:
            return
        self._events.append({
            "ph": "e", "cat": "request", "id": req_id, "pid": PID,
            "tid": self._req_tid(req_id), "ts": self._ts(t),
            "name": "request", "args": args})

    def request_span(self, req_id: int, name: str, t0: float, t1: float,
                     **args: Any) -> None:
        """One complete ("X") phase span on the request's track."""
        if not self.enabled:
            return
        ts = self._ts(t0)
        self._events.append({
            "ph": "X", "cat": "phase", "pid": PID,
            "tid": self._req_tid(req_id), "ts": ts,
            "dur": max(self._ts(t1) - ts, 0.0), "name": name, "args": args})

    def tick_span(self, t0: float, t1: float, **args: Any) -> None:
        """One engine tick on the tick track; ``args`` carry the
        ``TickRecord`` twin the conservation tests sum over."""
        if not self.enabled:
            return
        ts = self._ts(t0)
        self._events.append({
            "ph": "X", "cat": "tick", "pid": PID, "tid": TICK_TID,
            "ts": ts, "dur": max(self._ts(t1) - ts, 0.0), "name": "tick",
            "args": args})

    def instant(self, name: str, t: float, req_id: Optional[int] = None,
                **args: Any) -> None:
        """Point event ("i"): preempt / first_token / compile / ...;
        lands on the request's track when ``req_id`` is given, else on
        the tick track."""
        if not self.enabled:
            return
        tid = TICK_TID if req_id is None else self._req_tid(req_id)
        self._events.append({
            "ph": "i", "cat": "instant", "s": "t", "pid": PID, "tid": tid,
            "ts": self._ts(t), "name": name, "args": args})

    # -- export ------------------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        """The recorded trace events (the live list — cheap; callers
        treat it as read-only)."""
        return self._events

    def to_json(self) -> Dict[str, Any]:
        """JSON-object-format Chrome trace (the shape Perfetto and
        ``chrome://tracing`` open directly)."""
        return {"traceEvents": self._events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


__all__ = ["PID", "TICK_TID", "Tracer"]
