"""Trace-driven traffic: seeded arrival synthesis + an async replay harness.

The serving stack is evaluated the way "Prefill/Decode-Aware Evaluation
of LLM Inference on Emerging AI Accelerators" (PAPERS.md) argues it must
be: not by one batch's throughput, but by GOODPUT UNDER SLO against a
realistic arrival process.  This module provides both halves:

* ``synthesize(TrafficConfig)`` turns per-tenant specs into one merged,
  time-ordered list of ``ArrivalEvent``s — seeded Poisson or bursty
  ON-OFF arrivals, uniform prompt/output length ranges, and per-tenant
  SHARED-PREFIX pools (every prompt of a tenant starts with one of its
  ``n_prefixes`` fixed prefixes, which is exactly the workload the radix
  prefix cache exists for).  Same config -> same trace, bit for bit: the
  generator draws from ``numpy`` Generators seeded per tenant, never
  from wall clock.

* ``await replay(frontend, events)`` replays a trace against an
  ``AsyncEngine`` as concurrent clients — one task per arrival, each
  submitting at its event time (``time_scale`` compresses the clock;
  ``0`` submits in trace order with no waiting, making the engine-side
  interleaving deterministic) and consuming its stream to the end — and
  returns a ``TrafficReport``: goodput-under-SLO, TTFT/TPOT
  percentiles, shed/defer rates, and preemption counts, read from the
  SAME metrics registry the engine serves (never a parallel tally).
"""

from __future__ import annotations

import asyncio
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.frontend import AsyncEngine
from repro.serving.metrics import SLO, quantile
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import PRIORITY_STANDARD

__all__ = [
    "ArrivalEvent",
    "RequestResult",
    "TenantSpec",
    "TrafficConfig",
    "TrafficReport",
    "replay",
    "synthesize",
]


@dataclass(frozen=True)
class TenantSpec:
    """One traffic class: an arrival process plus a request shape.

    ``arrival="poisson"`` draws i.i.d. exponential gaps at ``rate_rps``.
    ``arrival="onoff"`` is the bursty twin: exponential ON/OFF dwell
    times (means ``on_s``/``off_s``) with Poisson arrivals at
    ``rate_rps`` DURING ON and silence during OFF — same mean shape,
    very different queue dynamics (the overload the admission controller
    exists for arrives in bursts, not smoothly).

    ``prompt_len``/``output_len`` are inclusive uniform ranges.  With
    ``shared_prefix_len > 0`` every prompt starts with one of the
    tenant's ``n_prefixes`` fixed token prefixes (drawn per request),
    modeling the shared system-prompt/RAG-template pools that make the
    radix prefix cache pay."""
    name: str
    rate_rps: float
    arrival: str = "poisson"            # poisson | onoff
    on_s: float = 1.0                   # mean ON dwell (onoff)
    off_s: float = 1.0                  # mean OFF dwell (onoff)
    prompt_len: Tuple[int, int] = (16, 64)
    output_len: Tuple[int, int] = (8, 32)
    shared_prefix_len: int = 0
    n_prefixes: int = 1
    priority: int = PRIORITY_STANDARD
    slo: Optional[SLO] = None

    def __post_init__(self):
        if self.rate_rps <= 0:
            raise ValueError(f"rate_rps must be > 0, got {self.rate_rps}")
        if self.arrival not in ("poisson", "onoff"):
            raise ValueError(f"arrival={self.arrival!r} "
                             "(expected 'poisson' or 'onoff')")
        if self.arrival == "onoff" and (self.on_s <= 0 or self.off_s <= 0):
            raise ValueError("onoff arrivals need on_s > 0 and off_s > 0")
        for fname in ("prompt_len", "output_len"):
            lo, hi = getattr(self, fname)
            if not (0 < lo <= hi):
                raise ValueError(f"{fname}={(lo, hi)} (need 0 < lo <= hi)")
        if self.shared_prefix_len < 0 or self.n_prefixes < 1:
            raise ValueError("shared_prefix_len >= 0 and n_prefixes >= 1")
        if self.shared_prefix_len >= self.prompt_len[0]:
            raise ValueError(
                f"shared_prefix_len={self.shared_prefix_len} must be < "
                f"min prompt_len={self.prompt_len[0]} (a prompt needs at "
                "least one non-shared token)")


@dataclass(frozen=True)
class TrafficConfig:
    tenants: Tuple[TenantSpec, ...]
    duration_s: float                   # trace horizon (arrival times < this)
    seed: int = 0
    vocab_size: int = 256

    def __post_init__(self):
        if not self.tenants:
            raise ValueError("TrafficConfig needs at least one tenant")
        if self.duration_s <= 0:
            raise ValueError(f"duration_s must be > 0, got {self.duration_s}")
        names = [t.name for t in self.tenants]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant names: {names}")
        if self.vocab_size < 2:
            raise ValueError(f"vocab_size must be >= 2, got {self.vocab_size}")


@dataclass(frozen=True)
class ArrivalEvent:
    t: float                            # arrival instant (trace time, s)
    tenant: str
    prompt: np.ndarray                  # [T] int32
    max_new_tokens: int
    priority: int = PRIORITY_STANDARD
    slo: Optional[SLO] = None


def _arrival_times(spec: TenantSpec, duration_s: float,
                   rng: np.random.Generator) -> List[float]:
    """Seeded arrival instants in [0, duration_s) for one tenant."""
    times: List[float] = []
    t = 0.0
    if spec.arrival == "poisson":
        while True:
            t += float(rng.exponential(1.0 / spec.rate_rps))
            if t >= duration_s:
                return times
            times.append(t)
    # onoff: exponential dwell alternation, Poisson arrivals during ON
    while t < duration_s:
        on_end = t + float(rng.exponential(spec.on_s))
        while True:
            t += float(rng.exponential(1.0 / spec.rate_rps))
            if t >= min(on_end, duration_s):
                break
            times.append(t)
        t = max(t, on_end) + float(rng.exponential(spec.off_s))
    return times


def synthesize(cfg: TrafficConfig) -> List[ArrivalEvent]:
    """The trace: every tenant's arrivals merged into one time-ordered
    event list.  Deterministic — each tenant draws from its own
    ``default_rng((seed, tenant_index))`` stream, so adding a tenant
    never perturbs another's arrivals, and ties in ``t`` break by
    tenant order."""
    events: List[Tuple[float, int, ArrivalEvent]] = []
    for ti, spec in enumerate(cfg.tenants):
        rng = np.random.default_rng((cfg.seed, ti))
        prefixes = [rng.integers(0, cfg.vocab_size,
                                 (spec.shared_prefix_len,), dtype=np.int32)
                    for _ in range(spec.n_prefixes)] \
            if spec.shared_prefix_len > 0 else []
        for t in _arrival_times(spec, cfg.duration_s, rng):
            p_lo, p_hi = spec.prompt_len
            o_lo, o_hi = spec.output_len
            plen = int(rng.integers(p_lo, p_hi + 1))
            mnt = int(rng.integers(o_lo, o_hi + 1))
            if prefixes:
                pre = prefixes[int(rng.integers(0, len(prefixes)))]
                suffix = rng.integers(0, cfg.vocab_size,
                                      (plen - len(pre),), dtype=np.int32)
                prompt = np.concatenate([pre, suffix])
            else:
                prompt = rng.integers(0, cfg.vocab_size, (plen,),
                                      dtype=np.int32)
            events.append((t, ti, ArrivalEvent(
                t=t, tenant=spec.name, prompt=prompt, max_new_tokens=mnt,
                priority=spec.priority, slo=spec.slo)))
    events.sort(key=lambda e: (e[0], e[1]))
    return [ev for _, _, ev in events]


@dataclass(frozen=True)
class RequestResult:
    """One replayed request's outcome (engine-measured latencies)."""
    req_id: int
    tenant: str
    t_arrival: float                    # trace time of the arrival
    finish_reason: Optional[str]
    n_tokens: int
    ttft_s: float                       # NaN if no first token
    tpot_s: float                       # NaN if undefined
    priority: int
    had_slo: bool

    @property
    def served(self) -> bool:
        return self.finish_reason not in ("shed", "abort")


@dataclass(frozen=True)
class TrafficReport:
    """One replay's scorecard.  Latency percentiles are over SERVED
    requests (a shed request has no TTFT — its cost appears in
    ``shed_rate`` and in the goodput denominator instead); goodput and
    the violation counts come from the engine's ``serving_slo_*``
    counters, which ALSO count shed deadline-carrying requests as
    un-attained demand — shedding is never free, it only beats
    thrashing."""
    n_requests: int
    n_served: int
    n_shed: int
    n_deferred: int                     # submits that were ever parked
    n_preemptions: int
    slo_total: int
    slo_attained: int
    goodput: float                      # attained / total SLO demand
    ttft_p50_s: float
    ttft_p95_s: float
    tpot_p50_s: float
    tpot_p95_s: float
    total_tokens: int
    wall_s: float
    results: Tuple[RequestResult, ...]

    @property
    def shed_rate(self) -> float:
        return self.n_shed / self.n_requests if self.n_requests else 0.0

    def by_tenant(self) -> Dict[str, Dict[str, int]]:
        out: Dict[str, Dict[str, int]] = {}
        for r in self.results:
            d = out.setdefault(r.tenant,
                               {"requests": 0, "served": 0, "shed": 0,
                                "tokens": 0})
            d["requests"] += 1
            d["served"] += r.served
            d["shed"] += r.finish_reason == "shed"
            d["tokens"] += r.n_tokens
        return out

    def render(self) -> str:
        lines = [
            f"requests={self.n_requests} served={self.n_served} "
            f"shed={self.n_shed} ({self.shed_rate:.1%}) "
            f"deferred={self.n_deferred} preemptions={self.n_preemptions}",
            f"goodput={self.goodput:.3f} "
            f"({self.slo_attained}/{self.slo_total} SLO demand attained)",
            f"ttft p50={self.ttft_p50_s * 1e3:.1f}ms "
            f"p95={self.ttft_p95_s * 1e3:.1f}ms | "
            f"tpot p50={self.tpot_p50_s * 1e3:.1f}ms "
            f"p95={self.tpot_p95_s * 1e3:.1f}ms",
            f"tokens={self.total_tokens} wall={self.wall_s:.2f}s",
        ]
        for name, d in sorted(self.by_tenant().items()):
            lines.append(f"  tenant {name}: {d['requests']} requests, "
                         f"{d['served']} served, {d['shed']} shed, "
                         f"{d['tokens']} tokens")
        return "\n".join(lines)


async def replay(frontend: AsyncEngine, events: Sequence[ArrivalEvent], *,
                 time_scale: float = 1.0,
                 sampling: Optional[SamplingParams] = None) -> TrafficReport:
    """Replay a trace as one client task per arrival.

    ``time_scale`` multiplies trace time: 1.0 replays in real time, 0.1
    ten-times compressed, ``0`` submits everything in trace order with
    no waiting — the engine sees the heaviest possible instantaneous
    load AND the submission order is exactly the trace order (each
    client posts to the mailbox before its first await), which is what
    makes zero-scale replays deterministic end to end.

    Greedy sampling by default (``sampling`` overrides per-trace); every
    client consumes its stream to the end, shed refusals included."""
    if time_scale < 0:
        raise ValueError(f"time_scale must be >= 0, got {time_scale}")
    sp = sampling if sampling is not None else SamplingParams()
    # counter snapshots: the report covers THIS replay's window, so a
    # warmup drain (or an earlier replay) on the same engine never
    # pollutes the scorecard
    eng = frontend.engine
    g0 = eng.goodput()
    preempt0 = int(eng.preemptions)
    deferred0 = int(eng.admission_deferred)
    t0 = time.monotonic()
    results: List[RequestResult] = []

    async def client(ev: ArrivalEvent) -> None:
        if time_scale > 0:
            delay = ev.t * time_scale - (time.monotonic() - t0)
            if delay > 0:
                await asyncio.sleep(delay)
        handle = await frontend.submit(
            ev.prompt, max_new_tokens=ev.max_new_tokens, sampling=sp,
            slo=ev.slo, priority=ev.priority)
        async for _ in handle:
            pass
        req = handle.request
        results.append(RequestResult(
            req_id=req.req_id, tenant=ev.tenant, t_arrival=ev.t,
            finish_reason=req.finish_reason, n_tokens=len(req.generated),
            ttft_s=req.ttft, tpot_s=req.tpot, priority=req.priority,
            had_slo=req.slo is not None))

    await asyncio.gather(*[client(ev) for ev in events])
    wall = time.monotonic() - t0
    results.sort(key=lambda r: r.req_id)

    g1 = eng.goodput()
    slo_total = int(g1["slo_total"] - g0["slo_total"])
    slo_attained = int(g1["slo_attained"] - g0["slo_attained"])
    served = [r for r in results if r.served]
    ttfts = [r.ttft_s for r in served]
    tpots = [r.tpot_s for r in served]
    return TrafficReport(
        n_requests=len(results),
        n_served=len(served),
        n_shed=sum(r.finish_reason == "shed" for r in results),
        n_deferred=int(eng.admission_deferred) - deferred0,
        n_preemptions=int(eng.preemptions) - preempt0,
        slo_total=slo_total,
        slo_attained=slo_attained,
        # same vacuous-1.0 convention as ``ServingEngine.goodput``
        goodput=slo_attained / slo_total if slo_total else 1.0,
        ttft_p50_s=quantile(ttfts, 0.50),
        ttft_p95_s=quantile(ttfts, 0.95),
        tpot_p50_s=quantile(tpots, 0.50),
        tpot_p95_s=quantile(tpots, 0.95),
        total_tokens=sum(r.n_tokens for r in results),
        wall_s=wall,
        results=tuple(results))
