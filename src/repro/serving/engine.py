"""Serving engine: continuous batching that EXECUTES the phase scheduler's
plan — chunked prefill, arena-direct KV writes, device-side sampling.

The engine owns a small table of jitted programs, keyed by (worker group,
phase kind).  On a production mesh the two groups are distinct worker
pools running differently-sharded executables (HALO: CiM for prefill
GEMMs, CiD for decode GEMVs); here they are separate jit instances and
the strategy (``halo`` / ``cent`` / ``attacc``) decides which group's
program serves each phase — exactly what ``TickPlan`` carries.

One engine tick = one ``PhaseScheduler.plan_tick`` executed verbatim:

  1. admit       — waiting requests claim free decode slots;
  2. prefill     — the plan's (request, n_tokens) chunks are packed into
                   ONE padded batch and run through the prefill-group
                   program, which writes K/V directly into the decode
                   arena at each request's slot and offset (the HALO
                   CiM -> CiD handoff, formerly a host-side splice loop).
                   Long prompts therefore prefill across several ticks,
                   interleaved with decode — the TTFT/TPOT trade-off;
  3. decode      — one batched token step for every DECODING slot, with
                   greedy / temperature / top-k sampling INSIDE the jitted
                   program: one [B]-shaped host transfer per tick instead
                   of a per-slot ``int(jnp.argmax(...))`` sync.

SSM / shared-attention plans cannot resume a recurrent state mid-prompt,
so their prefill falls back to whole-prompt — still a single jitted
program that splices the state into the arena on device
(``prefill_into_arena``); the scheduler plans those prompts as atomic
chunks.  Per-request TTFT/TPOT and a per-tick ``tick_log`` (phase
occupancy, groups, wall time) feed benchmarks/serving_bench.py.

This is a single-host engine; launch/serve.py instantiates it either on
the host CPU (examples, tests) or under the production mesh with the
decode shardings from distributed/sharding.py.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import (
    forward,
    forward_chunk,
    init_cache,
    prefill_into_arena,
    supports_chunked_prefill,
)
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import PhaseAwareConfig, PhaseScheduler, TickPlan


class RequestState(Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray                  # [T] int32 (or [K, T])
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine
    state: RequestState = RequestState.WAITING
    generated: List[Any] = field(default_factory=list)
    slot: int = -1
    prompt_len: int = 0
    prefill_pos: int = 0                # prompt tokens already in the arena
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit

    @property
    def tpot(self) -> float:
        n = max(len(self.generated) - 1, 1)
        return (self.t_done - self.t_first_token) / n


@dataclass
class TickRecord:
    """One engine tick as executed (mirrors the TickPlan it consumed)."""
    index: int
    prefill_reqs: List[int]
    prefill_tokens: int
    decode_reqs: List[int]
    prefill_group: str
    decode_group: str
    wall_s: float

    @property
    def mixed(self) -> bool:
        """Both phases ran this tick (prefill/decode interleaving)."""
        return bool(self.prefill_reqs) and bool(self.decode_reqs)


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    phase: PhaseAwareConfig = field(default_factory=PhaseAwareConfig)
    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0
    seed: int = 0


def _bucket(n: int, cap: int) -> int:
    """Round up to a power of two (capped) — bounds jit recompiles."""
    b = 1
    while b < n:
        b *= 2
    return max(1, min(b, cap)) if cap else b


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Any, sc: ServeConfig,
                 *, mesh=None):
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self.mesh = mesh
        self.scheduler = PhaseScheduler(sc.phase)
        B, S = sc.max_batch, sc.max_len
        self.cache = init_cache(cfg, B, S)
        self.slot_pos = np.full((B,), -1, np.int64)     # next write position
        self.slot_req: List[Optional[Request]] = [None] * B
        self.queue: List[Request] = []
        self.done: List[Request] = []
        # bounded record of recent ticks (a long-lived engine must not grow
        # per-tick state without bound); occupancy uses running counters
        self.tick_log: Deque[TickRecord] = deque(maxlen=65_536)
        self._n_ticks = 0
        self._n_prefill_ticks = 0
        self._n_decode_ticks = 0
        self._n_mixed_ticks = 0
        self.host_transfers = 0          # device->host syncs (see _to_host)
        self._next_id = 0
        self.chunked = (supports_chunked_prefill(cfg)
                        and sc.phase.prefill_chunk > 0)
        # (group, kind) -> jitted program; built lazily so each strategy
        # only compiles the programs its groups actually execute
        self._programs: Dict[Tuple[str, str], Callable] = {}
        self._rng = jax.random.PRNGKey(sc.seed)
        self._key0 = jax.random.PRNGKey(sc.seed)

    # -- program table ---------------------------------------------------------
    def _program(self, group: str, kind: str) -> Callable:
        """Jitted program for (worker group, phase kind).

        Each (group, kind) pair is a SEPARATE jit instance — the software
        analogue of phase disaggregation: on a cluster these are distinct
        executables resident on different worker pools, and the strategy
        table routes each phase to one of them.  ``kind``: "chunk"
        (packed chunked prefill), "whole" (whole-prompt prefill + arena
        splice, for SSM/hybrid plans), "decode" (one-token batched step).
        """
        key = (group, kind)
        if key not in self._programs:
            # the arena argument is donated: the engine rebinds self.cache
            # to the program's output every call, so XLA updates the KV
            # arena in place instead of copying it each tick
            impl, cache_arg = {
                "chunk": (self._prefill_chunk_impl, 5),
                "whole": (self._prefill_whole_impl, 3),
                "decode": (self._decode_impl, 2)}[kind]
            self._programs[key] = jax.jit(impl, donate_argnums=(cache_arg,))
        return self._programs[key]

    # -- jitted bodies ---------------------------------------------------------
    def _sample(self, logits, key):
        """logits [N, 1, V] (or [N, 1, K, V]) -> int32 tokens [N] / [N, K]."""
        return sample_tokens(logits[:, -1], greedy=self.sc.greedy,
                             temperature=self.sc.temperature,
                             top_k=self.sc.top_k, key=key)

    def _prefill_chunk_impl(self, params, tokens, offsets, lengths, slots,
                            cache, key):
        """Packed chunk prefill: K/V written arena-direct at (slot, offset)."""
        logits, new_cache = forward_chunk(params, self.cfg, tokens, offsets,
                                          lengths, slots, cache)
        return self._sample(logits, key), new_cache

    def _prefill_whole_impl(self, params, tokens, slot, cache, key):
        """Whole-prompt prefill + on-device arena splice (SSM / hybrid)."""
        logits, new_cache = prefill_into_arena(
            params, self.cfg, {"tokens": tokens}, slot, cache)
        return self._sample(logits, key), new_cache

    def _decode_impl(self, params, tokens, cache, pos, slot_mask, key):
        logits, new_cache, _ = forward(params, self.cfg, {"tokens": tokens},
                                       phase="decode", cache=cache, pos=pos)
        # frozen slots keep their old cache (mask out writes of idle slots).
        # attn caches are [L, B, ...] (batch at axis 1); shared_attn caches
        # are [B, ...] (batch leading) — pick the axis whose size matches.
        B = slot_mask.shape[0]

        def merge(old, new):
            ax = 1 if (old.ndim >= 2 and old.shape[1] == B) else 0
            shape = [1] * old.ndim
            shape[ax] = B
            b = slot_mask.reshape(shape)
            return jnp.where(b, new, old)

        merged = jax.tree.map(merge, cache, new_cache)
        return self._sample(logits, key), merged

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> Request:
        req = Request(self._next_id, np.asarray(prompt, np.int32),
                      max_new_tokens, eos_id)
        req.prompt_len = int(req.prompt.shape[-1])
        if req.prompt_len >= self.sc.max_len:
            raise ValueError(
                f"prompt of {req.prompt_len} tokens does not fit "
                f"max_len={self.sc.max_len} (need >= 1 decode position)")
        req.t_submit = time.monotonic()
        self._next_id += 1
        self.queue.append(req)
        return req

    # -- helpers ----------------------------------------------------------------
    def _to_host(self, arr) -> np.ndarray:
        """The engine's single device->host transfer point.

        Each PHASE PROGRAM CALL moves at most one token array ([B] or
        [B, K]) through here — one for the packed prefill batch, one for
        the decode step (so a mixed tick makes two; the per-request
        whole-prompt fallback makes one per call).  What device-side
        sampling eliminates is the per-SLOT logits sync; tests monkeypatch
        this to pin that down.
        """
        self.host_transfers += 1
        return np.asarray(arr)

    def _next_key(self):
        if self.sc.greedy:
            return self._key0                   # unused by argmax sampling
        self._rng, k = jax.random.split(self._rng)
        return k

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> List[Request]:
        admitted = []
        free = self._free_slots()
        while free and self.queue:
            req = self.queue.pop(0)
            slot = free.pop(0)
            req.slot = slot
            req.state = RequestState.PREFILLING
            self.slot_req[slot] = req
            admitted.append(req)
        return admitted

    def _by_id(self) -> Dict[int, Request]:
        return {r.req_id: r for r in self.slot_req if r is not None}

    def _append_token(self, req: Request, tok_row) -> None:
        flat = np.asarray(tok_row).reshape(-1)
        if self.cfg.n_codebooks > 1:
            req.generated.append([int(t) for t in flat])
        else:
            req.generated.append(int(flat[0]))

    def _start_decoding(self, req: Request, tok_row) -> None:
        self.slot_pos[req.slot] = req.prompt_len
        self._append_token(req, tok_row)
        req.t_first_token = time.monotonic()
        req.state = RequestState.DECODING
        if self._finished(req):
            self._retire(req)

    def _finished(self, req: Request) -> bool:
        if len(req.generated) >= req.max_new_tokens:
            return True
        if req.eos_id is not None and req.generated:
            last = req.generated[-1]
            if isinstance(last, list):          # multi-codebook: codebook 0
                last = last[0] if last else None
            if last == req.eos_id:
                return True
        if self.slot_pos[req.slot] >= self.sc.max_len - 1:
            return True
        return False

    def _retire(self, req: Request) -> None:
        req.state = RequestState.DONE
        req.t_done = time.monotonic()
        self.slot_req[req.slot] = None
        self.slot_pos[req.slot] = -1
        self.done.append(req)

    # -- phase execution --------------------------------------------------------
    def _run_prefill_tick(self, plan: TickPlan) -> None:
        """Execute the plan's prefill chunks on the planned worker group."""
        reqs = self._by_id()
        chunks = [(reqs[rid], take) for rid, take in plan.prefill_chunks
                  if rid in reqs]
        if not chunks:
            return
        if not self.chunked:
            # atomic whole-prompt prefill (SSM / shared-attn state handoff)
            for req, take in chunks:
                tokens = jnp.asarray(req.prompt[None], jnp.int32)
                toks, self.cache = self._program(plan.prefill_group, "whole")(
                    self.params, tokens, jnp.int32(req.slot), self.cache,
                    self._next_key())
                req.prefill_pos = req.prompt_len
                self._start_decoding(req, self._to_host(toks)[0])
            return

        # pack the tick's chunks into one padded batch (pow2 buckets bound
        # the number of compiled shapes)
        N = _bucket(len(chunks), self.sc.max_batch)
        C = _bucket(max(take for _, take in chunks), self.sc.phase.prefill_chunk)
        if self.cfg.n_codebooks > 1:
            tokens = np.zeros((N, self.cfg.n_codebooks, C), np.int32)
        else:
            tokens = np.zeros((N, C), np.int32)
        offs = np.zeros((N,), np.int32)
        lens = np.zeros((N,), np.int32)
        slots = np.full((N,), self.sc.max_batch, np.int32)  # OOB rows: drop
        for i, (req, take) in enumerate(chunks):
            sl = slice(req.prefill_pos, req.prefill_pos + take)
            tokens[i, ..., :take] = req.prompt[..., sl]
            offs[i] = req.prefill_pos
            lens[i] = take
            slots[i] = req.slot
        toks, self.cache = self._program(plan.prefill_group, "chunk")(
            self.params, jnp.asarray(tokens), jnp.asarray(offs),
            jnp.asarray(lens), jnp.asarray(slots), self.cache,
            self._next_key())
        sampled = None
        for i, (req, take) in enumerate(chunks):
            req.prefill_pos += take
            if req.prefill_pos >= req.prompt_len:
                if sampled is None:
                    sampled = self._to_host(toks)   # one transfer per tick
                self._start_decoding(req, sampled[i])

    def _run_decode_tick(self, plan: TickPlan) -> None:
        reqs = self._by_id()
        active = [reqs[rid] for rid in plan.decode_reqs
                  if rid in reqs and reqs[rid].state == RequestState.DECODING]
        if not active:
            return
        B = self.sc.max_batch
        if self.cfg.n_codebooks > 1:
            tokens = np.zeros((B, self.cfg.n_codebooks, 1), np.int32)
        else:
            tokens = np.zeros((B, 1), np.int32)
        mask = np.zeros((B,), bool)
        for r in active:
            tokens[r.slot, ..., 0] = r.generated[-1]
            mask[r.slot] = True
        # ragged decode: per-slot positions (vector pos -> per-slot rope,
        # per-slot cache write index, per-slot validity mask)
        pos = np.where(self.slot_pos >= 0, self.slot_pos, 0).astype(np.int32)
        toks, self.cache = self._program(plan.decode_group, "decode")(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(pos), jnp.asarray(mask), self._next_key())
        sampled = self._to_host(toks)               # one transfer per tick
        for r in active:
            self._append_token(r, sampled[r.slot])
            self.slot_pos[r.slot] += 1
            if self._finished(r):
                self._retire(r)

    # -- tick loop ---------------------------------------------------------------
    def step(self) -> Dict[str, int]:
        """One engine tick: plan (scheduler) -> execute (this method)."""
        t0 = time.monotonic()
        self._admit()
        prefilling = [(r.req_id, r.prompt_len - r.prefill_pos, self.chunked)
                      for r in self.slot_req
                      if r is not None and r.state == RequestState.PREFILLING]
        decoding = [r.req_id for r in self.slot_req
                    if r is not None and r.state == RequestState.DECODING]
        plan = self.scheduler.plan_tick(prefilling, decoding)
        if plan.prefill_chunks:
            self._run_prefill_tick(plan)
        if plan.decode_reqs:
            self._run_decode_tick(plan)
        rec = TickRecord(
            index=self._n_ticks,
            prefill_reqs=list(plan.prefill_reqs),
            prefill_tokens=plan.prefill_tokens,
            decode_reqs=list(plan.decode_reqs),
            prefill_group=plan.prefill_group,
            decode_group=plan.decode_group,
            wall_s=time.monotonic() - t0)
        self.tick_log.append(rec)
        self._n_ticks += 1
        self._n_prefill_ticks += bool(rec.prefill_reqs)
        self._n_decode_ticks += bool(rec.decode_reqs)
        self._n_mixed_ticks += rec.mixed
        return {"queued": len(self.queue),
                "active": sum(r is not None for r in self.slot_req),
                "done": len(self.done)}

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done

    # -- metrics ------------------------------------------------------------------
    @property
    def n_ticks(self) -> int:
        """Lifetime tick count (``tick_log`` itself is bounded)."""
        return self._n_ticks

    def phase_occupancy(self) -> Dict[str, float]:
        """Fractions of ticks running prefill / decode / both (interleave).

        Computed from running counters, so the numbers cover the engine's
        whole lifetime even after ``tick_log`` (bounded) has rotated."""
        n = max(self._n_ticks, 1)
        return {
            "prefill": self._n_prefill_ticks / n,
            "decode": self._n_decode_ticks / n,
            "mixed": self._n_mixed_ticks / n,
        }
