"""Serving engine: continuous batching that EXECUTES the phase scheduler's
plan — chunked prefill, arena-direct KV writes, device-side sampling.

The engine owns a small table of jitted programs, keyed by (worker group,
phase kind).  On a production mesh the two groups are distinct worker
pools running differently-sharded executables (HALO: CiM for prefill
GEMMs, CiD for decode GEMVs); here they are separate jit instances and
the strategy (``halo`` / ``cent`` / ``attacc``) decides which group's
program serves each phase — exactly what ``TickPlan`` carries.

One engine tick = one ``PhaseScheduler.plan_tick`` executed verbatim:

  1. admit       — waiting requests claim free decode slots;
  2. prefill     — the plan's (request, n_tokens) chunks are packed into
                   ONE padded batch and run through the prefill-group
                   program, which writes K/V directly into the decode
                   arena at each request's slot and offset (the HALO
                   CiM -> CiD handoff, formerly a host-side splice loop).
                   Long prompts therefore prefill across several ticks,
                   interleaved with decode — the TTFT/TPOT trade-off;
  3. decode      — one batched token step for every DECODING slot, with
                   greedy / temperature / top-k sampling INSIDE the jitted
                   program: one [B]-shaped host transfer per tick instead
                   of a per-slot ``int(jnp.argmax(...))`` sync.

SSM / shared-attention plans cannot resume a recurrent state mid-prompt,
so their prefill falls back to whole-prompt — still a single jitted
program that splices the state into the arena on device
(``prefill_into_arena``); the scheduler plans those prompts as atomic
chunks.  Per-request TTFT/TPOT and a per-tick ``tick_log`` (phase
occupancy, groups, wall time) feed benchmarks/serving_bench.py.

PAGED MODE (``ServeConfig(paged=True)``): the dense ``[L, B, max_len]``
arena is replaced by the block-pool cache of ``serving/kv_pool.py`` —
``n_pages`` pages of ``page_size`` tokens per attention run, mapped per
slot through block tables.  Capacity becomes a POOL property: ``submit``
accepts any prompt the pool can hold (one 16k request or eight 2k ones),
the scheduler admits prefill tokens only while free pages cover them
(decode's one-token growth is reserved first), and when the pool
exhausts mid-decode the YOUNGEST page-holding request is preempted —
its pages return to the pool and it re-queues as WAITING with its
generated tokens folded into the prompt (recompute-on-resume), so the
oldest request always finishes.  Decode attention routes through the
Pallas paged flash-decode kernel; ``kv_dtype="int8"`` stores GQA pages
AND MLA latent pages int8 with per-token f32 scales in parallel page
arrays; ``kv_dtype="int4"`` packs GQA pages two nibbles per byte
(~4x resident-KV reduction; MLA latents stay int8 — see kv_pool.py).

PREFIX CACHE (``ServeConfig(prefix_cache=True)``, requires paged): a
radix index over page-aligned token-block hashes
(``serving/prefix_cache.py``) maps each new prompt's longest cached
prefix to physical pages.  Admission attaches those pages to the slot's
block tables (refcounted sharing — no allocation, no compute) and starts
``prefill_pos`` past the matched tokens; completed prefills publish
their prompt pages back.  Pages are copy-on-write: before any program
writes a shared page (a ring wrap, a shared partially-filled tail) the
engine moves the slot to a private copy (``KVPool.ensure_writable`` +
one device page copy).  Cached pages are reclaimable capacity — evicted
LRU-first whenever live work needs pages, BEFORE any live request is
preempted.  Greedy token streams are bit-identical with the cache on or
off; only the prefill work executed changes.

SPECULATIVE DECODING (``ServeConfig(speculative=SpecConfig(...))``,
requires paged): every decode tick, a drafter proposes up to k tokens
per decoding request — the model-free n-gram prompt-lookup drafter by
default, or a small draft model with its own paged KV pool — and ONE
verify window of the target model (a k+1-token prefill-shaped chunk on
the CiM-analogue verify group, logits at every window position) accepts
the longest agreeing prefix and emits one correction/bonus token on top.
Rejected tokens' KV rolls back via ``KVPool.truncate`` (shared /
prefix-cache-pinned pages survive; COW already privatized the writer).
Greedy streams are bit-identical with speculation on or off; only the
number of ticks changes.  See serving/speculative.py and
docs/serving.md §Speculative decoding.

THE REQUEST API: the unit of the serving interface is the REQUEST, not
the batch run.  ``submit(prompt, sampling=SamplingParams(...))`` carries
per-request temperature (0 = greedy) / top-k / top-p / seed / token
budget / stop conditions; the jitted phase programs take per-slot ``[B]``
parameter arrays so one compiled program serves a batch mixing greedy
and stochastic requests (greedy rows bit-identical to an all-greedy
run), still one host transfer per tick.  ``step()`` returns incremental
``RequestOutput``s (new tokens, cumulative counts, finish reason:
length/eos/stop/abort) and ``stream()`` / ``generate()`` are the
streaming/batch facades over the tick loop.  ``abort(req_id)`` cancels a
request at ANY lifecycle stage — WAITING, PREFILLING, or DECODING
(speculative verify state included) — releasing its pages, prefix-cache
attachments, and draft-pool state.  The old engine-wide ``ServeConfig``
sampling fields survive as deprecated per-request defaults.

THREE LAYERS (PR 8): this module is the host-only ENGINE CORE — request
lifecycle, tick planning, batch packing, and page/tier accounting.  The
jitted program table, compile counting, and device placement live in the
EXECUTOR layer (``serving/executor.py``: ``ColocatedExecutor`` is
today's single-group behavior, ``DisaggregatedExecutor`` pins prefill
and decode programs to separate device groups and accounts KV-page
migration at the prefill -> decode handoff — the 2.5D-link analogue).
The KV TIERS live in ``serving/kv_pool.py``: the device ``PagePool``
plus an optional host-memory spill tier (``ServeConfig(host_spill_pages
> 0)``) that turns preemption into page SWAP instead of
recompute-on-resume and lets evicted prefix-cache blocks demote to host
and promote on re-hit.  The ``Request``/``RequestOutput``/``TickRecord``
/``ServeConfig`` dataclasses moved to ``serving/types.py``; they are
re-exported here so existing ``from repro.serving.engine import ...``
callers keep working.

This is a single-host engine; launch/serve.py instantiates it either on
the host CPU (examples, tests) or under the production mesh with the
decode shardings from distributed/sharding.py.
"""

from __future__ import annotations

import math
import time
import warnings
from collections import deque
from dataclasses import dataclass, replace
from typing import (
    Any, Callable, Deque, Dict, Iterator, List, Optional, Sequence, Tuple,
    Union,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import (
    forward,
    forward_chunk,
    forward_chunk_packed,
    init_cache,
    prefill_into_arena,
    supports_chunked_prefill,
    supports_paged,
)
from repro.serving.executor import make_executor
from repro.serving.kv_pool import HostTier, KVPool
from repro.serving.metrics import (
    SLO,
    MetricsRegistry,
    counter_attr,
    gauge_attr,
    slo_attainment,
)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.tracing import Tracer
from repro.serving.sampling import (
    SamplingParams,
    row_keys,
    sample_tokens_rows,
    verify_draft,
    verify_draft_rows,
)
from repro.serving.scheduler import (
    AdmissionController,
    PhaseScheduler,
    TickPlan,
    bucket_pow2 as _bucket,
    pack_chunks,
)
from repro.serving.speculative import build_drafter
from repro.serving.types import (                             # noqa: F401
    Request,
    RequestOutput,
    RequestState,
    ServeConfig,
    TickRecord,
)

# back-compat: these names were defined here before serving/types.py split
# them out, and external code imports them from this module
__all__ = [
    "Request", "RequestOutput", "RequestState", "ServeConfig",
    "ServingEngine", "TickRecord",
]


@dataclass
class _SwapHandle:
    """Where a swapped-out request's KV lives while it waits in the queue
    (``Request.swap``): per-run host-tier page lists in block-table row
    order, plus the slot state a swap-in must restore verbatim."""
    length: int                       # logical tokens the pages back
    pages: List[List[int]]            # per run: host page ids, row order
    prefill_pos: int
    cached_tokens: int
    pos: int                          # slot_pos at swap-out (-1 mid-prefill)
    state: RequestState               # PREFILLING or DECODING


class ServingEngine:
    # Lifetime counters live in the METRICS REGISTRY (serving/metrics.py):
    # each attribute below is a view over one named registry cell, so the
    # legacy dict APIs (counts() / spec_stats() / prefix_stats()) and
    # MetricsRegistry.snapshot() / render() can never disagree.  The
    # executor, HostTier, and PrefixCache share the same registry (the
    # engine passes it at construction), covering their counters too.
    host_transfers = counter_attr("serving_host_transfers_total")
    aborted = counter_attr("serving_aborted_total")
    admission_shed = counter_attr("serving_admission_shed_total")
    admission_deferred = counter_attr("serving_admission_deferred_total")
    preemptions = counter_attr("serving_preemptions_total")
    swap_outs = counter_attr("serving_swap_outs_total")
    swap_resumes = counter_attr("serving_swap_resumes_total")
    recompute_preemptions = counter_attr("serving_recompute_preemptions_total")
    prefill_tokens_executed = counter_attr("serving_prefill_tokens_total")
    cow_copies = counter_attr("serving_cow_copies_total")
    cache_evicted_pages = counter_attr("serving_cache_evicted_pages_total")
    spec_windows = counter_attr("serving_spec_windows_total")
    spec_drafted = counter_attr("serving_spec_drafted_total")
    spec_accepted = counter_attr("serving_spec_accepted_total")
    decode_tokens_emitted = counter_attr("serving_decode_tokens_total")
    decode_slot_ticks = counter_attr("serving_decode_slot_ticks_total")
    prefill_launches = counter_attr("serving_prefill_launches_total")
    prefill_rows_executed = counter_attr("serving_prefill_rows_total")
    kv_resident_peak = gauge_attr("serving_kv_resident_peak_bytes")
    _n_ticks = counter_attr("serving_ticks_total")
    _n_prefill_ticks = counter_attr("serving_prefill_ticks_total")
    _n_decode_ticks = counter_attr("serving_decode_ticks_total")
    _n_mixed_ticks = counter_attr("serving_mixed_ticks_total")

    # the counters step() diffs to fill each TickRecord's per-tick fields
    _TICK_DELTA_KEYS = (
        "serving_preemptions_total",
        "serving_spec_drafted_total",
        "serving_spec_accepted_total",
        "serving_swap_out_bytes_total",
        "serving_swap_in_bytes_total",
    )

    def __init__(self, cfg: ModelConfig, params: Any, sc: ServeConfig,
                 *, mesh=None, metrics: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None):
        # the registry IS the engine's counter state — construct it before
        # anything that counts.  Pass a DEDICATED registry per engine (the
        # per-tick deltas assume nobody else moves these counters); pass a
        # Tracer(enabled=True) to record the Chrome trace timeline
        # (serving/tracing.py) — tracing is OFF by default and leaves
        # greedy token streams bit-identical when on.
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        self.cfg = cfg
        if sc.weights_dtype not in ("f32", "int8"):
            raise ValueError(f"weights_dtype={sc.weights_dtype!r} "
                             "(expected 'f32' or 'int8')")
        if sc.weights_dtype == "int8":
            # serving quantizes EVERY matmul leaf (min_size=0): HALO's CiD
            # computes int8 end to end, and the decode GEMV kernel reads
            # the int8 bytes directly (models/layers.matmul routing)
            from repro.serving.quantized_weights import quantize_params
            params = quantize_params(params, min_size=0)
        self.params = params
        self.sc = sc
        self.mesh = mesh
        if sc.legacy_sampling_overridden():
            warnings.warn(
                "ServeConfig's engine-wide sampling fields (greedy/"
                "temperature/top_k/top_p) are deprecated: pass per-request "
                "SamplingParams via submit(..., sampling=...).  The values "
                "given are used as the default SamplingParams for submits "
                "that pass none.", DeprecationWarning, stacklevel=2)
        self._default_sampling = sc.default_sampling()
        self.scheduler = PhaseScheduler(sc.phase)
        B, S = sc.max_batch, sc.max_len
        self.paged = sc.paged
        if sc.paged:
            if not supports_paged(cfg):
                raise ValueError(
                    f"{cfg.name}: paged serving needs an all-attention plan "
                    "(SSM / shared-attention runs keep the dense arena)")
            if sc.phase.prefill_chunk <= 0:
                raise ValueError("paged serving requires chunked prefill "
                                 "(prefill_chunk > 0)")
            self.pool: Optional[KVPool] = KVPool(
                cfg, n_slots=B, n_pages=sc.n_pages, page_size=sc.page_size,
                kv_dtype=sc.kv_dtype)
            self.cache = self.pool.caches
        else:
            if sc.kv_dtype != "f32":
                raise ValueError(
                    f"kv_dtype={sc.kv_dtype!r} requires paged=True (the "
                    "dense engine stores the arena in the model dtype)")
            if sc.prefix_cache:
                raise ValueError("prefix_cache=True requires paged=True "
                                 "(prefix reuse shares physical pages "
                                 "through the block tables)")
            self.pool = None
            self.cache = init_cache(cfg, B, S)
        # host-memory spill tier (tiered KV): preemption swaps pages out
        # instead of recomputing, prefix-cache evictions demote to host
        if sc.host_spill_pages < 0:
            raise ValueError(f"host_spill_pages={sc.host_spill_pages} < 0")
        if sc.host_spill_pages and not sc.paged:
            raise ValueError("host_spill_pages > 0 requires paged=True "
                             "(the spill tier stores device pool pages)")
        self.host_tier: Optional[HostTier] = (
            HostTier(self.pool, sc.host_spill_pages, metrics=self.metrics)
            if sc.paged and sc.host_spill_pages > 0 else None)
        self.prefix: Optional[PrefixCache] = None
        if sc.paged and sc.prefix_cache:
            tiered = self.host_tier is not None
            self.prefix = PrefixCache(
                sc.page_size, self.pool.shareable_capacity(),
                demote=self._demote_pages if tiered else None,
                promote=self._promote_pages if tiered else None,
                discard=self._discard_host_pages if tiered else None,
                metrics=self.metrics)
        self.spec = sc.speculative
        self.drafter = None
        if self.spec is not None:
            if not sc.paged:
                raise ValueError(
                    "speculative decoding requires paged=True (the "
                    "draft/verify loop writes and rolls back through the "
                    "paged arena's block tables)")
            if cfg.n_codebooks > 1:
                raise ValueError("speculative decoding does not support "
                                 "multi-codebook heads")
            self.drafter = build_drafter(self.spec, cfg, n_slots=B,
                                         n_pages=sc.n_pages,
                                         page_size=sc.page_size)
            # rings bound rollback: a draft written at position p >= R
            # would overwrite live history at p - R that a rejection
            # cannot restore — speculation stops there (see
            # KVPool.rollback_bound) and decode falls back to one token
            self._rollback_bound = self.pool.rollback_bound()
        self.slot_pos = np.full((B,), -1, np.int64)     # next write position
        self.slot_req: List[Optional[Request]] = [None] * B
        self.queue: List[Request] = []
        self.done: List[Request] = []
        # admission control (ServeConfig.admission): submit() consults the
        # controller against the live backlog — shed requests retire
        # immediately (finish_reason "shed"), deferred ones park here and
        # are reconsidered at every step() until the backlog has room
        self.admission: Optional[AdmissionController] = (
            AdmissionController(sc.admission, sc.phase)
            if sc.admission is not None else None)
        self.deferred: List[Request] = []
        # live seconds-per-tick estimate (EMA over TickRecord.wall_s) the
        # controller projects TTFT with when no fixed tick_cost_s is set
        self._tick_wall_ema = 0.0
        self._tick_wall_n = 0
        # bounded record of recent ticks (a long-lived engine must not grow
        # per-tick state without bound); occupancy uses running counters
        self.tick_log: Deque[TickRecord] = deque(maxlen=65_536)
        # baseline for TickRecord's registry deltas, carried ACROSS ticks
        # (see step()): counter movement between ticks lands in the next
        # record, so the tick_log sums conserve the lifetime totals
        self._tick_delta_base = self.metrics.values(self._TICK_DELTA_KEYS)
        self._n_ticks = 0
        self._n_prefill_ticks = 0
        self._n_decode_ticks = 0
        self._n_mixed_ticks = 0
        self.host_transfers = 0          # device->host syncs (see _to_host)
        self.aborted = 0                 # requests cancelled via abort()
        self.admission_shed = 0          # submits refused by admission
        self.admission_deferred = 0      # submits parked by the backlog cap
        self.preemptions = 0             # lifetime pool evictions (paged)
        self.kv_resident_peak = 0        # peak allocated KV bytes (paged)
        # tiered-KV counters: how preemptions resumed (swap vs recompute)
        self.swap_outs = 0               # victims whose pages went to host
        self.swap_resumes = 0            # swapped requests resumed from host
        self.recompute_preemptions = 0   # fell back to recompute-on-resume
        self.prefill_tokens_executed = 0  # chunk tokens actually computed
        self.cow_copies = 0              # device page copies (COW)
        self.cache_evicted_pages = 0     # pages reclaimed from the cache
        # speculative decoding counters (spec_stats)
        self.spec_windows = 0            # verify windows executed
        self.spec_drafted = 0            # draft tokens verified
        self.spec_accepted = 0           # draft tokens accepted
        self.decode_tokens_emitted = 0   # tokens from decode/verify phases
        self.decode_slot_ticks = 0       # (request, tick) decode occupancies
        # the dense arena pins its full footprint up front; computed here
        # because the cache arrays are donated (buffers move every call).
        # The per-token/per-slot split prices the dense prefill->decode
        # handoff for the disaggregated executor: seq-axis leaves
        # ([L, B, S, ...]) move length x token-bytes, recurrent-state
        # leaves move their whole per-slot footprint once
        self._dense_kv_bytes = 0
        self._dense_token_bytes = 0
        self._dense_state_bytes = 0
        if not sc.paged:
            for c in self.cache:
                for leaf in c.values():
                    self._dense_kv_bytes += leaf.nbytes
                    if (leaf.ndim >= 3 and leaf.shape[1] == B
                            and leaf.shape[2] == S):
                        self._dense_token_bytes += leaf.nbytes // (B * S)
                    else:
                        self._dense_state_bytes += leaf.nbytes // B
        self._next_id = 0
        self.chunked = (supports_chunked_prefill(cfg)
                        and sc.phase.prefill_chunk > 0)
        # packed prefill needs the chunked attention path (arena-direct
        # writes at (slot, offset)) and a flat single-codebook stream
        self._packed = (sc.packed_prefill and self.chunked
                        and cfg.n_codebooks <= 1)
        self.prefill_launches = 0        # prefill phase-program calls
        self.prefill_rows_executed = 0   # token rows computed (incl. pad)
        # the EXECUTOR owns the jitted program table, compile accounting
        # and device placement (serving/executor.py); the engine stays
        # host-only and reaches it through _program/_note_compile below
        self.executor = make_executor(sc.executor, {
            "chunk": self._prefill_chunk_impl,
            "whole": self._prefill_whole_impl,
            "decode": self._decode_impl,
            "chunk_paged": self._prefill_chunk_paged_impl,
            "decode_paged": self._decode_paged_impl,
            "packed": self._prefill_packed_impl,
            "packed_paged": self._prefill_packed_paged_impl,
            "verify": self._verify_impl,
        }, mesh=mesh, metrics=self.metrics)
        # run -> jitted COW page copy (donated in-place, one per run shape)
        self._copy_programs: Dict[int, Callable] = {}
        # run -> jitted host-page upload (donated; swap-in / promote path)
        self._upload_programs: Dict[int, Callable] = {}

    # -- program table (owned by the executor) ---------------------------------
    @property
    def _programs(self) -> Dict[Tuple[str, str], Callable]:
        return self.executor.programs

    @property
    def compile_count(self) -> int:
        return self.executor.compile_count

    def _program(self, group: str, kind: str) -> Callable:
        """Jitted program for (worker group, phase kind) — built and cached
        by the executor layer.  Kept as an engine method so subclasses
        (tests' host-only engines) can stub program dispatch in one place."""
        return self.executor.program(group, kind)

    def _note_compile(self, group: str, kind: str, shape: Tuple[int, ...],
                      all_greedy: bool) -> None:
        before = self.executor.compile_count
        self.executor.note_compile(group, kind, shape, all_greedy)
        if self.tracer.enabled and self.executor.compile_count > before:
            self.tracer.instant("compile", self.tracer.now(), group=group,
                                kind=kind, shape=list(shape),
                                all_greedy=bool(all_greedy))

    # -- jitted bodies ---------------------------------------------------------
    def _sample(self, logits, temps, top_ks, top_ps, seeds, counters,
                all_greedy):
        """logits [N, 1, V] (or [N, 1, K, V]) -> int32 tokens [N] / [N, K].

        Per-row sampling params ([N] arrays); a row with temperature <= 0
        is greedy, so one program serves mixed batches.  ``all_greedy``
        is static — the common greedy tick never builds keys or sorts."""
        lg = logits[:, -1]
        if all_greedy:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return sample_tokens_rows(lg, temps, top_ks, top_ps,
                                  row_keys(seeds, counters))

    def _prefill_chunk_impl(self, params, tokens, offsets, lengths, slots,
                            cache, temps, top_ks, top_ps, seeds, counters,
                            all_greedy):
        """Packed chunk prefill: K/V written arena-direct at (slot, offset)."""
        logits, new_cache = forward_chunk(params, self.cfg, tokens, offsets,
                                          lengths, slots, cache)
        return self._sample(logits, temps, top_ks, top_ps, seeds, counters,
                            all_greedy), new_cache

    def _prefill_whole_impl(self, params, tokens, slot, cache, temps,
                            top_ks, top_ps, seeds, counters, all_greedy):
        """Whole-prompt prefill + on-device arena splice (SSM / hybrid)."""
        logits, new_cache = prefill_into_arena(
            params, self.cfg, {"tokens": tokens}, slot, cache)
        return self._sample(logits, temps, top_ks, top_ps, seeds, counters,
                            all_greedy), new_cache

    def _prefill_chunk_paged_impl(self, params, tokens, offsets, lengths,
                                  slots, cache, block_tables, temps, top_ks,
                                  top_ps, seeds, counters, all_greedy):
        """Packed chunk prefill into the page pool (block-table scatter)."""
        logits, new_cache = forward_chunk(params, self.cfg, tokens, offsets,
                                          lengths, slots, cache,
                                          block_tables=block_tables)
        return self._sample(logits, temps, top_ks, top_ps, seeds, counters,
                            all_greedy), new_cache

    def _prefill_packed_impl(self, params, tokens, starts, offsets, lengths,
                             slots, cache, temps, top_ks, top_ps, seeds,
                             counters, all_greedy):
        """Packed-stream chunk prefill (dense arena): the tick's chunks as
        one flat [T] token stream of bq-aligned segments — one launch,
        one compiled shape per bucketed T."""
        logits, new_cache = forward_chunk_packed(
            params, self.cfg, tokens, starts, offsets, lengths, slots,
            cache, pack_align=self.sc.phase.pack_align)
        return self._sample(logits, temps, top_ks, top_ps, seeds, counters,
                            all_greedy), new_cache

    def _prefill_packed_paged_impl(self, params, tokens, starts, offsets,
                                   lengths, slots, cache, block_tables,
                                   temps, top_ks, top_ps, seeds, counters,
                                   all_greedy):
        """Packed-stream chunk prefill into the page pool."""
        logits, new_cache = forward_chunk_packed(
            params, self.cfg, tokens, starts, offsets, lengths, slots,
            cache, block_tables=block_tables,
            pack_align=self.sc.phase.pack_align)
        return self._sample(logits, temps, top_ks, top_ps, seeds, counters,
                            all_greedy), new_cache

    def _verify_impl(self, params, tokens, offsets, lengths, slots, cache,
                     block_tables, draft, temps, top_ks, top_ps, seeds,
                     counters, all_greedy):
        """Speculative verify: ONE chunk forward of the target model over
        each row's [last_committed, d_1, .., d_k] window against the
        paged arena (K/V written arena-direct like any prefill chunk),
        returning logits at EVERY window position; accept/resample runs
        on device with PER-ROW sampling params (greedy rows accept the
        argmax prefix — bit-identical to their non-speculative decode —
        stochastic rows run Leviathan residual resampling with their own
        key chain) so the host sees one packed [N, C+1] array — C
        candidate tokens plus the emission count."""
        logits, new_cache = forward_chunk(params, self.cfg, tokens, offsets,
                                          lengths, slots, cache,
                                          block_tables=block_tables,
                                          return_all_logits=True)
        draft_len = jnp.asarray(lengths, jnp.int32) - 1
        if all_greedy:
            toks, n_emit = verify_draft(logits, draft, draft_len,
                                        greedy=True)
        else:
            toks, n_emit = verify_draft_rows(
                logits, draft, draft_len, temps, top_ks, top_ps,
                row_keys(seeds, counters))
        return jnp.concatenate([toks, n_emit[:, None]], axis=1), new_cache

    def _decode_paged_impl(self, params, tokens, cache, pos, block_tables,
                           temps, top_ks, top_ps, seeds, counters,
                           all_greedy):
        """One-token decode over the page pool.

        No merge-with-mask pass: inactive slots carry all-sentinel block
        table rows, so their K/V scatters DROP — the page pool is only
        ever written through an allocated page, which is the paged
        analogue of the dense path's ``jnp.where(slot_mask, new, old)``.
        """
        logits, new_cache, _ = forward(params, self.cfg, {"tokens": tokens},
                                       phase="decode", cache=cache, pos=pos,
                                       block_tables=block_tables)
        return self._sample(logits, temps, top_ks, top_ps, seeds, counters,
                            all_greedy), new_cache

    def _decode_impl(self, params, tokens, cache, pos, slot_mask, temps,
                     top_ks, top_ps, seeds, counters, all_greedy):
        logits, new_cache, _ = forward(params, self.cfg, {"tokens": tokens},
                                       phase="decode", cache=cache, pos=pos)
        # frozen slots keep their old cache (mask out writes of idle slots).
        # attn caches are [L, B, ...] (batch at axis 1); shared_attn caches
        # are [B, ...] (batch leading) — pick the axis whose size matches.
        B = slot_mask.shape[0]

        def merge(old, new):
            ax = 1 if (old.ndim >= 2 and old.shape[1] == B) else 0
            shape = [1] * old.ndim
            shape[ax] = B
            b = slot_mask.reshape(shape)
            return jnp.where(b, new, old)

        merged = jax.tree.map(merge, cache, new_cache)
        return self._sample(logits, temps, top_ks, top_ps, seeds, counters,
                            all_greedy), merged

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None,
               eos_id: Optional[int] = None, *,
               sampling: Optional[SamplingParams] = None,
               slo: Optional[SLO] = None,
               priority: Optional[int] = None) -> Request:
        """Queue one request.

        ``sampling`` carries the per-request parameters (temperature=0 is
        greedy); omitted, the ``ServeConfig`` legacy defaults apply.  The
        positional ``max_new_tokens`` / ``eos_id`` arguments are kept for
        existing callers and override the corresponding ``sampling``
        fields when given.  ``slo`` attaches TTFT/TPOT deadlines
        (``repro.serving.SLO``, milliseconds): the request counts into
        the ``serving_slo_*`` attainment counters at retirement, and —
        SLO-aware scheduling (PR 10) — its TTFT deadline steers prefill
        ordering (EDF within a priority class) and, with
        ``ServeConfig.admission`` set, the admission decision.

        ``priority`` is a ``scheduler.PRIORITY_*`` lane (default
        STANDARD): slot admission and the prefill budget serve lower
        values first.

        With admission control on, the returned ``Request`` may come back
        ALREADY RETIRED (``finish_reason == "shed"``: projected TTFT
        busts the deadline under current load, or the prompt alone
        overflows the pending-token cap) or parked in ``self.deferred``
        (best-effort request over the cap; it joins the queue once the
        backlog drains).  Callers that must distinguish these check
        ``req.finish_reason`` / ``req in engine.deferred``."""
        sp = sampling if sampling is not None else self._default_sampling
        if max_new_tokens is not None:
            sp = replace(sp, max_new_tokens=max_new_tokens)
        if eos_id is not None:
            sp = replace(sp, eos_id=eos_id)
        req = Request(self._next_id, np.asarray(prompt, np.int32), sp)
        # effective seed: explicit, or derived from (engine seed, req_id) —
        # deterministic across runs, distinct across requests.  Python-int
        # arithmetic, masked to int31: uint32 scalar math would overflow
        # (NumPy 2 warns, and raises outright for a negative sc.seed)
        req.seed = sp.seed if sp.seed is not None else (
            (self.sc.seed * 2654435761 + req.req_id + 1) & 0x7FFFFFFF)
        req.prompt_len = int(req.prompt.shape[-1])
        if self.paged:
            # capacity is a POOL property: a prompt fits iff the pool can
            # hold it (+ 1 decode position) when running alone
            if not self.pool.fits(req.prompt_len + 1):
                raise ValueError(
                    f"prompt of {req.prompt_len} tokens cannot fit the "
                    f"paged pool ({self.pool.n_pages} pages x "
                    f"{self.pool.page_size} = {self.pool.capacity} tokens)")
        elif req.prompt_len >= self.sc.max_len:
            raise ValueError(
                f"prompt of {req.prompt_len} tokens does not fit "
                f"max_len={self.sc.max_len} (need >= 1 decode position)")
        if slo is not None and not isinstance(slo, SLO):
            raise TypeError(f"slo={slo!r} (expected repro.serving.SLO)")
        req.slo = slo
        if priority is not None:
            req.priority = int(priority)
        req.t_submit = time.monotonic()
        self._next_id += 1
        if self.tracer.enabled:
            self.tracer.begin_request(req.req_id, req.t_submit,
                                      prompt_len=req.prompt_len)
        if self.admission is not None:
            decision = self.admission.decide(
                req.prompt_len,
                ttft_deadline_s=(slo.ttft_ms / 1e3
                                 if slo is not None and slo.ttft_ms is not None
                                 else math.inf),
                backlog_tokens=self._pending_prefill_tokens(),
                decode_backlog_tokens=self._pending_decode_tokens(),
                n_live=(len(self.queue) + len(self.deferred)
                        + sum(r is not None for r in self.slot_req)),
                ema_value=self._tick_wall_ema,
                ema_ticks=self._tick_wall_n)
            if decision == "shed":
                return self._shed(req)
            if decision == "defer":
                self.admission_deferred += 1
                self.deferred.append(req)
                return req
        self.queue.append(req)
        return req

    def _shed(self, req: Request) -> Request:
        """Refuse ``req`` at admission: retired immediately with
        finish_reason "shed" (terminal — it never holds a slot or a
        page).  Unlike an abort (the CLIENT's choice, excluded from
        goodput), a shed is the engine declining demand: a
        deadline-carrying request counts into
        ``serving_slo_requests_total`` un-attained (its NaN TTFT fails
        the bound), so ``goodput()`` is measured over ALL submitted SLO
        demand and shedding only wins by letting the survivors meet
        their deadlines."""
        self.admission_shed += 1
        req.state = RequestState.DONE
        req.finish_reason = "shed"
        req.t_done = time.monotonic()
        self._account_latency(req)
        if self.tracer.enabled:
            self.tracer.end_request(req.req_id, req.t_done, reason="shed",
                                    generated=0)
        self.done.append(req)
        return req

    def _pending_prefill_tokens(self, include_deferred: bool = True) -> int:
        """Queued-but-uncomputed prefill tokens: the backlog a new prompt
        must wait behind — the admission controller's projection input
        and the structural ``max_pending_tokens`` cap's measure."""
        pend = sum(max(r.prompt_len - r.prefill_pos, 0) for r in self.queue)
        if include_deferred:
            pend += sum(max(r.prompt_len - r.prefill_pos, 0)
                        for r in self.deferred)
        pend += sum(max(self._effective_len(r) - r.prefill_pos, 0)
                    for r in self.slot_req
                    if r is not None and r.state == RequestState.PREFILLING)
        return pend

    def _pending_decode_tokens(self, include_deferred: bool = True) -> int:
        """Remaining generation budget over every live request — the
        decode-side queueing a new prompt waits behind (drains at
        ``max_decode_batch`` tokens per tick)."""
        rem = sum(max(r.max_new_tokens - len(r.generated), 0)
                  for r in self.queue)
        if include_deferred:
            rem += sum(max(r.max_new_tokens - len(r.generated), 0)
                       for r in self.deferred)
        rem += sum(max(r.max_new_tokens - len(r.generated), 0)
                   for r in self.slot_req if r is not None)
        return rem

    def _reconsider_deferred(self) -> None:
        """Re-evaluate deferred requests against the current backlog;
        admitted ones join the queue tail in deferral order.  Deferral is
        only ever STRUCTURAL (best-effort request over the pending-token
        cap — deadline busts shed instead, and a prompt that alone
        overflows the cap was shed at submit), so every deferred request
        re-enters as soon as enough backlog drains: no starvation."""
        if not self.deferred or self.admission is None:
            return
        backlog = self._pending_prefill_tokens(include_deferred=False)
        n_live = (len(self.queue)
                  + sum(r is not None for r in self.slot_req))
        still = []
        for req in self.deferred:
            decision = self.admission.decide(
                req.prompt_len, ttft_deadline_s=math.inf,
                backlog_tokens=backlog, n_live=n_live,
                ema_value=self._tick_wall_ema, ema_ticks=self._tick_wall_n)
            # decode backlog omitted: deferral is structural (prefill-
            # token cap only), a best-effort request has no deadline to
            # project against
            if decision == "admit":
                self.queue.append(req)
                backlog += max(req.prompt_len - req.prefill_pos, 0)
                n_live += 1
            else:
                still.append(req)
        self.deferred = still

    @property
    def tick_wall_ema(self) -> float:
        """EMA of ``TickRecord.wall_s`` over ticks that compiled nothing
        (0.0 until the first such tick) — the live steady-state tick-cost
        estimate admission projections use."""
        return self._tick_wall_ema

    def abort(self, req_id: int) -> Optional[RequestOutput]:
        """Cancel a request at ANY lifecycle stage.

        WAITING: dequeued.  PREFILLING / DECODING (speculative verify
        state included): its slot is vacated — paged pool references
        (owned AND prefix-cache-attached pages), draft-pool state, and
        the dense slot mask are all released; pages the prefix cache
        pinned stay cached (they are the cache's references, reclaimable
        as usual).  Returns the terminal ``RequestOutput``
        (finish_reason "abort"), or None for an unknown / already
        finished id.  Tokens already generated remain on the Request."""
        req = None
        for i, r in enumerate(self.queue):
            if r.req_id == req_id:
                req = self.queue.pop(i)
                if req.swap is not None:    # swapped-out KV dies with it
                    for r_idx, host_pages in enumerate(req.swap.pages):
                        self.host_tier.release(r_idx, host_pages)
                    req.swap = None
                break
        if req is None:
            # deferred (admission-parked) requests hold no slot, pages,
            # or swap state — cancellation is pure list removal
            for i, r in enumerate(self.deferred):
                if r.req_id == req_id:
                    req = self.deferred.pop(i)
                    break
        if req is None:
            for r in self.slot_req:
                if r is not None and r.req_id == req_id:
                    req = r
                    if self.drafter is not None:
                        self.drafter.release(r.slot)
                    if self.paged:
                        self.pool.release(r.slot)
                    self.slot_req[r.slot] = None
                    self.slot_pos[r.slot] = -1
                    r.slot = -1
                    break
        if req is None:
            return None
        self.aborted += 1
        req.state = RequestState.DONE
        req.finish_reason = "abort"
        req.t_done = time.monotonic()
        # aborts are client cancellations, not serving failures: they are
        # EXCLUDED from SLO attainment (goodput measures what the engine
        # did with requests it was allowed to finish)
        if self.tracer.enabled:
            self.tracer.end_request(req.req_id, req.t_done, reason="abort",
                                    generated=len(req.generated))
        self.done.append(req)
        return RequestOutput(req_id=req.req_id, new_token_ids=[],
                             n_generated=len(req.generated), finished=True,
                             finish_reason="abort")

    # -- helpers ----------------------------------------------------------------
    def _to_host(self, arr) -> np.ndarray:
        """The engine's single device->host transfer point.

        Each PHASE PROGRAM CALL moves at most one token array ([B] or
        [B, K]) through here — one for the packed prefill batch, one for
        the decode step (so a mixed tick makes two; the per-request
        whole-prompt fallback makes one per call).  What device-side
        sampling eliminates is the per-SLOT logits sync; tests monkeypatch
        this to pin that down.
        """
        self.host_transfers += 1
        return np.asarray(arr)

    def _pack_params(self, rows: Sequence[Tuple[int, Request]], n: int):
        """Pack per-request sampling params into [n]-shaped device arrays
        for one jitted phase call.  ``rows`` maps row index -> request
        (a packed-batch index for prefill/verify, the SLOT for decode);
        unmapped rows are greedy placeholders (temperature 0 — argmax,
        result discarded).  The counter is the index of the token being
        sampled (= tokens emitted so far), which keys the request's
        per-row PRNG chain (see sampling.row_keys).  Returns the arrays
        plus the static ``all_greedy`` flag."""
        temps = np.zeros((n,), np.float32)
        top_ks = np.zeros((n,), np.int32)
        top_ps = np.zeros((n,), np.float32)
        seeds = np.zeros((n,), np.int32)
        counters = np.zeros((n,), np.int32)
        all_greedy = True
        for i, r in rows:
            sp = r.sampling
            temps[i] = sp.temperature
            top_ks[i] = sp.top_k
            top_ps[i] = sp.top_p
            seeds[i] = r.seed
            counters[i] = len(r.generated)
            all_greedy = all_greedy and sp.greedy
        return (jnp.asarray(temps), jnp.asarray(top_ks), jnp.asarray(top_ps),
                jnp.asarray(seeds), jnp.asarray(counters)), all_greedy

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> List[Request]:
        admitted = []
        free = self._free_slots()
        # SLO-aware slot order: (class, TTFT deadline, age).  Stable and
        # deterministic — all-default submissions sort (STANDARD, inf,
        # req_id), i.e. exactly the old FIFO.  The swap-resume head-wait
        # below is order-independent: deadlock freedom rests on a lone
        # request always fitting the pool, not on WHICH request is head
        if self.queue:
            self.queue.sort(
                key=lambda r: (r.priority, r.ttft_deadline_s, r.req_id))
        while free and self.queue:
            req = self.queue[0]
            if req.swap is not None:
                # swap-resume: the head's KV lives in the host tier; it
                # re-enters only when its device pages fit again.  On
                # failure the head WAITS (FIFO order preserved) — this is
                # deadlock-free because submit() guarantees a lone request
                # fits the pool and cached pages are always reclaimable
                if not self._try_swap_in(req, free[0]):
                    break
                self.queue.pop(0)
                free.pop(0)
                admitted.append(req)
                continue
            self.queue.pop(0)
            slot = free.pop(0)
            req.slot = slot
            req.state = RequestState.PREFILLING
            self.slot_req[slot] = req
            self._try_prefix_attach(req)
            admitted.append(req)
        if self.tracer.enabled and admitted:
            t = self.tracer.now()
            for req in admitted:
                self.tracer.request_span(
                    req.req_id, "queued", req.t_requeue or req.t_submit, t,
                    cached_tokens=req.cached_tokens,
                    n_preempted=req.n_preempted)
        return admitted

    def _by_id(self) -> Dict[int, Request]:
        return {r.req_id: r for r in self.slot_req if r is not None}

    # -- prefix cache ------------------------------------------------------------
    def _try_prefix_attach(self, req: Request) -> None:
        """Admission-time prefix lookup: point the slot's leading block-
        table rows at the longest cached prefix (shared, refcounted) and
        start prefill past it.  The match is capped at len - 1 so at
        least one token remains to prefill — the prompt's last-token
        logits seed decoding."""
        if self.prefix is None:
            return
        tokens = self._effective_tokens(req)
        matched, pages = self.prefix.match(
            tokens, max_tokens=int(tokens.shape[-1]) - 1)
        if matched <= 0:
            return
        self.pool.attach(req.slot, pages, matched)
        req.prefill_pos = matched
        req.cached_tokens = matched

    def _publish_prefix(self, req: Request) -> None:
        """Publish a freshly-prefilled request's PROMPT pages into the
        cache.  Ring purity gate: a sliding-window run's pages are
        position-pure only while the total prefilled length has not
        wrapped its ring — once it has, row 0 holds late positions and
        the prefix is unpublishable (see docs/serving.md §Prefix cache)."""
        if self.prefix is None:
            return
        if self._effective_len(req) > self.pool.shareable_capacity():
            return
        prompt = self._effective_tokens(req)[..., :req.prompt_len]
        self.prefix.insert(prompt, self.pool, req.slot)

    def _reclaim_cache(self, n_pages: int) -> int:
        """Evict LRU cached blocks until at least ``n_pages`` pages are
        actually FREE again (blocks still pinned by live slots are
        skipped — evicting them frees nothing and only loses future
        hits).  Cached pages are reclaimable capacity: this always runs
        before any live request is preempted."""
        if self.prefix is None:
            return 0
        freed = self.prefix.evict(self.pool, max(n_pages, 1))
        self.cache_evicted_pages += freed
        return freed

    def _copy_pages(self, copies) -> None:
        """Mirror ``KVPool.ensure_writable``'s accounting with the device
        copies: one donated in-place program per run moves page ``old``'s
        rows to ``new`` before the writer's program launches."""
        for r, old, new in copies:
            if r not in self._copy_programs:
                # pool leaves are [L, n_pages, P, ...]: pages live on axis 1
                self._copy_programs[r] = jax.jit(
                    lambda c, src, dst: jax.tree.map(
                        lambda x: x.at[:, dst].set(x[:, src]), c),
                    donate_argnums=(0,))
            self.cache[r] = self._copy_programs[r](
                self.cache[r], jnp.int32(old), jnp.int32(new))
        self.cow_copies += len(copies)

    def _ensure_writable(self, slot: int, start: int, end: int) -> bool:
        """COW every shared page a write to [start, end) would dirty,
        reclaiming cached pages for copy targets if needed.  False if
        copy targets remain unavailable (caller preempts or defers)."""
        copies = self.pool.ensure_writable(slot, start, end)
        if copies is None:
            # reclaim exactly the copy-target deficit (a multi-page chunk
            # may need several targets per run — one fixed-size reclaim
            # would drip-feed it through the stall breaker)
            self._reclaim_cache(self.pool.cow_deficit(slot, start, end))
            copies = self.pool.ensure_writable(slot, start, end)
        if copies is None:
            return False
        self._copy_pages(copies)
        return True

    # -- recompute-on-resume -----------------------------------------------------
    def _effective_tokens(self, req: Request) -> np.ndarray:
        """The token stream a (re)prefill must process: the prompt, plus —
        after a preemption — everything already generated (recompute: the
        resumed prefill rebuilds the evicted KV and its final logits yield
        the CONTINUATION token, exactly what the evicted decode step would
        have produced)."""
        if not req.generated:
            return req.prompt
        if self.cfg.n_codebooks > 1:
            gen = np.asarray(req.generated, np.int32).T          # [K, n]
            return np.concatenate([req.prompt, gen], axis=-1)
        return np.concatenate(
            [req.prompt, np.asarray(req.generated, np.int32)])

    def _effective_len(self, req: Request) -> int:
        return req.prompt_len + len(req.generated)

    def _preempt(self, req: Request) -> None:
        """Evict ``req`` from its slot: pages back to the pool, request
        back to WAITING (age-ordered).  With a host tier the victim's KV
        swaps out (exact page copies, resumed with ZERO recomputation);
        without one — or when the tier is full — it falls back to
        recompute-on-resume."""
        assert self.paged and req.slot >= 0
        if self.drafter is not None:
            self.drafter.release(req.slot)
        if not self._swap_out(req):
            # recompute-on-resume: the re-prefill rebuilds the evicted KV
            req.prefill_pos = 0
            req.cached_tokens = 0       # re-matched at re-admission
            self.recompute_preemptions += 1
        self.pool.release(req.slot)
        self.slot_req[req.slot] = None
        self.slot_pos[req.slot] = -1
        req.slot = -1
        req.state = RequestState.WAITING
        req.n_preempted += 1
        self.preemptions += 1
        req.t_requeue = time.monotonic()    # the next queued span starts here
        if self.tracer.enabled:
            self.tracer.instant("preempt", req.t_requeue, req_id=req.req_id,
                                swapped=req.swap is not None)
        # keep the queue age-ordered: older (smaller id) requests first,
        # so the re-queued victim outranks later submissions
        i = 0
        while i < len(self.queue) and self.queue[i].req_id < req.req_id:
            i += 1
        self.queue.insert(i, req)

    def _preemption_victim(self, needy: Request) -> Request:
        """Youngest slot-holding request whose eviction frees pages (or
        ``needy`` itself if nobody else holds any) — the oldest request is
        never chosen over an older needy one, so it always completes."""
        holders = sorted((r for r in self.slot_req if r is not None),
                         key=lambda r: r.req_id, reverse=True)
        for r in holders:
            if r is needy:
                continue
            if r.req_id > needy.req_id and self.pool.len_of(r.slot) > 0:
                return r
        return needy

    def _break_prefill_stall(self) -> None:
        """Deadlock breaker: PREFILLING requests exist but the tick planned
        NOTHING — mid-prefill requests hold every page between them and no
        decoder is running to trigger growth preemption.  Evict the
        youngest page holder (never the oldest: ``submit`` guarantees any
        single request fits the pool alone, so the oldest can always make
        progress once the young have yielded their pages)."""
        if not any(r is not None and r.state == RequestState.PREFILLING
                   for r in self.slot_req):
            return
        # cached pages yield before any live request does
        if self._reclaim_cache(1):
            return
        holders = [r for r in self.slot_req
                   if r is not None and self.pool.len_of(r.slot) > 0]
        if not holders:
            return
        victim = max(holders, key=lambda r: r.req_id)
        oldest = min((r for r in self.slot_req if r is not None),
                     key=lambda r: r.req_id)
        if victim is not oldest:
            self._preempt(victim)

    # -- tiered KV (host spill) --------------------------------------------------
    def _read_page(self, r: int, page: int) -> Dict[str, np.ndarray]:
        """Pull one device page across every layer to host numpy — pool
        leaves are [L, n_pages, P, ...], so each leaf yields [L, P, ...]."""
        return {k: np.asarray(leaf[:, page])
                for k, leaf in self.cache[r].items()}

    def _write_page(self, r: int, page: int,
                    data: Dict[str, np.ndarray]) -> None:
        """Upload one host-tier page into device page ``page``: a donated
        in-place program per run (mirrors ``_copy_pages``), so the arena
        is patched without a full-pool copy."""
        if r not in self._upload_programs:
            self._upload_programs[r] = jax.jit(
                lambda c, dst, vals: jax.tree.map(
                    lambda x, v: x.at[:, dst].set(v), c, vals),
                donate_argnums=(0,))
        self.cache[r] = self._upload_programs[r](
            self.cache[r], jnp.int32(page),
            {k: jnp.asarray(v) for k, v in data.items()})

    def _swap_out(self, req: Request) -> bool:
        """Copy a preemption victim's device pages into the host tier and
        hang a ``_SwapHandle`` off the request.  All-or-nothing: False (no
        state change) when the tier is absent, the slot holds nothing, or
        host pages run short — the caller then falls back to
        recompute-on-resume."""
        if self.host_tier is None:
            return False
        length = self.pool.len_of(req.slot)
        if length <= 0:
            return False
        pools = self.pool.pools
        need = [p.pages_of(length) for p in pools]
        if any(self.host_tier.free_pages(r) < n for r, n in enumerate(need)):
            return False
        t_sw0, b0 = self.tracer.now(), self.host_tier.swap_out_bytes
        pages: List[List[int]] = []
        for r, p in enumerate(pools):
            host = self.host_tier.alloc(r, need[r])
            assert host is not None     # free_pages checked per run above
            for i, hp in enumerate(host):
                self.host_tier.store(
                    r, hp, self._read_page(r, int(p.table[req.slot, i])))
            pages.append(host)
        req.swap = _SwapHandle(
            length=length, pages=pages, prefill_pos=req.prefill_pos,
            cached_tokens=req.cached_tokens,
            pos=int(self.slot_pos[req.slot]), state=req.state)
        self.swap_outs += 1
        if self.tracer.enabled:
            self.tracer.request_span(
                req.req_id, "swap_out", t_sw0, self.tracer.now(),
                bytes=self.host_tier.swap_out_bytes - b0, tokens=length)
        return True

    def _try_swap_in(self, req: Request, slot: int) -> bool:
        """Restore a swapped-out request into ``slot``: regrow its device
        pages (reclaiming cached pages on a shortfall), upload the host
        copies row for row, and resume EXACTLY where it left off — the
        swap path re-prefills zero tokens.  False leaves the request at
        the queue head with its handle intact."""
        h = req.swap
        if not self.pool.grow(slot, h.length):
            deficit = max(p.pages_of(h.length) - p.free_pages()
                          for p in self.pool.pools)
            self._reclaim_cache(deficit)
            if not self.pool.grow(slot, h.length):
                return False
        t_sw0, b0 = self.tracer.now(), self.host_tier.swap_in_bytes
        req.slot = slot
        self.slot_req[slot] = req
        for r, p in enumerate(self.pool.pools):
            for i, hp in enumerate(h.pages[r]):
                self._write_page(r, int(p.table[slot, i]),
                                 self.host_tier.load(r, hp))
            self.host_tier.release(r, h.pages[r])
        req.prefill_pos = h.prefill_pos
        req.cached_tokens = h.cached_tokens
        req.state = h.state
        self.slot_pos[slot] = h.pos     # -1 for a mid-prefill swap
        req.swap = None
        self.swap_resumes += 1
        if self.tracer.enabled:
            self.tracer.request_span(
                req.req_id, "swap_in", t_sw0, self.tracer.now(),
                bytes=self.host_tier.swap_in_bytes - b0, tokens=h.length)
        return True

    def _demote_pages(self, dev_pages: List[int]) -> Optional[List[int]]:
        """PrefixCache demote callback: copy one cached block (one device
        page PER RUN) into the host tier.  All-or-nothing; None makes the
        cache hard-drop the block instead."""
        host: List[int] = []
        for r, q in enumerate(dev_pages):
            got = self.host_tier.alloc(r, 1)
            if got is None:
                for rr, hp in enumerate(host):
                    self.host_tier.release(rr, [hp])
                return None
            host.append(got[0])
            self.host_tier.store(r, got[0], self._read_page(r, int(q)))
        return host

    def _promote_pages(self, host_pages: List[int]) -> Optional[List[int]]:
        """PrefixCache promote callback: re-materialise a demoted block on
        device — fresh externally-owned pages (``alloc_external``: ref=1,
        external=1, conservation holds from birth) — and free the host
        copies.  None when any run's free list is empty (partial hit)."""
        if any(not p.free for p in self.pool.pools):
            return None
        dev: List[int] = []
        for r, hp in enumerate(host_pages):
            q = self.pool.pools[r].alloc_external()
            assert q is not None        # free list checked above
            self._write_page(r, q, self.host_tier.load(r, hp))
            self.host_tier.release(r, [hp])
            dev.append(q)
        return dev

    def _discard_host_pages(self, host_pages: List[int]) -> None:
        """PrefixCache discard callback: a demoted block died (evicted
        subtree / re-published over) — drop its host copies."""
        for r, hp in enumerate(host_pages):
            self.host_tier.release(r, [hp])

    # -- prefill -> decode handoff (the 2.5D-link analogue) ----------------------
    def _record_handoff(self, req: Request) -> None:
        """Price the KV a prefill->decode handoff moves across HALO's
        2.5D interposer link (CiM prefill stack -> CiD decode stack).
        Only NEWLY-built state moves — prefix-cache hits are already
        decode-side resident.  Colocated executors have no link: no-op."""
        if not self.executor.migrates_kv:
            return
        eff = self._effective_len(req)
        if self.paged:
            pages = nbytes = 0
            for r, p in enumerate(self.pool.pools):
                n = max(p.pages_of(eff) - p.pages_of(req.cached_tokens), 0)
                pages += n
                nbytes += n * self.pool.page_bytes(r)
        else:
            pages = 0
            nbytes = eff * self._dense_token_bytes + self._dense_state_bytes
        self.executor.record_handoff(pages, nbytes)

    def _append_token(self, req: Request, tok_row) -> None:
        flat = np.asarray(tok_row).reshape(-1)
        if self.cfg.n_codebooks > 1:
            req.generated.append([int(t) for t in flat])
        else:
            req.generated.append(int(flat[0]))

    def _start_decoding(self, req: Request, tok_row) -> None:
        self._publish_prefix(req)       # prompt pages complete & unwrapped
        self.slot_pos[req.slot] = self._effective_len(req)
        self._record_handoff(req)       # KV crosses the phase boundary here
        if req.sampling.max_new_tokens == 0 and not req.generated:
            # prefill-only request: the seeding sample is discarded, no
            # token ever emits (ttft/tpot stay NaN), reason is "length"
            req.finish_reason = "length"
            self._retire(req)
            return
        self._append_token(req, tok_row)
        if req.t_first_token == 0.0:    # a resumed prefill keeps its TTFT
            req.t_first_token = time.monotonic()
            if self.tracer.enabled:
                self.tracer.instant("first_token", req.t_first_token,
                                    req_id=req.req_id)
        req.state = RequestState.DECODING
        if self._finished(req):
            self._retire(req)

    def _stream_reason(self, req: Request) -> Optional[str]:
        """Token-stream termination only (max_new / eos / stop) — what a
        verify window's emission loop may stop on.  The arena position
        bound is NOT checked here: a window commits its slot_pos jump
        before the tokens append, so mid-emission the position test would
        fire early and drop accepted tokens non-speculative decode would
        emit."""
        if len(req.generated) >= req.max_new_tokens:
            return "length"
        if req.generated:
            last = req.generated[-1]
            if isinstance(last, list):          # multi-codebook: codebook 0
                last = last[0] if last else None
            if req.eos_id is not None and last == req.eos_id:
                return "eos"
            if last is not None and last in req.sampling.stop:
                return "stop"
        return None

    def _stream_done(self, req: Request) -> bool:
        return self._stream_reason(req) is not None

    def _finished(self, req: Request) -> bool:
        reason = self._stream_reason(req)
        if reason is None:
            limit = self.pool.length_bound if self.paged else self.sc.max_len
            if self.slot_pos[req.slot] >= limit - 1:
                reason = "length"       # arena/pool position bound
        if reason is None:
            return False
        req.finish_reason = reason
        return True

    def _retire(self, req: Request) -> None:
        req.state = RequestState.DONE
        req.t_done = time.monotonic()
        self._account_latency(req)
        if self.tracer.enabled:
            self.tracer.end_request(req.req_id, req.t_done,
                                    reason=req.finish_reason,
                                    generated=len(req.generated),
                                    n_preempted=req.n_preempted)
        if self.drafter is not None:
            self.drafter.release(req.slot)
        if self.paged:
            self.pool.release(req.slot)
        self.slot_req[req.slot] = None
        self.slot_pos[req.slot] = -1
        self.done.append(req)

    def _account_latency(self, req: Request) -> None:
        """Retirement-time latency/SLO bookkeeping: TTFT/TPOT histogram
        samples (NaN = never emitted — skipped by ``observe``) and, for
        requests submitted with deadlines, the ``serving_slo_*``
        attainment counters behind ``goodput()``.  Aborted requests never
        reach here (see ``abort``): goodput measures served requests."""
        m = self.metrics
        m.observe("serving_ttft_seconds", req.ttft)
        m.observe("serving_tpot_seconds", req.tpot)
        if req.slo is None:
            return
        ok, ttft_ok, tpot_ok = slo_attainment(req.ttft, req.tpot, req.slo)
        m.inc("serving_slo_requests_total")
        if ok:
            m.inc("serving_slo_attained_total")
        if not ttft_ok:
            m.inc("serving_slo_ttft_violations_total")
        if not tpot_ok:
            m.inc("serving_slo_tpot_violations_total")

    def _grow_for_decode(self, r: Request) -> bool:
        """Secure this tick's one-token write for ``r``: grow the slot by
        one position and COW any shared page that position lands in
        (ring wrap over attached/published prefix pages).  Exhaustion
        order: reclaim cached pages first, preempt live requests only
        after the cache is dry.  Returns False iff ``r`` itself was
        evicted."""
        pos = int(self.slot_pos[r.slot])
        while True:
            if self.pool.grow(r.slot, pos + 1):
                if self._ensure_writable(r.slot, pos, pos + 1):
                    return True
                # grown but no COW target: roll back before freeing pages
                self.pool.shrink(r.slot, pos)
            elif self._reclaim_cache(1):
                continue
            victim = self._preemption_victim(r)
            self._preempt(victim)
            if victim is r:
                return False

    # -- phase execution --------------------------------------------------------
    def _run_prefill_tick(self, plan: TickPlan) -> None:
        """Execute the plan's prefill chunks on the planned worker group."""
        reqs = self._by_id()
        chunks = [(reqs[rid], take) for rid, take in plan.prefill_chunks
                  if rid in reqs and take > 0]
        if not chunks:
            return
        if not self.chunked:
            # atomic whole-prompt prefill (SSM / shared-attn state handoff)
            self._prefill_progress = True
            for req, take in chunks:
                tokens = jnp.asarray(req.prompt[None], jnp.int32)
                pp, all_greedy = self._pack_params([(0, req)], 1)
                tw0 = self.tracer.now()
                self._note_compile(plan.prefill_group, "whole",
                                   (req.prompt_len,), all_greedy)
                toks, self.cache = self._program(plan.prefill_group, "whole")(
                    self.params, tokens, jnp.int32(req.slot), self.cache,
                    *pp, all_greedy)
                if self.tracer.enabled:
                    self.tracer.request_span(
                        req.req_id, "prefill_chunk", tw0, self.tracer.now(),
                        take=req.prompt_len, offset=0)
                req.prefill_pos = req.prompt_len
                self.prefill_tokens_executed += req.prompt_len
                self.prefill_launches += 1
                self.prefill_rows_executed += req.prompt_len
                self._start_decoding(req, self._to_host(toks)[0])
            return

        if self.paged:
            # claim the chunks' pages; the scheduler planned against the
            # pool headroom, so this succeeds — trim defensively (one
            # query, one grow) if a same-tick race says otherwise.  Any
            # SHARED page the chunk would dirty (a ring wrap over attached
            # prefix pages) is copied first; if no copy target exists even
            # after reclaiming cached pages, the chunk rolls back and
            # waits for a later tick.
            claimed = []
            for req, take in chunks:
                take = min(take, self.pool.max_grow_tokens(req.slot))
                if take <= 0 or not self.pool.grow(req.slot,
                                                   req.prefill_pos + take):
                    continue
                if not self._ensure_writable(req.slot, req.prefill_pos,
                                             req.prefill_pos + take):
                    self.pool.shrink(req.slot, req.prefill_pos)
                    continue
                claimed.append((req, take))
            chunks = claimed
            if not chunks:
                return
        self._prefill_progress = True

        tp0 = self.tracer.now()
        if self._packed:
            toks = self._launch_packed_prefill(plan, chunks)
        else:
            toks = self._launch_padded_prefill(plan, chunks)
        if self.tracer.enabled:
            tp1 = self.tracer.now()     # one launch serves every chunk
            for req, take in chunks:
                self.tracer.request_span(req.req_id, "prefill_chunk",
                                         tp0, tp1, take=take,
                                         offset=req.prefill_pos)
        self.prefill_tokens_executed += sum(take for _, take in chunks)
        self.prefill_launches += 1
        sampled = None
        for i, (req, take) in enumerate(chunks):
            req.prefill_pos += take
            if req.prefill_pos >= self._effective_len(req):
                if sampled is None:
                    sampled = self._to_host(toks)   # one transfer per tick
                self._start_decoding(req, sampled[i])

    def _launch_padded_prefill(self, plan: TickPlan, chunks) -> Any:
        """The tick's chunks as one padded [N, C] batch (pow2 buckets bound
        the number of compiled shapes).  Row i samples chunk i."""
        N = _bucket(len(chunks), self.sc.max_batch)
        C = _bucket(max(take for _, take in chunks), self.sc.phase.prefill_chunk)
        if self.cfg.n_codebooks > 1:
            tokens = np.zeros((N, self.cfg.n_codebooks, C), np.int32)
        else:
            tokens = np.zeros((N, C), np.int32)
        offs = np.zeros((N,), np.int32)
        lens = np.zeros((N,), np.int32)
        slots = np.full((N,), self.sc.max_batch, np.int32)  # OOB rows: drop
        for i, (req, take) in enumerate(chunks):
            sl = slice(req.prefill_pos, req.prefill_pos + take)
            tokens[i, ..., :take] = self._effective_tokens(req)[..., sl]
            offs[i] = req.prefill_pos
            lens[i] = take
            slots[i] = req.slot
        pp, all_greedy = self._pack_params(
            [(i, req) for i, (req, _) in enumerate(chunks)], N)
        self.prefill_rows_executed += N * C
        if self.paged:
            self._note_compile(plan.prefill_group, "chunk_paged", (N, C),
                               all_greedy)
            toks, self.cache = self._program(plan.prefill_group,
                                             "chunk_paged")(
                self.params, jnp.asarray(tokens), jnp.asarray(offs),
                jnp.asarray(lens), jnp.asarray(slots), self.cache,
                self.pool.block_tables(), *pp, all_greedy)
        else:
            self._note_compile(plan.prefill_group, "chunk", (N, C),
                               all_greedy)
            toks, self.cache = self._program(plan.prefill_group, "chunk")(
                self.params, jnp.asarray(tokens), jnp.asarray(offs),
                jnp.asarray(lens), jnp.asarray(slots), self.cache,
                *pp, all_greedy)
        return toks

    def _launch_packed_prefill(self, plan: TickPlan, chunks) -> Any:
        """The tick's chunks as ONE flat [T] token stream: chunk i occupies
        ``[starts[i], starts[i] + take)``, T is the pow2-bucketed packed
        length, and pad gaps carry no request (start sentinel T, slot
        sentinel max_batch).  Pad work is the alignment remainder instead
        of the padded batch's ``N*C - sum(take)``, and the compiled-shape
        key is (T,) alone — one ladder, not an (N, C) grid: the segment
        metadata is always max_batch wide (tiny arrays; pad segments are
        sentinel-masked), so only the stream length retraces.  Row i of
        the returned tokens samples chunk i, exactly like the padded
        batch."""
        packed = pack_chunks([(req.req_id, take) for req, take in chunks],
                             align=self.sc.phase.pack_align)
        T = packed.length
        Nb = self.sc.max_batch
        tokens = np.zeros((T,), np.int32)
        starts = np.full((Nb,), T, np.int32)    # pad segments: empty tail
        offs = np.zeros((Nb,), np.int32)
        lens = np.zeros((Nb,), np.int32)
        slots = np.full((Nb,), self.sc.max_batch, np.int32)  # OOB rows: drop
        for i, (req, take) in enumerate(chunks):
            s = packed.starts[i]
            sl = slice(req.prefill_pos, req.prefill_pos + take)
            tokens[s:s + take] = self._effective_tokens(req)[sl]
            starts[i] = s
            offs[i] = req.prefill_pos
            lens[i] = take
            slots[i] = req.slot
        pp, all_greedy = self._pack_params(
            [(i, req) for i, (req, _) in enumerate(chunks)], Nb)
        self.prefill_rows_executed += T
        if self.paged:
            self._note_compile(plan.prefill_group, "packed_paged", (T, Nb),
                               all_greedy)
            toks, self.cache = self._program(plan.prefill_group,
                                             "packed_paged")(
                self.params, jnp.asarray(tokens), jnp.asarray(starts),
                jnp.asarray(offs), jnp.asarray(lens), jnp.asarray(slots),
                self.cache, self.pool.block_tables(), *pp, all_greedy)
        else:
            self._note_compile(plan.prefill_group, "packed", (T, Nb),
                               all_greedy)
            toks, self.cache = self._program(plan.prefill_group, "packed")(
                self.params, jnp.asarray(tokens), jnp.asarray(starts),
                jnp.asarray(offs), jnp.asarray(lens), jnp.asarray(slots),
                self.cache, *pp, all_greedy)
        return toks

    # -- speculative draft/verify ------------------------------------------------
    def _spec_budget(self, r: Request) -> int:
        """Largest draft window this tick could commit AND roll back for
        ``r``, before any drafter runs: the ring rollback bound, the pool
        length bound, the remaining token budget (a window emits up to
        k+1 tokens), and the pages the pool can grant without preempting
        anyone (speculation is opportunistic — the one-token decode path
        owns the preemption machinery).  Computed drafter-free so
        permanently unspeculatable requests (a ring target past its
        rollback bound) never pay drafting cost at all."""
        pos = int(self.slot_pos[r.slot])
        return min(
            self.spec.k,
            self._rollback_bound - pos - 1,
            self.pool.length_bound - pos - 2,
            r.max_new_tokens - len(r.generated) - 1,
            self.pool.max_grow_tokens(r.slot) - 1,
        )

    def _run_verify_tick(self, plan: TickPlan,
                         rows: List[Tuple[Request, np.ndarray]]) -> None:
        """Execute the tick's verify windows as ONE packed batch on the
        verify (CiM-analogue) worker group and commit the results:
        accepted drafts + one correction/bonus token per row, with the
        rejected tail's KV rolled back via ``KVPool.truncate``."""
        kmax = max(int(d.shape[-1]) for _, d in rows)
        N = _bucket(len(rows), self.sc.max_batch)
        C = _bucket(kmax + 1, self.spec.k + 1)
        tokens = np.zeros((N, C), np.int32)
        draft = np.zeros((N, C - 1), np.int32)
        offs = np.zeros((N,), np.int32)
        lens = np.zeros((N,), np.int32)
        slots = np.full((N,), self.sc.max_batch, np.int32)  # OOB rows: drop
        for i, (r, d) in enumerate(rows):
            kd = int(d.shape[-1])
            tokens[i, 0] = r.generated[-1]
            tokens[i, 1:1 + kd] = d
            draft[i, :kd] = d
            offs[i] = self.slot_pos[r.slot]
            lens[i] = kd + 1
            slots[i] = r.slot
        pp, all_greedy = self._pack_params(
            [(i, r) for i, (r, _) in enumerate(rows)], N)
        tv0 = self.tracer.now()
        self._note_compile(plan.verify_group, "verify", (N, C), all_greedy)
        out, self.cache = self._program(plan.verify_group, "verify")(
            self.params, jnp.asarray(tokens), jnp.asarray(offs),
            jnp.asarray(lens), jnp.asarray(slots), self.cache,
            self.pool.block_tables(), jnp.asarray(draft), *pp, all_greedy)
        packed = self._to_host(out)                 # [N, C+1], one transfer
        tv1 = self.tracer.now()
        for i, (r, d) in enumerate(rows):
            kd = int(d.shape[-1])
            n_emit = int(packed[i, -1])
            accepted = n_emit - 1
            self.decode_slot_ticks += 1
            self.spec_windows += 1
            self.spec_drafted += kd
            self.spec_accepted += accepted
            # the emitted tokens' KV: window inputs [gen[-1], d_1..d_acc]
            # are committed; the final emitted token is fed next tick; the
            # rejected tail (positions past pos + acc + 1) rolls back
            new_pos = int(self.slot_pos[r.slot]) + accepted + 1
            self.pool.truncate(r.slot, new_pos)
            self.slot_pos[r.slot] = new_pos
            appended = 0
            for t in packed[i, :n_emit]:
                self._append_token(r, t)
                self.decode_tokens_emitted += 1
                appended += 1
                if self._stream_done(r):        # eos / max_new clip only
                    break
            if self.tracer.enabled:
                self.tracer.request_span(r.req_id, "verify_window", tv0, tv1,
                                         drafted=kd, accepted=accepted,
                                         emitted=appended)
            if self.drafter is not None:
                self.drafter.observe(r.slot, r.req_id,
                                     self._effective_len(r))
            if self._finished(r):
                self._retire(r)

    def _plan_speculation(self, active: List[Request]
                          ) -> Tuple[List[Tuple[Request, np.ndarray]],
                                     List[Request]]:
        """Partition this tick's decode occupants into verify rows (the
        drafter proposed something usable, window pages secured, shared
        pages COW'd) and plain one-token decoders (everything else)."""
        budgets: Dict[int, int] = {}
        plain: List[Request] = []
        candidates: List[Request] = []
        for r in sorted(active, key=lambda r: r.req_id):
            budgets[r.req_id] = self._spec_budget(r)
            (candidates if budgets[r.req_id] >= 1 else plain).append(r)
        proposals = self.drafter.propose_batch(
            [(r.slot, r.req_id, self._effective_tokens(r))
             for r in candidates],
            self.spec.k) if candidates else {}
        rows: List[Tuple[Request, np.ndarray]] = []
        for r in candidates:
            d = proposals.get(r.slot)
            kd = min(budgets[r.req_id], int(d.shape[-1])) \
                if d is not None else 0
            if kd < 1:
                plain.append(r)
                continue
            d = np.asarray(d[:kd], np.int32)
            pos = int(self.slot_pos[r.slot])
            if not self.pool.grow(r.slot, pos + kd + 1):
                plain.append(r)                     # raced: fall back
                continue
            if not self._ensure_writable(r.slot, pos, pos + kd + 1):
                self.pool.shrink(r.slot, pos)       # roll the claim back
                plain.append(r)
                continue
            rows.append((r, d))
        return rows, plain

    def _run_decode_tick(self, plan: TickPlan) -> None:
        reqs = self._by_id()
        active = [reqs[rid] for rid in plan.decode_reqs
                  if rid in reqs and reqs[rid].state == RequestState.DECODING]
        if self.spec is not None and active:
            rows, active = self._plan_speculation(active)
            if rows:
                self._run_verify_tick(plan, rows)
        if self.paged and active:
            # each decode write may cross into a fresh page (or, shared-
            # prefix, into a page another request still reads — COW).
            # Grow oldest-first; when the pool is out, reclaim CACHED
            # pages LRU-first, and only if the cache cannot help PREEMPT
            # the youngest page holder — its pages come back, it
            # re-queues for recompute
            survivors = []
            for r in sorted(active, key=lambda r: r.req_id):
                if r.state != RequestState.DECODING or r.slot < 0:
                    continue                        # evicted earlier this loop
                if self._grow_for_decode(r):
                    survivors.append(r)
            active = survivors
        if not active:
            return
        B = self.sc.max_batch
        if self.paged:
            # the page pool addresses KV through the CALL's block tables,
            # not the batch row, so the decode batch compacts: active
            # slots map to rows 0..len(active) and the row count rounds
            # up the pow2 bucket ladder — a lone straggler decodes at
            # batch 1, not max_batch, with at most log2(B)+1 shapes
            nb = _bucket(len(active), B)
            if self.cfg.n_codebooks > 1:
                tokens = np.zeros((nb, self.cfg.n_codebooks, 1), np.int32)
            else:
                tokens = np.zeros((nb, 1), np.int32)
            pos = np.zeros((nb,), np.int32)
            for i, r in enumerate(active):
                tokens[i, ..., 0] = r.generated[-1]
                pos[i] = self.slot_pos[r.slot]
            pp, all_greedy = self._pack_params(
                [(i, r) for i, r in enumerate(active)], nb)
            td0 = self.tracer.now()
            self._note_compile(plan.decode_group, "decode_paged", (nb,),
                               all_greedy)
            # pad rows carry all-sentinel block-table rows: their scatters
            # drop — the paged analogue of the dense slot_mask
            toks, self.cache = self._program(plan.decode_group,
                                             "decode_paged")(
                self.params, jnp.asarray(tokens), self.cache,
                jnp.asarray(pos),
                self.pool.block_tables(rows=[r.slot for r in active], n=nb),
                *pp, all_greedy)
            sampled = self._to_host(toks)           # one transfer per tick
            emitted = [(i, r) for i, r in enumerate(active)]
        else:
            # the dense arena is slot-indexed, so the batch stays [B]
            if self.cfg.n_codebooks > 1:
                tokens = np.zeros((B, self.cfg.n_codebooks, 1), np.int32)
            else:
                tokens = np.zeros((B, 1), np.int32)
            mask = np.zeros((B,), bool)
            for r in active:
                tokens[r.slot, ..., 0] = r.generated[-1]
                mask[r.slot] = True
            # ragged decode: per-slot positions (vector pos -> per-slot
            # rope, per-slot cache write index, per-slot validity mask)
            pos = np.where(self.slot_pos >= 0,
                           self.slot_pos, 0).astype(np.int32)
            pp, all_greedy = self._pack_params(
                [(r.slot, r) for r in active], B)
            td0 = self.tracer.now()
            self._note_compile(plan.decode_group, "decode", (B,), all_greedy)
            toks, self.cache = self._program(plan.decode_group, "decode")(
                self.params, jnp.asarray(tokens), self.cache,
                jnp.asarray(pos), jnp.asarray(mask), *pp, all_greedy)
            sampled = self._to_host(toks)           # one transfer per tick
            emitted = [(r.slot, r) for r in active]
        td1 = self.tracer.now()
        for row, r in emitted:
            self._append_token(r, sampled[row])
            # occupancy is counted at emission, not at planning: a request
            # preempted by its own growth failure emitted nothing and must
            # not drag tokens_per_tick below the non-speculative 1.0 floor
            self.decode_tokens_emitted += 1
            self.decode_slot_ticks += 1
            self.slot_pos[r.slot] += 1
            if self.tracer.enabled:
                self.tracer.request_span(r.req_id, "decode", td0, td1,
                                         tokens=1)
            if self._finished(r):
                self._retire(r)

    # -- tick loop ---------------------------------------------------------------
    def step(self) -> List[RequestOutput]:
        """One engine tick: plan (scheduler) -> execute -> report.

        Returns one incremental ``RequestOutput`` per request that
        ADVANCED this tick — new tokens appended, and/or the request
        finished — ordered by req_id.  A preempted request whose tokens
        are unchanged emits nothing (preemption keeps its generated
        tokens; recompute-on-resume replays no draws), but one that
        GAINED tokens earlier in the same tick still reports them; an
        ``abort()`` between ticks returns its terminal output directly
        from ``abort``."""
        t0 = time.monotonic()
        self.executor.begin_tick()
        self._prefill_progress = False
        # deferred submits re-enter as soon as the backlog has room —
        # BEFORE this tick's admission so they compete for freed slots
        self._reconsider_deferred()
        # snapshot for incremental outputs: every request that can gain
        # tokens this tick is in the queue or a slot right now
        counts0 = {r.req_id: len(r.generated) for r in self.queue}
        counts0.update({r.req_id: len(r.generated)
                        for r in self.slot_req if r is not None})
        done0 = len(self.done)
        self._admit()
        # entries carry (priority, TTFT deadline) so plan_tick can order
        # the prefill budget SLO-aware: class first, then EDF, then age
        # (FIFO) — for deadline-free requests this is the original pure
        # age order, where under page contention the oldest request gets
        # the budget/pages first (slot order would starve older requests
        # behind a recycled low slot and thrash the pool)
        prefilling = sorted(
            ((r.req_id, self._effective_len(r) - r.prefill_pos,
              self.chunked, r.prefill_pos, r.priority, r.ttft_deadline_s)
             for r in self.slot_req
             if r is not None and r.state == RequestState.PREFILLING),
            key=lambda e: e[0])
        decoding = [r.req_id for r in self.slot_req
                    if r is not None and r.state == RequestState.DECODING]
        spec_k = self.spec.k if self.spec is not None else 0
        if self.paged:
            # token-level admission: prefill work is planned against the
            # pool's free pages, with this tick's decode growth reserved
            # (a speculative verify window grows by up to k+1 tokens — the
            # windows are charged like mini prefill chunks)
            headroom = self.pool.headroom_pages(
                [self.pool.len_of(r.slot) for r in self.slot_req
                 if r is not None and r.state == RequestState.DECODING],
                growth=spec_k + 1)
            plan = self.scheduler.plan_tick(
                prefilling, decoding, free_pages=headroom,
                page_size=self.sc.page_size,
                capacity=self.pool.widest_capacity(), spec_k=spec_k)
        else:
            plan = self.scheduler.plan_tick(prefilling, decoding,
                                            spec_k=spec_k)
        if plan.prefill_chunks:
            self._run_prefill_tick(plan)
        if plan.decode_reqs:
            self._run_decode_tick(plan)
        if self.paged and not plan.decode_reqs and not self._prefill_progress:
            self._break_prefill_stall()
        resident = self.pool.resident_bytes() if self.paged else 0
        self.kv_resident_peak = max(self.kv_resident_peak, resident)
        # per-tick counters are registry DELTAS against the previous
        # record's baseline — preemption/spec/swap totals have exactly one
        # home (the registry), each TickRecord reports what accrued since
        # the last one, and Σ tick_log.<field> == the lifetime counter
        # even for movement BETWEEN ticks (e.g. a caller-driven preempt)
        cur = self.metrics.values(self._TICK_DELTA_KEYS)
        delta = {k: cur[k] - self._tick_delta_base[k] for k in cur}
        self._tick_delta_base = cur
        rec = TickRecord(
            index=self._n_ticks,
            prefill_reqs=list(plan.prefill_reqs),
            prefill_tokens=plan.prefill_tokens,
            decode_reqs=list(plan.decode_reqs),
            prefill_group=plan.prefill_group,
            decode_group=plan.decode_group,
            wall_s=time.monotonic() - t0,
            preemptions=int(delta["serving_preemptions_total"]),
            kv_resident_bytes=resident,
            spec_drafted=int(delta["serving_spec_drafted_total"]),
            spec_accepted=int(delta["serving_spec_accepted_total"]),
            new_compiles=self.executor.tick_new_compiles,
            migrated_pages=self.executor.tick_migrated_pages,
            migrated_bytes=self.executor.tick_migrated_bytes,
            swap_out_bytes=int(delta["serving_swap_out_bytes_total"]),
            swap_in_bytes=int(delta["serving_swap_in_bytes_total"]),
            host_resident_pages=(self.host_tier.used_pages()
                                 if self.host_tier is not None else 0))
        self.metrics.observe("serving_tick_wall_seconds", rec.wall_s)
        # tick-cost EMA for admission TTFT projections: alpha 0.2 tracks a
        # load shift within ~5 ticks.  Ticks that compiled a new phase
        # program are excluded outright — a compile stall is paid once per
        # shape, not per tick, and folding even one multi-second compile
        # into the EMA would have admission shedding everything on a
        # near-idle engine until the average decays
        if rec.new_compiles == 0:
            self._tick_wall_n += 1
            self._tick_wall_ema = (rec.wall_s if self._tick_wall_n == 1 else
                                   0.8 * self._tick_wall_ema
                                   + 0.2 * rec.wall_s)
        if self.tracer.enabled:
            # the TickRecord twin: every rec counter appears as a tick-span
            # arg, so summing an arg across the tick track reproduces the
            # registry total (the conservation law the tests pin)
            self.tracer.tick_span(
                t0, t0 + rec.wall_s, index=rec.index,
                prefill_reqs=list(rec.prefill_reqs),
                prefill_tokens=rec.prefill_tokens,
                decode_reqs=list(rec.decode_reqs),
                prefill_group=rec.prefill_group or "",
                decode_group=rec.decode_group or "",
                preemptions=rec.preemptions,
                kv_resident_bytes=rec.kv_resident_bytes,
                spec_drafted=rec.spec_drafted,
                spec_accepted=rec.spec_accepted,
                new_compiles=rec.new_compiles,
                migrated_pages=rec.migrated_pages,
                migrated_bytes=rec.migrated_bytes,
                swap_out_bytes=rec.swap_out_bytes,
                swap_in_bytes=rec.swap_in_bytes,
                host_resident_pages=rec.host_resident_pages)
        self.tick_log.append(rec)
        self._n_ticks += 1
        self._n_prefill_ticks += bool(rec.prefill_reqs)
        self._n_decode_ticks += bool(rec.decode_reqs)
        self._n_mixed_ticks += rec.mixed
        # incremental outputs: live slot holders + requests retired this
        # tick + requests preempted BACK TO THE QUEUE after gaining tokens
        # earlier in the same tick (a growth victim whose prefill had just
        # completed: its seeding token must not vanish from the stream),
        # diffed against the entry snapshot
        touched = [r for r in self.slot_req if r is not None]
        touched += self.done[done0:]
        touched += [r for r in self.queue
                    if len(r.generated) > counts0.get(r.req_id, 0)]
        outputs: List[RequestOutput] = []
        for r in sorted(touched, key=lambda r: r.req_id):
            n0 = counts0.get(r.req_id, 0)
            finished = r.state == RequestState.DONE
            if len(r.generated) > n0 or finished:
                outputs.append(RequestOutput(
                    req_id=r.req_id,
                    new_token_ids=list(r.generated[n0:]),
                    n_generated=len(r.generated),
                    finished=finished,
                    finish_reason=r.finish_reason if finished else None))
        return outputs

    def counts(self) -> Dict[str, int]:
        """Queue/slot/done occupancy (the old ``step()`` return value),
        plus the lifetime migration / tiered-KV counters and SLO
        attainment — every value is a view over the metrics registry
        (or derived from one), never a second copy."""
        g = self.goodput()
        return {"queued": len(self.queue),
                "deferred": len(self.deferred),
                "active": sum(r is not None for r in self.slot_req),
                "done": len(self.done),
                "shed": self.admission_shed,
                "migrated_pages": self.executor.migrated_pages,
                "migrated_bytes": self.executor.migrated_bytes,
                "swap_out_bytes": (self.host_tier.swap_out_bytes
                                   if self.host_tier is not None else 0),
                "swap_in_bytes": (self.host_tier.swap_in_bytes
                                  if self.host_tier is not None else 0),
                "swap_resumes": self.swap_resumes,
                "recompute_preemptions": self.recompute_preemptions,
                "host_resident_pages": (self.host_tier.used_pages()
                                        if self.host_tier is not None else 0),
                "slo_total": g["slo_total"],
                "slo_attained": g["slo_attained"],
                "goodput": g["goodput"]}

    def goodput(self) -> Dict[str, float]:
        """SLO attainment over retired requests submitted with deadlines
        (``submit(..., slo=SLO(...))``); aborted requests are excluded.

        ``goodput`` is the attained fraction — 1.0 vacuously when no
        request carried an SLO, so SLO-free runs read as unconstrained
        rather than failing.  The per-axis violation counts say WHICH
        deadline was missed (a request can violate both)."""
        m = self.metrics
        total = int(m.counter("serving_slo_requests_total"))
        attained = int(m.counter("serving_slo_attained_total"))
        return {
            "slo_total": total,
            "slo_attained": attained,
            "ttft_violations":
                int(m.counter("serving_slo_ttft_violations_total")),
            "tpot_violations":
                int(m.counter("serving_slo_tpot_violations_total")),
            "goodput": attained / total if total else 1.0,
        }

    def metrics_snapshot(self) -> Dict[str, Dict]:
        """The full registry snapshot (counters / gauges / histograms)
        with the point-in-time occupancy gauges refreshed first — the
        machine-readable superset of ``counts()`` / ``spec_stats()`` /
        ``prefix_stats()``."""
        m = self.metrics
        m.set_gauge("serving_requests_queued", len(self.queue))
        m.set_gauge("serving_requests_active",
                    sum(r is not None for r in self.slot_req))
        m.set_gauge("serving_requests_done", len(self.done))
        m.set_gauge("serving_kv_resident_bytes",
                    self.pool.resident_bytes() if self.paged else 0)
        m.set_gauge("serving_host_resident_pages",
                    self.host_tier.used_pages()
                    if self.host_tier is not None else 0)
        return m.snapshot()

    def _check_drained(self, ticks: int, max_ticks: int) -> None:
        """Fail LOUDLY when the tick budget runs out with live requests —
        a silent partial drain poisons every downstream comparison.  The
        message carries the counts() snapshot, the per-state request
        breakdown, and the last TickRecord so a stuck engine is
        diagnosable from the exception alone.  Admission-control
        outcomes appear as their OWN buckets: ``deferred`` (parked
        outside the queue, still owed service) and the ``shed`` tally —
        an admission stall must read differently from a scheduling
        stall of live queued requests."""
        if ticks >= max_ticks and (
                self.queue or self.deferred
                or any(r is not None for r in self.slot_req)):
            c = self.counts()
            states: Dict[str, int] = {}
            for r in list(self.queue) + [r for r in self.slot_req
                                         if r is not None]:
                states[r.state.value] = states.get(r.state.value, 0) + 1
            if self.deferred:
                # WAITING but not in the queue — their own bucket, not
                # lumped into "waiting"
                states["deferred"] = len(self.deferred)
            last = self.tick_log[-1] if self.tick_log else None
            raise RuntimeError(
                f"max_ticks={max_ticks} exhausted with live requests "
                f"({c['queued']} queued, {c['deferred']} deferred, "
                f"{c['active']} active, {c['done']} done of which "
                f"{c['shed']} shed; states={states}, "
                f"preemptions={self.preemptions}) — the engine did not "
                f"drain; raise max_ticks or check for a scheduling stall. "
                f"counts={c} last_tick={last}")

    def _live(self) -> bool:
        """Requests still owed service: queued, deferred, or in a slot."""
        return bool(self.queue or self.deferred
                    or any(r is not None for r in self.slot_req))

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while self._live() and ticks < max_ticks:
            self.step()
            ticks += 1
        self._check_drained(ticks, max_ticks)
        return self.done

    def stream(self, max_ticks: int = 10_000) -> Iterator[RequestOutput]:
        """Run the tick loop, yielding each ``RequestOutput`` as soon as
        its tick produced it — tokens are observable while OTHER requests
        are still prefilling or decoding.  ``submit()`` and ``abort()``
        may be called from the consuming loop (an abort's terminal output
        is returned by ``abort`` itself, not re-yielded here)."""
        ticks = 0
        while self._live() and ticks < max_ticks:
            yield from self.step()
            ticks += 1
        self._check_drained(ticks, max_ticks)

    def generate(self, prompts: Sequence[np.ndarray],
                 sampling: Union[SamplingParams, Sequence[SamplingParams],
                                 None] = None,
                 max_ticks: int = 10_000) -> List[Request]:
        """Batch facade: submit every prompt (one shared ``SamplingParams``
        or one per prompt), drain, and return the finished ``Request``s in
        submission order."""
        if sampling is None or isinstance(sampling, SamplingParams):
            sampling = [sampling] * len(prompts)
        if len(sampling) != len(prompts):
            raise ValueError(f"got {len(list(sampling))} SamplingParams for "
                             f"{len(prompts)} prompts")
        reqs = [self.submit(p, sampling=sp)
                for p, sp in zip(prompts, sampling)]
        self.run_until_drained(max_ticks)
        return reqs

    # -- metrics ------------------------------------------------------------------
    @property
    def n_ticks(self) -> int:
        """Lifetime tick count (``tick_log`` itself is bounded)."""
        return self._n_ticks

    def kv_bytes(self) -> Dict[str, int]:
        """KV memory accounting, dense-vs-paged comparable.

        ``reserved``: bytes the arena pins for its lifetime.  ``resident``:
        bytes actually backing live tokens right now (== reserved for the
        dense arena — that is the point); ``peak_resident``: high-water
        mark across ticks."""
        if self.paged:
            return {"reserved": self.pool.total_bytes(),
                    "resident": self.pool.resident_bytes(),
                    "peak_resident": self.kv_resident_peak}
        return {"reserved": self._dense_kv_bytes,
                "resident": self._dense_kv_bytes,
                "peak_resident": self._dense_kv_bytes}

    def prefix_stats(self) -> Dict[str, float]:
        """Prefix-cache effectiveness: hit rate, tokens served from cache
        vs prefill tokens actually computed, COW copies, evictions.
        Zeros when the cache is off (the comparison baseline)."""
        out = {
            "prefill_tokens_executed": float(self.prefill_tokens_executed),
            "cow_copies": float(self.cow_copies),
            "cache_evicted_pages": float(self.cache_evicted_pages),
            "hit_rate": 0.0,
            "hit_tokens": 0.0,
            "cached_pages": 0.0,
        }
        if self.prefix is not None:
            s = self.prefix.stats()
            out["hit_rate"] = float(s["hit_rate"])
            out["hit_tokens"] = float(s["hit_tokens"])
            out["cached_pages"] = float(s["cached_pages"])
        return out

    def spec_stats(self) -> Dict[str, float]:
        """Speculative-decoding effectiveness.

        ``acceptance_rate``: accepted / drafted (the drafter-quality
        number k should be tuned against); ``tokens_per_tick``: tokens
        emitted per (request, decode-tick) occupancy — 1.0 exactly for
        non-speculative decode, > 1 as soon as any draft survives
        verification.  Zeros/1.0 when speculation is off (the comparison
        baseline)."""
        return {
            "windows": float(self.spec_windows),
            "drafted": float(self.spec_drafted),
            "accepted": float(self.spec_accepted),
            "acceptance_rate": self.spec_accepted / max(self.spec_drafted, 1),
            "decode_tokens": float(self.decode_tokens_emitted),
            "tokens_per_tick": (self.decode_tokens_emitted
                                / max(self.decode_slot_ticks, 1)),
        }

    def phase_occupancy(self) -> Dict[str, float]:
        """Fractions of ticks running prefill / decode / both (interleave).

        Computed from running counters, so the numbers cover the engine's
        whole lifetime even after ``tick_log`` (bounded) has rotated."""
        n = max(self._n_ticks, 1)
        return {
            "prefill": self._n_prefill_ticks / n,
            "decode": self._n_decode_ticks / n,
            "mixed": self._n_mixed_ticks / n,
        }
