"""Serving engine: continuous batching with phase-disaggregated execution.

The engine owns two jitted programs over the SAME weights:

  * ``prefill_fn``  — full-sequence forward returning (last_logits, cache);
    on the production mesh this is the compute-sharded program (HALO: CiM);
  * ``decode_fn``   — one-token step against the batched KV cache;
    bandwidth-sharded (HALO: CiD).

Requests flow: queue -> (chunked) prefill -> KV handoff into a decode slot
-> continuous decode until EOS/max_tokens -> slot freed and refilled.  The
decode cache is a fixed [max_batch, max_len] arena; per-slot write indices
and validity masks implement right-aligned ragged batching (a slot's prompt
occupies positions [0, plen); generation continues at plen, plen+1, ...).

This is a single-host engine; launch/serve.py instantiates it either on the
host CPU (examples, tests) or under the production mesh with the decode
shardings from distributed/sharding.py.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import (
    build_plan,
    cache_len,
    forward,
    init_cache,
)
from repro.serving.scheduler import PhaseAwareConfig, PhaseScheduler


class RequestState(Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray                  # [T] int32 (or [K, T])
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine
    state: RequestState = RequestState.WAITING
    generated: List[int] = field(default_factory=list)
    slot: int = -1
    prompt_len: int = 0
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_submit

    @property
    def tpot(self) -> float:
        n = max(len(self.generated) - 1, 1)
        return (self.t_done - self.t_first_token) / n


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    phase: PhaseAwareConfig = field(default_factory=PhaseAwareConfig)
    greedy: bool = True


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Any, sc: ServeConfig,
                 *, mesh=None):
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self.mesh = mesh
        self.scheduler = PhaseScheduler(sc.phase)
        B, S = sc.max_batch, sc.max_len
        self.cache = init_cache(cfg, B, S)
        self.slot_pos = np.full((B,), -1, np.int64)     # next write position
        self.slot_req: List[Optional[Request]] = [None] * B
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self._next_id = 0

        # jitted programs (separate = phase-disaggregation; they would live
        # on different worker groups on a real cluster)
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)

    # -- jitted bodies --------------------------------------------------------
    def _prefill_impl(self, params, tokens, positions, pad_mask):
        """tokens [1, T_pad]; returns (last_logits [1, ...], cache pieces)."""
        logits, cache, _ = forward(params, self.cfg,
                                   {"tokens": tokens}, phase="prefill")
        return logits, cache

    def _decode_impl(self, params, tokens, cache, pos, slot_mask):
        logits, new_cache, _ = forward(params, self.cfg, {"tokens": tokens},
                                       phase="decode", cache=cache, pos=pos)
        # frozen slots keep their old cache (mask out writes of idle slots).
        # attn caches are [L, B, ...] (batch at axis 1); shared_attn caches
        # are [B, ...] (batch leading) — pick the axis whose size matches.
        B = slot_mask.shape[0]

        def merge(old, new):
            ax = 1 if (old.ndim >= 2 and old.shape[1] == B) else 0
            shape = [1] * old.ndim
            shape[ax] = B
            b = slot_mask.reshape(shape)
            return jnp.where(b, new, old)

        merged = jax.tree.map(merge, cache, new_cache)
        return logits, merged

    # -- public API -----------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: int = 32,
               eos_id: Optional[int] = None) -> Request:
        req = Request(self._next_id, np.asarray(prompt, np.int32),
                      max_new_tokens, eos_id)
        req.prompt_len = int(req.prompt.shape[-1])
        req.t_submit = time.monotonic()
        self._next_id += 1
        self.queue.append(req)
        return req

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> List[Request]:
        admitted = []
        free = self._free_slots()
        while free and self.queue:
            req = self.queue.pop(0)
            slot = free.pop(0)
            req.slot = slot
            req.state = RequestState.PREFILLING
            self.slot_req[slot] = req
            admitted.append(req)
        return admitted

    def _run_prefill(self, req: Request) -> None:
        """Prefill one request and splice its KV into the decode arena.

        The splice IS the HALO handoff: on a disaggregated deployment the
        prefill group computes the cache and ships it to the decode group.
        """
        T = req.prompt_len
        tokens = jnp.asarray(req.prompt[None], jnp.int32)
        if tokens.ndim == 3:
            pass                                         # [1, K, T] musicgen
        logits, cache = self._prefill(
            self.params, tokens,
            jnp.arange(T, dtype=jnp.int32)[None],
            jnp.ones((1, T), jnp.bool_))
        self._splice_cache(req.slot, cache, T)
        self.slot_pos[req.slot] = T
        tok = int(jnp.argmax(logits[0, -1], -1).reshape(-1)[0])
        req.generated.append(tok)
        req.t_first_token = time.monotonic()
        req.state = RequestState.DECODING
        if self._finished(req):
            self._retire(req)

    def _splice_cache(self, slot: int, new_cache, T: int) -> None:
        """Copy a single-request prefill cache into arena slot ``slot``."""
        plan = build_plan(self.cfg)
        S = self.sc.max_len
        out = []
        for run, arena, piece in zip(plan, self.cache, new_cache):
            if run.kind == "ssm":
                upd = {k: arena[k].at[:, slot:slot + 1].set(piece[k])
                       for k in arena}
                out.append(upd)
                continue
            d: Dict[str, Any] = {}
            for k in arena:
                a, p = arena[k], piece[k]
                # attn caches: [L, B, S, ...] (batch=1, seq=2);
                # shared_attn:  [B, S, ...]   (batch=0, seq=1)
                b_ax, ax = (1, 2) if run.kind == "attn" else (0, 1)
                Sa = a.shape[ax]
                pl = min(p.shape[ax], Sa)
                sl_a = [slice(None)] * a.ndim
                sl_p = [slice(None)] * p.ndim
                sl_a[b_ax] = slice(slot, slot + 1)
                sl_a[ax] = slice(0, pl)
                sl_p[b_ax] = slice(0, 1)
                sl_p[ax] = slice(p.shape[ax] - pl, p.shape[ax])
                d[k] = a.at[tuple(sl_a)].set(p[tuple(sl_p)])
            out.append(d)
        self.cache = out

    def _finished(self, req: Request) -> bool:
        if len(req.generated) >= req.max_new_tokens:
            return True
        if (req.eos_id is not None and req.generated
                and req.generated[-1] == req.eos_id):
            return True
        if self.slot_pos[req.slot] >= self.sc.max_len - 1:
            return True
        return False

    def _retire(self, req: Request) -> None:
        req.state = RequestState.DONE
        req.t_done = time.monotonic()
        self.slot_req[req.slot] = None
        self.slot_pos[req.slot] = -1
        self.done.append(req)

    def _run_decode_tick(self) -> None:
        active = [r for r in self.slot_req if r is not None
                  and r.state == RequestState.DECODING]
        if not active:
            return
        B = self.sc.max_batch
        tokens = np.zeros((B, 1), np.int32)
        mask = np.zeros((B,), bool)
        for r in active:
            tokens[r.slot, 0] = r.generated[-1]
            mask[r.slot] = True
        # ragged decode: per-slot positions (vector pos -> per-slot rope,
        # per-slot cache write index, per-slot validity mask)
        pos = np.where(self.slot_pos >= 0, self.slot_pos, 0).astype(np.int32)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tokens), self.cache,
            jnp.asarray(pos), jnp.asarray(mask))
        for r in active:
            tok = int(jnp.argmax(logits[r.slot, -1], -1).reshape(-1)[0])
            r.generated.append(tok)
            self.slot_pos[r.slot] += 1
            if self._finished(r):
                self._retire(r)

    def step(self) -> Dict[str, int]:
        """One engine tick: admit -> prefill -> decode (continuous batching)."""
        admitted = self._admit()
        waiting = [(r.req_id, r.prompt_len) for r in admitted]
        decoding = [r.req_id for r in self.slot_req
                    if r is not None and r.state == RequestState.DECODING]
        plan = self.scheduler.plan_tick(waiting, decoding)
        for r in admitted:
            self._run_prefill(r)
        self._run_decode_tick()
        return {"queued": len(self.queue),
                "active": sum(r is not None for r in self.slot_req),
                "done": len(self.done)}

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or any(self.slot_req)) and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.done
