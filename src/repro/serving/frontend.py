"""Async continuous-serving front-end: many concurrent clients, ONE engine.

``ServingEngine`` is single-threaded by design — every structure it owns
(queue, slots, page pool, metrics registry) assumes exactly one caller
drives ``submit()``/``step()``.  ``AsyncEngine`` keeps that invariant
while serving concurrent clients by the ACTOR pattern:

      client tasks                      owner task (one per engine)
    ──────────────                    ─────────────────────────────
    await submit()  ──┐
    await submit()  ──┼──▶  mailbox  ──▶  drain FIFO ─▶ engine.submit()
    cancel()        ──┘   (deque+event)   engine.step()
                                            │ RequestOutputs
         ◀──────── per-request asyncio.Queue┘  (fan-out by req_id)

Clients never touch the engine.  They post messages (submit / abort)
into a mailbox; a single background OWNER task drains the mailbox in
FIFO order, runs one ``engine.step()`` when work is live, and fans each
``RequestOutput`` out to its request's private ``asyncio.Queue``.  A
client consumes its stream with ``async for`` and cancels by dropping
the stream (``stream()`` aborts the request on the way out).

DETERMINISM: this design is deterministic BY CONSTRUCTION, not by a
mode switch.  The mailbox is drained in the order clients posted
(posting happens synchronously inside ``submit()`` before its first
``await``), so request ids and derived seeds match the synchronous
engine fed the same submissions in the same order; and greedy token
streams are batch-composition-independent (the invariant the serving
test-suite pins), so WHATEVER tick interleaving the event loop produces,
greedy streams are bit-identical to ``EngineCore`` run synchronously —
the identity `tests/test_frontend.py` and the traffic bench both assert.

The owner task calls ``engine.step()`` inline (the event loop blocks for
the tick's duration, then yields): ticks are the unit of progress and
everything a client does between ticks is queue operations, so a
thread-pool handoff would buy responsiveness measured in microseconds at
the price of cross-thread engine state.  Single host, single engine —
scaling across engines is a layer above this one.
"""

from __future__ import annotations

import asyncio
from collections import deque
from dataclasses import dataclass
from typing import Any, AsyncIterator, Deque, Dict, List, Optional, Union

import numpy as np

from repro.serving.engine import ServingEngine
from repro.serving.metrics import SLO
from repro.serving.sampling import SamplingParams
from repro.serving.types import Request, RequestOutput

__all__ = [
    "AsyncEngine",
    "AsyncRequest",
]


@dataclass
class _Submit:
    prompt: np.ndarray
    kwargs: Dict[str, Any]
    future: asyncio.Future


@dataclass
class _Abort:
    req_id: int
    future: Optional[asyncio.Future]


class AsyncRequest:
    """A client's handle on one in-flight request.

    Async-iterable: ``async for out in handle`` yields each incremental
    ``RequestOutput`` and ends after the terminal one (``finished=True``
    — reason "length"/"eos"/"stop", or "abort"/"shed" for a request that
    never ran).  ``token_ids()``/``finish_reason`` read the accumulated
    result after the stream ends."""

    def __init__(self, request: Request, frontend: "AsyncEngine"):
        self.request = request
        self._frontend = frontend
        self.outputs: asyncio.Queue = asyncio.Queue()
        self.finished = False

    @property
    def req_id(self) -> int:
        return self.request.req_id

    @property
    def finish_reason(self) -> Optional[str]:
        return self.request.finish_reason

    def token_ids(self) -> List[Any]:
        return list(self.request.generated)

    def __aiter__(self) -> "AsyncRequest":
        return self

    async def __anext__(self) -> RequestOutput:
        if self.finished:
            raise StopAsyncIteration
        out = await self.outputs.get()
        if isinstance(out, BaseException):
            self.finished = True
            raise out
        if out.finished:
            self.finished = True
        return out


class AsyncEngine:
    """The asyncio front-end over one ``ServingEngine`` (see module doc).

    Use as an async context manager (starts/stops the owner task), or
    call ``start()`` / ``await aclose()`` explicitly::

        async with AsyncEngine(engine) as fe:
            h = await fe.submit(prompt, sampling=SamplingParams(...))
            async for out in h:
                ...

    ``aclose()`` aborts every in-flight request before stopping, so a
    client that forgets a stream cannot leak pool pages.
    """

    def __init__(self, engine: ServingEngine):
        self.engine = engine
        self._mailbox: Deque[Union[_Submit, _Abort]] = deque()
        self._wake = asyncio.Event()
        self._handles: Dict[int, AsyncRequest] = {}
        self._task: Optional[asyncio.Task] = None
        self._closing = False
        self._error: Optional[BaseException] = None

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> None:
        if self._task is None or self._task.done():
            self._closing = False
            self._task = asyncio.get_running_loop().create_task(
                self._run(), name="async-engine-owner")

    async def aclose(self) -> None:
        """Abort in-flight requests, stop the owner task, surface any
        engine error it died on."""
        if self._task is None:
            return
        self._closing = True
        self._wake.set()
        task, self._task = self._task, None
        await task

    async def __aenter__(self) -> "AsyncEngine":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    # -- client API ------------------------------------------------------------
    async def submit(self, prompt: np.ndarray,
                     max_new_tokens: Optional[int] = None,
                     eos_id: Optional[int] = None, *,
                     sampling: Optional[SamplingParams] = None,
                     slo: Optional[SLO] = None,
                     priority: Optional[int] = None) -> AsyncRequest:
        """Post one request to the engine; resolves once the OWNER task
        has run ``engine.submit`` (so ``handle.req_id`` is final).  The
        handle may come back already terminal: admission shed yields one
        ``finished`` output with reason "shed" and no tokens.

        Submission ORDER is the posting order — concurrent clients that
        each ``await submit(...)`` sequentially get consecutive ids, and
        the same prompts posted in the same order always derive the same
        per-request seeds (the determinism the identity tests pin)."""
        self._require_running()
        fut = asyncio.get_running_loop().create_future()
        self._mailbox.append(_Submit(
            prompt, dict(max_new_tokens=max_new_tokens, eos_id=eos_id,
                         sampling=sampling, slo=slo, priority=priority),
            fut))
        self._wake.set()
        return await fut

    async def stream(self, prompt: np.ndarray,
                     max_new_tokens: Optional[int] = None,
                     eos_id: Optional[int] = None, *,
                     sampling: Optional[SamplingParams] = None,
                     slo: Optional[SLO] = None,
                     priority: Optional[int] = None
                     ) -> AsyncIterator[RequestOutput]:
        """submit + iterate, with DISCONNECT SEMANTICS: if the consumer
        stops early — ``break``, task cancellation, client gone — the
        request is aborted, releasing its slot and pool pages.  This is
        the one-call path a network handler should use."""
        handle = await self.submit(prompt, max_new_tokens, eos_id,
                                   sampling=sampling, slo=slo,
                                   priority=priority)
        try:
            async for out in handle:
                yield out
        finally:
            if not handle.finished:
                # post-only (no await): safe under CancelledError /
                # GeneratorExit, where awaiting would re-raise
                self.cancel(handle.req_id)

    def cancel(self, req_id: int) -> None:
        """Fire-and-forget abort (safe from ``finally`` during
        cancellation).  The owner task aborts the request before its
        next tick; the handle's stream receives the terminal "abort"
        output."""
        if self._task is None or self._task.done():
            return
        self._mailbox.append(_Abort(req_id, None))
        self._wake.set()

    async def abort(self, req_id: int) -> Optional[RequestOutput]:
        """Abort and wait for the terminal output (None if the id is
        unknown or already finished)."""
        self._require_running()
        fut = asyncio.get_running_loop().create_future()
        self._mailbox.append(_Abort(req_id, fut))
        self._wake.set()
        return await fut

    async def drain(self) -> None:
        """Wait until every posted request has retired (the engine and
        mailbox are both empty).  Test/bench convenience — production
        clients just consume their streams."""
        self._require_running()
        while (self._mailbox or self._handles
               or self.engine._live()):        # noqa: SLF001 (owner facade)
            if self._error is not None:
                raise self._error
            await asyncio.sleep(0)
        if self._error is not None:
            raise self._error

    def _require_running(self) -> None:
        if self._error is not None:
            raise self._error
        if self._task is None or self._task.done():
            raise RuntimeError(
                "AsyncEngine is not running — use 'async with "
                "AsyncEngine(engine)' or call start() first")

    # -- owner task ------------------------------------------------------------
    def _drain_mailbox(self) -> None:
        """Apply every queued client message in FIFO order.  Runs ONLY in
        the owner task — the single place ``engine.submit``/``abort``
        are ever called from."""
        while self._mailbox:
            msg = self._mailbox.popleft()
            if isinstance(msg, _Submit):
                try:
                    req = self.engine.submit(msg.prompt, **msg.kwargs)
                except BaseException as e:           # invalid prompt etc.
                    if not msg.future.cancelled():
                        msg.future.set_exception(e)
                    continue
                handle = AsyncRequest(req, self)
                if req.finish_reason == "shed":
                    # terminal at admission: one finished output, stream
                    # ends immediately — the client sees the refusal the
                    # same way it sees any other terminal state
                    handle.outputs.put_nowait(RequestOutput(
                        req_id=req.req_id, new_token_ids=[], n_generated=0,
                        finished=True, finish_reason="shed"))
                else:
                    self._handles[req.req_id] = handle
                if not msg.future.cancelled():
                    msg.future.set_result(handle)
            else:
                out = self.engine.abort(msg.req_id)
                if out is not None:
                    self._dispatch(out)
                if msg.future is not None and not msg.future.cancelled():
                    msg.future.set_result(out)

    def _dispatch(self, out: RequestOutput) -> None:
        handle = self._handles.get(out.req_id)
        if handle is None:
            return
        handle.outputs.put_nowait(out)
        if out.finished:
            del self._handles[out.req_id]

    async def _run(self) -> None:
        """The owner loop: drain mailbox -> one tick -> fan out -> yield.

        ``asyncio.sleep(0)`` between ticks hands the loop to every ready
        client exactly once, so submissions posted while a tick ran are
        admitted before the next one — continuous batching across the
        async boundary."""
        try:
            while True:
                self._drain_mailbox()
                if self._closing:
                    break
                if self.engine._live():          # noqa: SLF001 (owner facade)
                    for out in self.engine.step():
                        self._dispatch(out)
                    await asyncio.sleep(0)
                else:
                    self._wake.clear()
                    if self._mailbox or self._closing:
                        continue
                    await self._wake.wait()
        except BaseException as e:
            self._error = e
            self._fail_inflight(e)
            raise
        finally:
            if self._error is None:
                self._close_inflight()

    def _fail_inflight(self, e: BaseException) -> None:
        for handle in self._handles.values():
            handle.outputs.put_nowait(e)
        self._handles.clear()
        for msg in self._mailbox:
            if msg.future is not None and not msg.future.done():
                msg.future.set_exception(e)
        self._mailbox.clear()

    def _close_inflight(self) -> None:
        """Clean shutdown with clients still attached: abort each live
        request so streams terminate and the engine releases its state."""
        for req_id in list(self._handles):
            out = self.engine.abort(req_id)
            if out is not None:
                self._dispatch(out)
            else:
                self._handles.pop(req_id, None)
        for msg in self._mailbox:
            if msg.future is not None and not msg.future.done():
                msg.future.set_exception(
                    RuntimeError("AsyncEngine closed before the request "
                                 "was accepted"))
        self._mailbox.clear()
