"""Executor layer: the jitted program table, compile counting, and device
placement for the serving engine.

The EngineCore (``serving/engine.py``) is host-only — it plans ticks,
packs batches, and keeps request/page accounting.  Everything that
touches a compiled executable lives HERE, keyed by (worker group, phase
kind).  Two placements:

* ``ColocatedExecutor`` — every program runs wherever jax would put it
  (one device group; the default, and exactly the pre-split behavior).
  The (group, kind) keying still simulates phase disaggregation — the
  strategy table routes each phase to a distinct jit instance — but
  no KV ownership ever moves.
* ``DisaggregatedExecutor`` — the HALO shape: prefill-side programs
  (chunk / whole / packed / speculative verify: the CiM-analogue GEMM
  phases) are pinned to the PREFILL device group and decode programs
  (the CiD-analogue GEMV phase) to the DECODE group
  (``launch/mesh.phase_device_groups``).  At each prefill -> decode
  handoff the engine reports the request's freshly-filled KV pages via
  ``record_handoff``; the executor accounts them as pages/bytes crossing
  the 2.5D interposer link — batched per tick (one link transaction per
  tick, however many requests finished prefilling in it).  On a
  single-device host both groups resolve to the same device, so greedy
  streams are bit-identical colocated vs disaggregated BY CONSTRUCTION
  — the programs, batches, and sampling are the same; only placement
  and ownership accounting differ.

Compile counting also lives here: every phase call notes its (group,
kind, bucketed shape, all_greedy) key and a first sighting counts as a
compile — the recompile-stall guarantee serving_bench asserts on (a
second wave of the same traffic adds ZERO keys) is an executor
property, not an engine one.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.launch.mesh import phase_device_groups
from repro.serving.metrics import MetricsRegistry, counter_attr


class Executor:
    """Base executor: program table + compile accounting, no placement.

    ``impls`` maps phase kind -> the (unjitted) program body; the engine
    passes its bound ``_*_impl`` methods.  Each (group, kind) pair
    becomes a SEPARATE jit instance — the software analogue of phase
    disaggregation: on a cluster these are distinct executables resident
    on different worker pools, and the strategy table routes each phase
    to one of them.
    """

    # kind -> (donated cache argnum, static all_greedy argnum).  The cache
    # argument is donated: the engine rebinds ``self.cache`` to each
    # program's output, so XLA updates the KV arena in place instead of
    # copying it.  ``all_greedy`` is STATIC: an all-greedy tick compiles
    # to plain argmax with no sort/PRNG work, a mixed tick compiles the
    # per-row path — at most two specializations per program.
    KIND_ARGS: Dict[str, Tuple[int, int]] = {
        "chunk": (5, 11),           # packed chunked prefill (dense arena)
        "whole": (3, 9),            # whole-prompt prefill (SSM / hybrid)
        "decode": (2, 10),          # one-token batched step (dense)
        "chunk_paged": (5, 12),     # chunked prefill into the page pool
        "decode_paged": (2, 10),    # paged flash-decode step
        "packed": (6, 12),          # packed-stream prefill (dense)
        "packed_paged": (6, 13),    # packed-stream prefill (paged)
        "verify": (5, 13),          # speculative verify window
    }
    # phase classification: decode kinds run on the decode (CiD) side,
    # everything else — prefill chunks AND speculative verify windows
    # (k+1-token prefill-shaped GEMMs) — on the prefill (CiM) side
    DECODE_KINDS = frozenset({"decode", "decode_paged"})

    #: True iff KV ownership moves at the prefill -> decode handoff
    #: (the engine consults this before computing handoff footprints)
    migrates_kv: bool = False

    # lifetime counters live in the metrics registry (the engine shares
    # its own via make_executor(..., metrics=...), so counts()/snapshot()
    # and these attributes read the same cells — serving/metrics.py)
    compile_count = counter_attr("serving_compiles_total")
    migrated_pages = counter_attr("serving_migrated_pages_total")
    migrated_bytes = counter_attr("serving_migrated_bytes_total")
    migration_batches = counter_attr("serving_migration_batches_total")

    def __init__(self, impls: Dict[str, Callable], *, mesh=None,
                 metrics: Optional[MetricsRegistry] = None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.impls = impls
        self.mesh = mesh
        # (group, kind) -> jitted program; built lazily so each strategy
        # only compiles the programs its groups actually execute
        self.programs: Dict[Tuple[str, str], Callable] = {}
        self._compile_keys: set = set()
        self.compile_count = 0           # distinct phase-program shapes
        self.tick_new_compiles = 0
        # migration counters (stay 0 forever on the colocated executor)
        self.migrated_pages = 0          # KV pages moved prefill -> decode
        self.migrated_bytes = 0          # the 2.5D-link byte analogue
        self.migration_batches = 0       # ticks with >= 1 handoff
        self.tick_migrated_pages = 0
        self.tick_migrated_bytes = 0

    # -- placement -------------------------------------------------------------
    def phase_of(self, kind: str) -> str:
        return "decode" if kind in self.DECODE_KINDS else "prefill"

    def device_for(self, kind: str):
        """Device the (jitted) programs of ``kind`` are pinned to, or None
        for jax's default placement (colocated)."""
        return None

    # -- program table ---------------------------------------------------------
    def program(self, group: str, kind: str) -> Callable:
        """Jitted program for (worker group, phase kind), built on first
        use and pinned to ``device_for(kind)`` when the executor places
        phases on separate device groups."""
        key = (group, kind)
        if key not in self.programs:
            cache_arg, static_arg = self.KIND_ARGS[kind]
            fn = jax.jit(self.impls[kind], donate_argnums=(cache_arg,),
                         static_argnums=(static_arg,))
            dev = self.device_for(kind)
            if dev is not None:
                fn = _pin(fn, dev)
            self.programs[key] = fn
        return self.programs[key]

    # -- compile accounting ----------------------------------------------------
    def note_compile(self, group: str, kind: str, shape: Tuple[int, ...],
                     all_greedy: bool) -> None:
        """Record one phase-program call's compilation key.

        jit retraces on every new input-shape signature; with the pow2
        buckets each phase has a small closed key set, so after warmup
        every key is a cache hit.  The counter is what serving_bench and
        the tier-2 smoke assert on: a second pass of the same traffic mix
        must add ZERO new compiles — the recompile-stall guarantee the
        bucket ladder exists to provide."""
        key = (group, kind, shape, bool(all_greedy))
        if key not in self._compile_keys:
            self._compile_keys.add(key)
            self.compile_count += 1
            self.tick_new_compiles += 1

    # -- per-tick bookkeeping --------------------------------------------------
    def begin_tick(self) -> None:
        self.tick_new_compiles = 0
        self.tick_migrated_pages = 0
        self.tick_migrated_bytes = 0

    def record_handoff(self, pages: int, nbytes: int) -> None:
        """One request's prefill -> decode KV handoff (colocated: no
        ownership moves, nothing to record)."""

    def stats(self) -> Dict[str, int]:
        return {
            "compile_count": self.compile_count,
            "migrated_pages": self.migrated_pages,
            "migrated_bytes": self.migrated_bytes,
            "migration_batches": self.migration_batches,
        }


class ColocatedExecutor(Executor):
    """Default placement: one device group runs every program."""


class DisaggregatedExecutor(Executor):
    """Prefill programs pinned to the prefill device group, decode
    programs to the decode group, with KV page ownership migrating at the
    prefill -> decode handoff (batched per tick — HALO's 2.5D link).

    ``devices`` overrides the (prefill_group, decode_group) split; by
    default ``phase_device_groups()`` halves ``jax.devices()`` (a
    single-device host shares the one device between both groups, which
    keeps streams bit-identical while the ownership accounting — the
    quantity under study — still runs for real)."""

    migrates_kv = True

    def __init__(self, impls: Dict[str, Callable], *, mesh=None,
                 metrics: Optional[MetricsRegistry] = None,
                 devices: Optional[Tuple[List[Any], List[Any]]] = None):
        super().__init__(impls, mesh=mesh, metrics=metrics)
        groups = devices if devices is not None else phase_device_groups()
        self.prefill_devices, self.decode_devices = groups

    def device_for(self, kind: str):
        group = (self.decode_devices if self.phase_of(kind) == "decode"
                 else self.prefill_devices)
        return group[0] if group else None

    def record_handoff(self, pages: int, nbytes: int) -> None:
        if pages <= 0 and nbytes <= 0:
            return
        if not self.tick_migrated_pages and not self.tick_migrated_bytes:
            self.migration_batches += 1      # first handoff this tick
        self.tick_migrated_pages += pages
        self.tick_migrated_bytes += nbytes
        self.migrated_pages += pages
        self.migrated_bytes += nbytes

    def stats(self) -> Dict[str, int]:
        out = super().stats()
        out["prefill_devices"] = len(self.prefill_devices)
        out["decode_devices"] = len(self.decode_devices)
        return out


def _pin(fn: Callable, dev) -> Callable:
    """Run ``fn`` with ``dev`` as the default device, so uncommitted
    inputs and fresh outputs land on the phase's worker group."""
    def run(*args, **kwargs):
        with jax.default_device(dev):
            return fn(*args, **kwargs)
    return run


def make_executor(name: str, impls: Dict[str, Callable], *, mesh=None,
                  metrics: Optional[MetricsRegistry] = None) -> Executor:
    """ServeConfig.executor -> Executor instance."""
    if name == "colocated":
        return ColocatedExecutor(impls, mesh=mesh, metrics=metrics)
    if name == "disaggregated":
        return DisaggregatedExecutor(impls, mesh=mesh, metrics=metrics)
    raise ValueError(f"executor={name!r} (expected 'colocated' or "
                     "'disaggregated')")


__all__ = [
    "ColocatedExecutor",
    "DisaggregatedExecutor",
    "Executor",
    "make_executor",
]
