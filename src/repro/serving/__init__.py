from repro.serving.engine import (
    Request,
    RequestState,
    ServeConfig,
    ServingEngine,
)
from repro.serving.scheduler import PhaseScheduler, PhaseAwareConfig

__all__ = ["Request", "RequestState", "ServeConfig", "ServingEngine",
           "PhaseScheduler", "PhaseAwareConfig"]
