"""Phase-disaggregated serving: the engine EXECUTES the scheduler's TickPlan
— chunked prefill packed into one batch per tick, K/V written directly into
the decode arena (the HALO CiM -> CiD handoff), device-side sampling (one
host transfer per tick), and strategy-routed worker-group programs.  See
docs/serving.md for the tick loop and its mapping onto the paper."""

from repro.serving.engine import (
    Request,
    RequestState,
    ServeConfig,
    ServingEngine,
    TickRecord,
)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import sample_tokens
from repro.serving.scheduler import PhaseAwareConfig, PhaseScheduler, TickPlan

__all__ = ["Request", "RequestState", "ServeConfig", "ServingEngine",
           "TickRecord", "TickPlan", "PhaseScheduler", "PhaseAwareConfig",
           "PrefixCache", "sample_tokens"]
