"""Phase-disaggregated serving: the engine EXECUTES the scheduler's TickPlan
— chunked prefill packed into one batch per tick, K/V written directly into
the decode arena (the HALO CiM -> CiD handoff), device-side sampling (one
host transfer per tick), and strategy-routed worker-group programs.

The public surface is REQUEST-centric: ``submit(prompt, sampling=
SamplingParams(...))`` takes per-request sampling/termination parameters
(temperature=0 is greedy), ``step()`` returns incremental
``RequestOutput``s, ``stream()``/``generate()`` are the streaming/batch
facades, and ``abort(req_id)`` cancels a request at any lifecycle stage.
See docs/serving.md for the tick loop and its mapping onto the paper."""

from repro.serving.engine import ServingEngine
from repro.serving.executor import (
    ColocatedExecutor,
    DisaggregatedExecutor,
    Executor,
    make_executor,
)
from repro.serving.frontend import AsyncEngine, AsyncRequest
from repro.serving.kv_pool import HostTier, KVPool
from repro.serving.metrics import (
    SLO,
    MetricsRegistry,
    quantile,
    slo_attainment,
)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampling import (
    SamplingParams,
    sample_tokens,
    sample_tokens_rows,
    verify_draft,
    verify_draft_rows,
)
from repro.serving.scheduler import (
    PRIORITY_BATCH,
    PRIORITY_INTERACTIVE,
    PRIORITY_STANDARD,
    AdmissionConfig,
    AdmissionController,
    PackedPrefill,
    PhaseAwareConfig,
    PhaseScheduler,
    TickPlan,
    pack_chunks,
)
from repro.serving.speculative import SpecConfig
from repro.serving.tracing import Tracer
from repro.serving.traffic import (
    ArrivalEvent,
    RequestResult,
    TenantSpec,
    TrafficConfig,
    TrafficReport,
    replay,
    synthesize,
)
from repro.serving.types import (
    Request,
    RequestOutput,
    RequestState,
    ServeConfig,
    TickRecord,
)

__all__ = [
    "AdmissionConfig",
    "AdmissionController",
    "ArrivalEvent",
    "AsyncEngine",
    "AsyncRequest",
    "ColocatedExecutor",
    "DisaggregatedExecutor",
    "Executor",
    "HostTier",
    "KVPool",
    "MetricsRegistry",
    "PRIORITY_BATCH",
    "PRIORITY_INTERACTIVE",
    "PRIORITY_STANDARD",
    "PackedPrefill",
    "PhaseAwareConfig",
    "PhaseScheduler",
    "PrefixCache",
    "Request",
    "RequestOutput",
    "RequestResult",
    "RequestState",
    "SLO",
    "SamplingParams",
    "ServeConfig",
    "ServingEngine",
    "SpecConfig",
    "TenantSpec",
    "TickPlan",
    "TickRecord",
    "Tracer",
    "TrafficConfig",
    "TrafficReport",
    "make_executor",
    "pack_chunks",
    "quantile",
    "replay",
    "sample_tokens",
    "sample_tokens_rows",
    "slo_attainment",
    "synthesize",
    "verify_draft",
    "verify_draft_rows",
]
