"""Paged KV arena: a fixed block pool replaces the dense per-slot cache.

The dense arena allocates ``[L, max_batch, max_len, Hkv, Dh]`` per attention
run — memory scales with ``max_batch x max_len`` whatever the actual
lengths, and no prompt may exceed ``max_len``.  The paged arena instead
allocates a fixed pool of ``n_pages`` pages of ``page_size`` tokens per run
(``[L, n_pages, page_size, Hkv, Dh]``) and maps each slot's logical
positions onto physical pages through a per-slot block table.  Capacity is
then a POOL property, not a slot property: the same pool serves one
16k-token request or eight 2k-token requests, and the scheduler admits
prefill work token-by-token against the free-page count (see
``PhaseScheduler.plan_tick``) while the engine preempts the youngest
request when decode outgrows the pool.

HALO reading: a page is a contiguous CiD row burst — the block table is
the bank/row decoder, so the GEMV sweep still streams whole rows (bank
locality) while placement becomes fully dynamic.  See docs/serving.md.

Two layers:

* ``PagePool`` — pure host-side accounting for ONE pool: free list,
  per-slot block tables, per-page REFCOUNTS, grow/shrink/release plus
  attach (share another owner's pages), retain/release_ref (external —
  prefix-cache — references) and copy-on-write.  No jax; property-testable
  (refcounts are conserved, no page is freed while referenced, COW never
  leaves a writer aliasing a shared page).
* ``KVPool`` — one ``PagePool`` + device page arrays per attention run of
  the model plan, ring/MLA-aware via ``cache_len``: a sliding-window run
  pools only its ring of ``min(window, capacity)`` logical entries, an MLA
  run pools latent rows ``[L, n_pages, page_size, r+dr]``.  With
  ``kv_dtype="int8"`` GQA runs store int8 pages with per-token f32 scales
  riding in a parallel page array (same block table), and MLA runs store
  int8 latent pages with a per-token ``latent_scale`` page array.  With
  ``kv_dtype="int4"`` GQA pages pack two nibbles per byte (uint8
  ``[..., Dh//2]``, see quantized_cache.pack_int4) for a 4x resident-KV
  reduction vs f32; MLA latents are already rank-compressed, so int4
  falls back to the int8 latent layout there (halving is the floor the
  rmsnorm-sensitive latents tolerate).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import build_plan, cache_len
from repro.serving.metrics import MetricsRegistry, counter_attr
from repro.serving.scheduler import pages_for


class PagePool:
    """Host-side page accounting for one fixed pool of ``n_pages`` pages.

    Tracks, per slot: the logical length and the block table row mapping
    logical page ``i`` to a physical page (the sentinel ``n_pages`` means
    "never allocated" — device scatters through it drop, gathers clamp and
    mask).  Pages carry a REFCOUNT: a page may back the same logical range
    of several slots at once (shared-prefix reuse) and may additionally be
    pinned by an external holder (the radix prefix cache) via
    ``retain``/``release_ref``.  A page returns to the free list only when
    its last reference drops; a writer about to dirty a shared page must
    go through ``cow`` first.  Pure Python/numpy; every mutation preserves
    the pool invariants (refcount conservation: ref == table references +
    external references; no free-while-referenced; no double assignment)
    that tests/test_kv_pool.py property-checks under arbitrary
    interleavings.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 capacity: int):
        if n_pages < 1 or page_size < 1:
            raise ValueError(f"need n_pages >= 1 and page_size >= 1, got "
                             f"{n_pages}/{page_size}")
        self.n_pages = n_pages
        self.page_size = page_size
        self.n_slots = n_slots
        # logical entries a slot can address (ring length R, or the full
        # pool span for position-indexed runs)
        self.capacity = capacity
        self.width = pages_for(capacity, page_size, capacity)
        self.free: List[int] = list(range(n_pages - 1, -1, -1))
        self.table = np.full((n_slots, self.width), n_pages, np.int32)
        self.lens = np.zeros((n_slots,), np.int64)
        # ref[p] = block-table rows pointing at p + external (cache) holds;
        # external is tracked separately so conservation is checkable
        self.ref = np.zeros((n_pages,), np.int32)
        self.external = np.zeros((n_pages,), np.int32)

    # -- queries ---------------------------------------------------------------
    def pages_of(self, length: int) -> int:
        return pages_for(length, self.page_size, self.capacity)

    def pages_needed(self, slot: int, new_len: int) -> int:
        return max(self.pages_of(new_len) - self.pages_of(int(self.lens[slot])),
                   0)

    def free_pages(self) -> int:
        return len(self.free)

    def used_pages(self) -> int:
        return self.n_pages - len(self.free)

    def is_shared(self, page: int) -> bool:
        """True iff ``page`` has more than one reference (another slot's
        table row, or the prefix cache) — a writer must COW it first."""
        return int(self.ref[page]) > 1

    def rows_touched(self, start: int, end: int) -> List[int]:
        """Block-table rows a write to logical positions [start, end)
        lands in (ring mapping: entry = pos % capacity).  A write range
        spanning the whole ring touches every row."""
        if end - start >= self.capacity:
            return list(range(self.width))
        rows, pos = [], start
        while pos < end:
            e = pos % self.capacity
            rows.append(e // self.page_size)
            # hop to the next page boundary OR the ring wrap, whichever
            # comes first (a ring span that is not a page multiple wraps
            # mid-page: positions on both sides land in different rows)
            pos += min(self.page_size - (e % self.page_size),
                       self.capacity - e)
        return sorted(set(rows))

    # -- mutations ---------------------------------------------------------------
    def _alloc(self) -> int:
        p = self.free.pop()
        assert self.ref[p] == 0, "free page had live references"
        self.ref[p] = 1
        return p

    def _decref(self, page: int) -> None:
        self.ref[page] -= 1
        assert self.ref[page] >= 0, "refcount underflow"
        if self.ref[page] == 0:
            self.free.append(int(page))

    def alloc_external(self) -> Optional[int]:
        """Allocate one free page owned by an EXTERNAL holder from birth
        (the prefix cache's host-tier PROMOTE path: a demoted block's KV
        is uploaded into a page no block table references yet).  The page
        starts at ref=1 external=1 — conservation (``ref == table_refs +
        external``) holds immediately — and frees through the usual
        ``release_ref``.  Returns None when the free list is empty."""
        if not self.free:
            return None
        p = self._alloc()
        self.external[p] += 1
        return p

    def grow(self, slot: int, new_len: int) -> bool:
        """Allocate the pages taking ``slot`` to ``new_len`` logical tokens.
        All-or-nothing: returns False (state unchanged) if the pool cannot
        cover it."""
        cur = int(self.lens[slot])
        if new_len < cur:
            raise ValueError(f"grow: new_len {new_len} < current {cur}")
        have = self.pages_of(cur)
        need = self.pages_of(new_len) - have
        if need > len(self.free):
            return False
        for j in range(need):
            self.table[slot, have + j] = self._alloc()
        self.lens[slot] = new_len
        return True

    def attach(self, slot: int, pages: Sequence[int], new_len: int) -> None:
        """Point an EMPTY slot's leading table rows at existing pages
        (shared-prefix reuse): each page gains a table reference, no page
        is allocated.  ``pages`` must exactly cover ``new_len`` tokens."""
        if int(self.lens[slot]) != 0:
            raise ValueError(f"attach: slot {slot} is not empty "
                             f"(len {int(self.lens[slot])})")
        if len(pages) != self.pages_of(new_len):
            raise ValueError(
                f"attach: {len(pages)} pages cannot back {new_len} tokens "
                f"(need {self.pages_of(new_len)})")
        for i, p in enumerate(pages):
            if not (0 <= p < self.n_pages) or self.ref[p] < 1:
                raise ValueError(f"attach: page {p} is not live")
            self.table[slot, i] = p
            self.ref[p] += 1
        self.lens[slot] = new_len

    def cow(self, slot: int, row: int) -> Optional[tuple]:
        """Copy-on-write the shared page behind ``table[slot, row]``: move
        the row to a freshly-allocated page and drop the old reference.
        Returns (old_page, new_page) for the caller's device copy, None if
        the page was exclusive (nothing to do).  Raises IndexError if the
        free list cannot supply the copy target — callers check
        ``free_pages()`` (or evict) first."""
        old = int(self.table[slot, row])
        if old >= self.n_pages or not self.is_shared(old):
            return None
        if not self.free:
            raise IndexError("cow: no free page for the copy target")
        new = self._alloc()
        self.table[slot, row] = new
        self.ref[old] -= 1              # > 0 by is_shared: never frees here
        return (old, new)

    def shrink(self, slot: int, new_len: int) -> None:
        """Drop the slot's references beyond ``new_len`` (rollback /
        partial free).  A page another slot or the prefix cache still
        references survives; exclusive pages return to the free list."""
        cur = int(self.lens[slot])
        if new_len > cur:
            raise ValueError(f"shrink: new_len {new_len} > current {cur}")
        keep = self.pages_of(new_len)
        for i in range(keep, self.pages_of(cur)):
            self._decref(int(self.table[slot, i]))
            self.table[slot, i] = self.n_pages
        self.lens[slot] = new_len

    def release(self, slot: int) -> None:
        """Drop every reference the slot holds (request done / preempted)."""
        self.shrink(slot, 0)

    # -- external (prefix cache) references ---------------------------------------
    def retain(self, page: int) -> None:
        """Pin a live page from outside the block tables (prefix cache)."""
        if not (0 <= page < self.n_pages) or self.ref[page] < 1:
            raise ValueError(f"retain: page {page} is not live")
        self.ref[page] += 1
        self.external[page] += 1

    def release_ref(self, page: int) -> None:
        """Drop one external reference; frees the page at refcount zero."""
        if self.external[page] < 1:
            raise ValueError(f"release_ref: page {page} has no external ref")
        self.external[page] -= 1
        self._decref(int(page))

    # -- invariants (asserted by the property tests) -----------------------------
    def check_invariants(self) -> None:
        table_refs = np.zeros((self.n_pages,), np.int64)
        for row in self.table:
            for p in row:
                if p < self.n_pages:
                    table_refs[p] += 1
        live = self.ref > 0
        assert (self.ref == table_refs + self.external).all(), \
            "refcount conservation violated (ref != table + external)"
        assert not (set(np.nonzero(live)[0].tolist()) & set(self.free)), \
            "page both referenced and free"
        assert len(self.free) == int((~live).sum()), \
            "free list does not match zero-ref pages"
        assert len(set(self.free)) == len(self.free), "free list duplicates"
        for s in range(self.n_slots):
            assert self.pages_of(int(self.lens[s])) == int(
                (self.table[s] < self.n_pages).sum()), "table/len mismatch"


class KVPool:
    """Device page arrays + per-run ``PagePool`` accounting for a model.

    ``caches`` is a list aligned with ``build_plan(cfg)`` — the paged
    analogue of ``init_cache`` — and is meant to be threaded through the
    engine's donated jitted programs exactly like the dense arena.  The
    block tables stay host-side (numpy) and are shipped per call.
    """

    def __init__(self, cfg: ModelConfig, n_slots: int, n_pages: int,
                 page_size: int, kv_dtype: str = "f32"):
        plan = build_plan(cfg)
        if not all(run.kind == "attn" for run in plan):
            raise ValueError(
                "paged KV arena requires an all-attention plan (GQA / "
                "sliding-window / MLA); SSM and shared-attention runs carry "
                f"recurrent state — got kinds {[r.kind for r in plan]}")
        if kv_dtype not in ("f32", "int8", "int4"):
            raise ValueError(f"kv_dtype must be 'f32', 'int8' or 'int4', "
                             f"got {kv_dtype!r}")
        if (kv_dtype == "int4" and not cfg.mla.enabled
                and cfg.d_head % 2):
            raise ValueError(f"kv_dtype='int4' packs head-dim pairs; "
                             f"d_head={cfg.d_head} is odd")
        self.cfg = cfg
        self.n_slots = n_slots
        self.n_pages = n_pages
        self.page_size = page_size
        self.kv_dtype = kv_dtype
        # a position-indexed (full-attention / MLA) run can address the
        # whole pool from one slot: that IS the new length bound
        self.capacity = n_pages * page_size
        # ... but a plan whose every run is a ring (all-sliding-window)
        # bounds nothing: rings reuse their pages forever, so sequence
        # length is unlimited (the scheduler's ring-clamped page charge
        # and the decode kernels' pos % R addressing both already handle
        # arbitrary positions)
        self.length_bound = (self.capacity
                             if any(r.window == 0 for r in plan)
                             else (1 << 62))
        self.plan = plan
        self.pools: List[PagePool] = []
        self.caches: List[Any] = []
        dtype = jnp.dtype(cfg.dtype)
        for run in plan:
            R = cache_len(run, self.capacity)
            self.pools.append(PagePool(n_pages, page_size, n_slots, R))
            L, P = run.n_layers, page_size
            if cfg.mla.enabled:
                w = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
                if kv_dtype != "f32":
                    # int8 latent pages + one f32 scale per (layer, token);
                    # int4 deliberately maps here too (see module docstring)
                    self.caches.append({
                        "latent": jnp.zeros((L, n_pages, P, w), jnp.int8),
                        "latent_scale": jnp.zeros((L, n_pages, P),
                                                  jnp.float32),
                    })
                else:
                    self.caches.append(
                        {"latent": jnp.zeros((L, n_pages, P, w), dtype)})
            elif kv_dtype == "int4":
                # packed nibble pairs: uint8 pages at half the head width
                # (uint8 vs int8 is also the runtime marker q4-vs-q8)
                shape = (L, n_pages, P, cfg.n_kv_heads, cfg.d_head // 2)
                sshape = (L, n_pages, P, cfg.n_kv_heads)
                self.caches.append({
                    "k": jnp.zeros(shape, jnp.uint8),
                    "k_scale": jnp.zeros(sshape, jnp.float32),
                    "v": jnp.zeros(shape, jnp.uint8),
                    "v_scale": jnp.zeros(sshape, jnp.float32),
                })
            elif kv_dtype == "int8":
                shape = (L, n_pages, P, cfg.n_kv_heads, cfg.d_head)
                sshape = (L, n_pages, P, cfg.n_kv_heads)
                self.caches.append({
                    "k": jnp.zeros(shape, jnp.int8),
                    "k_scale": jnp.zeros(sshape, jnp.float32),
                    "v": jnp.zeros(shape, jnp.int8),
                    "v_scale": jnp.zeros(sshape, jnp.float32),
                })
            else:
                shape = (L, n_pages, P, cfg.n_kv_heads, cfg.d_head)
                self.caches.append({"k": jnp.zeros(shape, dtype),
                                    "v": jnp.zeros(shape, dtype)})
        # byte accounting is precomputed: the engine DONATES the cache
        # arrays to its jitted programs, so the initial leaves must never
        # be touched again after handing ``caches`` over
        self._page_bytes = [
            sum(leaf.nbytes // n_pages for leaf in c.values())
            for c in self.caches]

    # -- capacity queries ---------------------------------------------------------
    def fits(self, total_len: int) -> bool:
        """Can the pool EVER hold a request of ``total_len`` tokens (prompt +
        generation), assuming it runs alone?  Position-indexed runs bound
        length by pool span; an all-ring plan bounds nothing (pages_of is
        ring-clamped, so the per-run page check is what binds)."""
        if total_len > self.length_bound:
            return False
        return all(p.pages_of(total_len) <= p.n_pages for p in self.pools)

    def free_pages(self) -> int:
        """Binding free-page count (min across runs)."""
        return min(p.free_pages() for p in self.pools)

    def headroom_pages(self, decode_lens: Sequence[int],
                       growth: int = 1) -> int:
        """Free pages available to NEW prefill work after reserving the
        growth this tick's decode writes need (``growth`` tokens per
        listed slot length — one for a plain decode step, ``spec_k + 1``
        when speculative verify windows write a whole draft window).
        Min across runs; floored at 0."""
        room = None
        for p in self.pools:
            reserve = sum(p.pages_of(l + growth) - p.pages_of(l)
                          for l in decode_lens)
            r = p.free_pages() - reserve
            room = r if room is None else min(room, r)
        return max(room or 0, 0)

    def len_of(self, slot: int) -> int:
        return int(self.pools[0].lens[slot])

    def max_grow_tokens(self, slot: int) -> int:
        """Largest token growth ``grow(slot, len + t)`` can grant right now
        (min across runs).  A run whose current + free pages reach its full
        width is never binding: ring runs reuse their pages forever."""
        room = None
        for p in self.pools:
            cur = int(p.lens[slot])
            held = p.pages_of(cur)
            if held + p.free_pages() >= p.width:
                continue
            cov = (held + p.free_pages()) * p.page_size - cur
            room = cov if room is None else min(room, cov)
        return self.capacity if room is None else max(room, 0)

    # -- prefix-sharing queries ----------------------------------------------------
    def shareable_capacity(self) -> int:
        """Longest prefix (tokens) whose pages are position-pure in EVERY
        run: up to the narrowest ring span, logical page ``i`` is table row
        ``i`` for all runs, so one per-run page list describes the prefix.
        Beyond a run's ring span the ring has wrapped and its pages mix
        positions — those are never shared."""
        return min(p.capacity for p in self.pools)

    def widest_capacity(self) -> int:
        """Logical span of the widest run — the scheduler's conservative
        page-charge basis (see ``PhaseScheduler.plan_tick``)."""
        return max(p.capacity for p in self.pools)

    def prefix_pages(self, slot: int, n_tokens: int) -> List[List[int]]:
        """Per-run physical pages backing the slot's first ``n_tokens``
        tokens (``n_tokens`` page-aligned, within ``shareable_capacity``)."""
        if n_tokens % self.page_size or n_tokens > self.shareable_capacity():
            raise ValueError(f"prefix of {n_tokens} tokens is not "
                             "page-aligned/shareable")
        n = n_tokens // self.page_size
        return [[int(q) for q in p.table[slot, :n]] for p in self.pools]

    # -- mutations ---------------------------------------------------------------
    def grow(self, slot: int, new_len: int) -> bool:
        """Grow ``slot`` to ``new_len`` logical tokens in EVERY run's pool —
        all-or-nothing (partial successes roll back)."""
        done: List[PagePool] = []
        prev = [int(p.lens[slot]) for p in self.pools]
        for p, old in zip(self.pools, prev):
            if not p.grow(slot, new_len):
                for q, o in zip(done, prev):
                    q.shrink(slot, o)
                return False
            done.append(p)
        return True

    def attach(self, slot: int, pages: Sequence[Sequence[int]],
               new_len: int) -> None:
        """Point an empty slot at cached prefix pages (one page list per
        run) — shared, refcounted, no allocation.  ``new_len`` must be
        page-aligned and within ``shareable_capacity``."""
        if new_len % self.page_size or new_len > self.shareable_capacity():
            raise ValueError(f"attach of {new_len} tokens is not "
                             "page-aligned/shareable")
        for p, pp in zip(self.pools, pages):
            p.attach(slot, pp, new_len)

    def cow_deficit(self, slot: int, start: int, end: int) -> int:
        """Free pages still missing before ``ensure_writable(slot, start,
        end)`` could supply every COW copy target (max across runs; 0 when
        it would succeed right now)."""
        deficit = 0
        for p in self.pools:
            need = sum(1 for row in p.rows_touched(start, end)
                       if int(p.table[slot, row]) < p.n_pages
                       and p.is_shared(int(p.table[slot, row])))
            deficit = max(deficit, need - p.free_pages())
        return max(deficit, 0)

    def ensure_writable(self, slot: int, start: int, end: int
                        ) -> Optional[List[tuple]]:
        """Copy-on-write every SHARED page a write to logical positions
        [start, end) of ``slot`` would dirty, across all runs.  Returns
        [(run, old_page, new_page)] — the caller must mirror each entry
        with a device page copy BEFORE launching the write — or None,
        state unchanged, if some run's free list cannot supply its copy
        targets (the caller evicts/preempts and retries)."""
        planned: List[tuple] = []                 # (run, pool, row)
        for r, p in enumerate(self.pools):
            rows = [row for row in p.rows_touched(start, end)
                    if int(p.table[slot, row]) < p.n_pages
                    and p.is_shared(int(p.table[slot, row]))]
            if len(rows) > p.free_pages():
                return None                       # nothing mutated yet
            planned.extend((r, p, row) for row in rows)
        copies: List[tuple] = []
        for r, p, row in planned:
            moved = p.cow(slot, row)
            assert moved is not None
            copies.append((r, *moved))
        return copies

    def shrink(self, slot: int, new_len: int) -> None:
        """Drop every run's references beyond ``new_len`` (rollback)."""
        for p in self.pools:
            p.shrink(slot, new_len)

    def truncate(self, slot: int, new_len: int) -> int:
        """Roll ``slot`` back to ``new_len`` logical tokens — the
        speculative-decode rejection path (rejected draft tokens' KV must
        stop being addressable).  Per run: pages that backed ONLY the
        rejected tail return to the free list; pages still referenced —
        another slot sharing the prefix, a prefix-cache pin — survive with
        their references intact (a rejected token never frees a shared
        page out from under its sharers), and a page holding both kept and
        rejected tokens is kept whole (the stale entries past ``new_len``
        are masked by position validity on every read path and overwritten
        in place by the accepted continuation).  COW'd pages the verify
        write privatized stay private.  Returns pages freed across runs.
        """
        if new_len > self.len_of(slot):
            raise ValueError(f"truncate: new_len {new_len} > current "
                             f"{self.len_of(slot)}")
        before = sum(p.free_pages() for p in self.pools)
        self.shrink(slot, new_len)
        return sum(p.free_pages() for p in self.pools) - before

    def rollback_bound(self) -> int:
        """Highest arena position (exclusive) through which speculative
        writes can still be ROLLED BACK safely.  A ring (sliding-window)
        run's entry at index ``p % R`` holds live position ``p - R`` once
        ``p >= R`` — writing a draft token there destroys history a
        rollback cannot restore, so the draft/verify loop must stop
        speculating at the narrowest ring span and fall back to one-token
        decode.  Position-indexed runs (full attention, MLA) mask stale
        entries by length, so any position the pool can address is
        rollback-safe."""
        bounds = [p.capacity for p, run in zip(self.pools, self.plan)
                  if run.window > 0]
        return min(bounds) if bounds else self.length_bound

    def release(self, slot: int) -> None:
        for p in self.pools:
            p.release(slot)

    # -- external (prefix cache) references ---------------------------------------
    def retain(self, run: int, page: int) -> None:
        self.pools[run].retain(page)

    def release_ref(self, run: int, page: int) -> None:
        self.pools[run].release_ref(page)

    # -- device-facing views --------------------------------------------------------
    def block_tables(self, active: Optional[np.ndarray] = None, *,
                     rows: Optional[Sequence[int]] = None,
                     n: int = 0) -> List[Any]:
        """Per-run ``[n_slots, W_r]`` int32 block tables for a jitted call.
        Rows of slots not in ``active`` (bool [n_slots]) are forced to the
        sentinel so their scatters drop and their gathers mask out.

        ``rows`` selects a COMPACTED view instead: row i of the returned
        tables is slot ``rows[i]``'s table, padded with all-sentinel rows
        up to ``max(n, len(rows))`` — the engine's bucketed decode batch,
        where batch rows no longer coincide with slots."""
        out = []
        for p in self.pools:
            if rows is not None:
                nb = max(n, len(rows))
                t = np.full((nb, p.table.shape[1]), p.n_pages,
                            p.table.dtype)
                if rows:
                    t[:len(rows)] = p.table[list(rows)]
            else:
                t = p.table
                if active is not None:
                    t = t.copy()
                    t[~active] = p.n_pages
            out.append(jnp.asarray(t))
        return out

    # -- accounting ---------------------------------------------------------------
    def page_bytes(self, r: int) -> int:
        """Bytes of device memory one physical page of run ``r`` holds
        (across all layers and parallel leaves, scales included)."""
        return self._page_bytes[r]

    def resident_bytes(self) -> int:
        """KV bytes resident = allocated pages x page bytes (the number the
        dense arena pins at ``sum(leaf.nbytes)`` regardless of occupancy)."""
        return sum(self.pools[r].used_pages() * self._page_bytes[r]
                   for r in range(len(self.pools)))

    def total_bytes(self) -> int:
        return sum(b * self.n_pages for b in self._page_bytes)

    def utilization(self) -> float:
        total = sum(p.n_pages for p in self.pools)
        used = sum(p.used_pages() for p in self.pools)
        return used / max(total, 1)

    def stats(self) -> Dict[str, Any]:
        return {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "capacity_tokens": self.capacity,
            "free_pages": self.free_pages(),
            "utilization": self.utilization(),
            "resident_bytes": self.resident_bytes(),
            "total_bytes": self.total_bytes(),
        }


class HostTier:
    """Host-memory spill pool behind the device page pool — the second
    level of the KV hierarchy (HALO reading: capacity memory behind the
    CiD banks, in the spirit of the High-Bandwidth-Flash argument).

    Holds ``n_pages`` host pages PER RUN, each mirroring the run's device
    page layout (every leaf — k/v, scales, MLA latents — at the same
    dtype, page axis dropped), plain numpy.  Two users:

    * **preemption swap**: the engine copies a victim's device pages here
      (``ServingEngine._swap_out``) and uploads them back at re-admission
      — resume costs two page copies instead of re-prefilling the whole
      prompt + generation (recompute stays the fallback when this tier is
      full or disabled);
    * **prefix demote/promote**: evicted prefix-cache blocks park their
      page contents here instead of dying, and re-promote to a fresh
      device page on the next hit (``PrefixCache`` callbacks).

    Pure host-side storage + free-list accounting; the DEVICE side of
    every copy lives in the engine (``_read_page``/``_write_page``), so
    tests can property-check this class without jax arrays.
    """

    # swap byte totals live in the metrics registry (the engine passes its
    # own, making these attributes views over the same cells counts() and
    # MetricsRegistry.snapshot() report — serving/metrics.py)
    swap_out_bytes = counter_attr("serving_swap_out_bytes_total")
    swap_in_bytes = counter_attr("serving_swap_in_bytes_total")

    def __init__(self, pool: KVPool, n_pages: int, *,
                 metrics: Optional[MetricsRegistry] = None):
        if n_pages < 1:
            raise ValueError(f"HostTier needs n_pages >= 1, got {n_pages}")
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.n_pages = n_pages
        self.n_runs = len(pool.caches)
        self._page_bytes = [pool.page_bytes(r) for r in range(self.n_runs)]
        # per-run page stores: leaf [L, n_dev_pages, P, ...] -> host
        # [L, n_host_pages, P, ...] (page axis 1, same as the device side)
        self._store: List[Dict[str, np.ndarray]] = [
            {k: np.zeros((leaf.shape[0], n_pages) + tuple(leaf.shape[2:]),
                         leaf.dtype)
             for k, leaf in cache.items()}
            for cache in pool.caches]
        self.free: List[List[int]] = [
            list(range(n_pages - 1, -1, -1)) for _ in range(self.n_runs)]
        # byte counters (device->host is "swap out", host->device "swap in")
        self.swap_out_bytes = 0
        self.swap_in_bytes = 0

    # -- queries ---------------------------------------------------------------
    def free_pages(self, run: Optional[int] = None) -> int:
        """Free host pages of one run, or the binding min across runs."""
        if run is not None:
            return len(self.free[run])
        return min(len(f) for f in self.free)

    def used_pages(self) -> int:
        """Host pages in use, summed across runs (the residency metric)."""
        return sum(self.n_pages - len(f) for f in self.free)

    def resident_bytes(self) -> int:
        return sum((self.n_pages - len(f)) * b
                   for f, b in zip(self.free, self._page_bytes))

    # -- allocation ------------------------------------------------------------
    def alloc(self, run: int, n: int) -> Optional[List[int]]:
        """Claim ``n`` host pages of ``run`` — all-or-nothing."""
        if n > len(self.free[run]):
            return None
        return [self.free[run].pop() for _ in range(n)]

    def release(self, run: int, pages: Sequence[int]) -> None:
        for p in pages:
            assert 0 <= p < self.n_pages and p not in self.free[run], \
                f"HostTier.release: page {p} of run {run} is not in use"
            self.free[run].append(int(p))

    # -- page contents ---------------------------------------------------------
    def store(self, run: int, page: int, data: Dict[str, np.ndarray]) -> None:
        """Copy one device page's host-fetched contents ({leaf: [L, P,
        ...]}) into host page ``page`` of ``run``."""
        for k, v in data.items():
            self._store[run][k][:, page] = v
        self.swap_out_bytes += self._page_bytes[run]

    def load(self, run: int, page: int) -> Dict[str, np.ndarray]:
        """Contents of host page ``page`` of ``run``, ready for a device
        upload (views — the caller uploads before the page is reused)."""
        self.swap_in_bytes += self._page_bytes[run]
        return {k: v[:, page] for k, v in self._store[run].items()}

    # -- invariants -------------------------------------------------------------
    def check_invariants(self) -> None:
        for r, f in enumerate(self.free):
            assert len(set(f)) == len(f), f"run {r}: free-list duplicates"
            assert all(0 <= p < self.n_pages for p in f), \
                f"run {r}: free list out of range"

    def stats(self) -> Dict[str, Any]:
        return {
            "n_pages": self.n_pages,
            "free_pages": self.free_pages(),
            "used_pages": self.used_pages(),
            "resident_bytes": self.resident_bytes(),
            "swap_out_bytes": self.swap_out_bytes,
            "swap_in_bytes": self.swap_in_bytes,
        }
