"""Serving datatypes: the host-only vocabulary shared by every layer.

PR 8 split the serving stack into three layers (see docs/serving.md
§Disaggregated serving):

  * **EngineCore** (``serving/engine.py``) — request lifecycle +
    ``PhaseScheduler`` driving; device-agnostic;
  * **Executor** (``serving/executor.py``) — the jitted program table,
    compile counting, and device placement (colocated or disaggregated
    prefill/decode device groups with KV-page migration);
  * **KV tiers** (``serving/kv_pool.py``) — the device ``PagePool`` plus
    an optional host-memory spill tier behind it.

These dataclasses are the contract between them — pure host types with
no jax dependency.  Code that used to import them from
``repro.serving.engine`` keeps working (the engine re-exports them),
but new code should import from ``repro.serving`` or here.
"""

from __future__ import annotations

import time  # noqa: F401  (Request timestamps are filled by the engine)
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, List, Optional

import numpy as np

from repro.serving.metrics import SLO
from repro.serving.sampling import SamplingParams
from repro.serving.scheduler import (AdmissionConfig, PhaseAwareConfig,
                                     PRIORITY_STANDARD)
from repro.serving.speculative import SpecConfig


class RequestState(Enum):
    WAITING = "waiting"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    DONE = "done"


@dataclass
class Request:
    req_id: int
    prompt: np.ndarray                  # [T] int32 (or [K, T])
    sampling: SamplingParams = field(default_factory=SamplingParams)
    # filled by the engine
    state: RequestState = RequestState.WAITING
    generated: List[Any] = field(default_factory=list)
    finish_reason: Optional[str] = None  # "length"|"eos"|"stop"|"abort"
    seed: int = 0                       # effective per-request PRNG seed
    slot: int = -1
    prompt_len: int = 0
    prefill_pos: int = 0                # prompt tokens already in the arena
    n_preempted: int = 0                # pool-exhaustion evictions survived
    cached_tokens: int = 0              # tokens served from the prefix cache
    t_submit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    t_requeue: float = 0.0              # last preemption re-queue instant
    # latency deadlines for goodput accounting (serving/metrics.py);
    # None = best-effort, excluded from SLO attainment
    slo: Optional[SLO] = None
    # scheduling lane (scheduler.PRIORITY_*): prefill admission orders by
    # (priority, TTFT deadline, age) — see PhaseScheduler.plan_tick
    priority: int = PRIORITY_STANDARD
    # host-tier swap handle (set while the request's KV pages live in the
    # host spill pool between a swap-out preemption and its swap-in resume)
    swap: Optional[Any] = None

    @property
    def max_new_tokens(self) -> int:
        return self.sampling.max_new_tokens

    @property
    def eos_id(self) -> Optional[int]:
        return self.sampling.eos_id

    @property
    def ttft_deadline_s(self) -> float:
        """Absolute wall-clock instant the first token is due (``inf``
        for best-effort requests or an SLO with no TTFT term) — the EDF
        key ``PhaseScheduler.plan_tick`` orders prefill admission by,
        and the bound the admission controller projects against."""
        if self.slo is not None and self.slo.ttft_ms is not None:
            return self.t_submit + self.slo.ttft_ms / 1e3
        return float("inf")

    @property
    def ttft(self) -> float:
        """Time to first token; NaN for a request that never emitted one
        (max_new_tokens=0, aborted pre-first-token) — the old sentinel
        arithmetic returned a large negative number instead."""
        if self.t_first_token <= 0.0:
            return float("nan")
        return self.t_first_token - self.t_submit

    @property
    def tpot(self) -> float:
        """Time per output token after the first; NaN when undefined
        (no token ever emitted, or not yet finished)."""
        if self.t_first_token <= 0.0 or self.t_done <= 0.0:
            return float("nan")
        n = max(len(self.generated) - 1, 1)
        return (self.t_done - self.t_first_token) / n


@dataclass(frozen=True)
class RequestOutput:
    """One incremental slice of a request's token stream.

    ``step()`` returns one per request that advanced this tick (new
    tokens appended and/or the request finished); ``stream()`` yields
    them as they are produced.  ``new_token_ids`` holds only THIS
    step's tokens (ints, or per-codebook lists for multi-codebook
    heads); ``n_generated`` is the cumulative count.  ``finish_reason``
    is set on the final output: "length" (max_new_tokens or arena/pool
    length bound), "eos", "stop" (a ``SamplingParams.stop`` token), or
    "abort"."""
    req_id: int
    new_token_ids: List[Any]
    n_generated: int
    finished: bool
    finish_reason: Optional[str] = None


@dataclass
class TickRecord:
    """One engine tick as executed (mirrors the TickPlan it consumed)."""
    index: int
    prefill_reqs: List[int]
    prefill_tokens: int
    decode_reqs: List[int]
    prefill_group: str
    decode_group: str
    wall_s: float
    preemptions: int = 0                # pool evictions this tick (paged)
    kv_resident_bytes: int = 0          # allocated KV bytes after the tick
    spec_drafted: int = 0               # draft tokens verified this tick
    spec_accepted: int = 0              # draft tokens accepted this tick
    new_compiles: int = 0               # phase-program shapes first seen here
    # prefill -> decode KV migration (DisaggregatedExecutor: the 2.5D-link
    # analogue; one batch per tick covers every handoff the tick completed)
    migrated_pages: int = 0
    migrated_bytes: int = 0
    # host spill tier (swap preemption + prefix demote/promote)
    swap_out_bytes: int = 0             # device -> host bytes this tick
    swap_in_bytes: int = 0              # host -> device bytes this tick
    host_resident_pages: int = 0        # host-tier pages in use after tick

    @property
    def mixed(self) -> bool:
        """Both phases ran this tick (prefill/decode interleaving)."""
        return bool(self.prefill_reqs) and bool(self.decode_reqs)


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512                  # dense arena length (unused if paged)
    phase: PhaseAwareConfig = field(default_factory=PhaseAwareConfig)
    # DEPRECATED engine-wide sampling fields: sampling is per-request now
    # (``submit(..., sampling=SamplingParams(...))``).  These survive as
    # the default SamplingParams for submits that pass none — setting any
    # of them off-default warns at engine construction.
    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 0.0                  # nucleus sampling (0 = off)
    seed: int = 0                       # base seed for derived request seeds
    # speculative decoding (serving/speculative.py, requires paged): a
    # drafter proposes up to k tokens per decode tick and one verify
    # window of the target model accepts/rejects them all at once
    speculative: Optional[SpecConfig] = None
    # paged KV arena (serving/kv_pool.py): capacity = n_pages * page_size
    # tokens PER POOL, not per slot — prompts/generations are bounded by
    # pool capacity rather than max_len
    paged: bool = False
    page_size: int = 16
    n_pages: int = 64
    # KV page dtype (paged only): "int8" stores GQA K/V pages and MLA
    # latent pages quantized per token; "int4" packs GQA K/V two nibbles
    # per byte (MLA latents stay int8 — see serving/kv_pool.py)
    kv_dtype: str = "f32"
    # weight dtype: "int8" runs quantize_params at engine build and serves
    # from {"q","scale"} leaves — decode-shaped matmuls then route through
    # the fused quantized Pallas GEMV (models/layers.matmul)
    weights_dtype: str = "f32"
    # radix prefix cache over the page pool (requires paged): shared-prompt
    # KV pages are reused copy-on-write instead of recomputed
    prefix_cache: bool = False
    # packed prefill: the tick's chunks run as ONE flat token stream with
    # per-segment metadata (models/transformer.forward_chunk_packed)
    # instead of a padded [N, C] batch — pad work drops from
    # N*C - sum(take) to the pack-alignment remainder, and the compiled
    # shape is keyed by ONE bucketed length instead of an (N, C) grid.
    # Applies to chunked attention-only single-codebook plans; everything
    # else falls back to the padded path.  Greedy streams are
    # bit-identical either way.
    packed_prefill: bool = True
    # executor: "colocated" (one device group runs every program — today's
    # behavior, the default) or "disaggregated" (prefill/verify programs
    # pinned to the prefill device group, decode programs to the decode
    # group, with KV pages migrating at the prefill->decode handoff —
    # serving/executor.py; greedy streams are bit-identical either way)
    executor: str = "colocated"
    # host-memory spill tier (paged only): pages per run the HostTier may
    # hold.  > 0 makes preemption SWAP a victim's KV pages to host memory
    # and resume by swapping them back in (zero re-prefilled tokens)
    # instead of recompute-on-resume, and lets evicted prefix-cache nodes
    # demote to host and promote on re-hit.  0 disables the tier
    # (recompute-on-resume, prefix eviction is terminal — PR 2/3 behavior)
    host_spill_pages: int = 0
    # admission control (scheduler.AdmissionController): shed/defer work
    # at submit() when projected TTFT under current occupancy busts the
    # request's deadline, instead of admitting into preemption thrash.
    # None disables it (every submit is admitted — pre-PR-10 behavior)
    admission: Optional[AdmissionConfig] = None

    def __post_init__(self):
        if self.executor not in ("colocated", "disaggregated"):
            raise ValueError(f"executor={self.executor!r} (expected "
                             "'colocated' or 'disaggregated')")

    _LEGACY_SAMPLING_DEFAULTS = (True, 1.0, 0, 0.0)

    def legacy_sampling_overridden(self) -> bool:
        return ((self.greedy, self.temperature, self.top_k, self.top_p)
                != self._LEGACY_SAMPLING_DEFAULTS)

    def default_sampling(self) -> SamplingParams:
        """The deprecated engine-wide sampling fields as a per-request
        default.  ``greedy=True`` maps to temperature 0 (the new API's
        greedy); the legacy ``max(temperature, 1e-6)`` floor applies only
        inside this shim — ``SamplingParams(temperature=0)`` itself IS
        greedy, with no epsilon rewriting."""
        return SamplingParams(
            temperature=0.0 if self.greedy else max(self.temperature, 1e-6),
            top_k=self.top_k, top_p=self.top_p)


__all__ = [
    "Request",
    "RequestOutput",
    "RequestState",
    "ServeConfig",
    "TickRecord",
]
